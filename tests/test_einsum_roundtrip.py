"""Round-trip property tests for the extended-einsum string parser and
pretty-printer: ``parse_einsum`` ↔ ``EinSpec.pretty`` / ``einsum_str``.

``hypothesis`` is optional (requirements-dev.txt): when installed the
properties are fuzzed over random specs; otherwise a deterministic grid
covers the same territory — unary specs, empty-agg elementwise nodes,
non-sum aggregations, scalar outputs, word-mode vs char-mode labels, and
the documented single-multi-char-label ambiguity fallback.
"""
import pytest

from repro.core import canon
from repro.core.einsum import AGGS, EinSpec, parse_einsum

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


# ---------------------------------------------------------------------------
# Deterministic case grid
# ---------------------------------------------------------------------------


def _cases():
    for L in ("ijkl", ("batch", "seq", "heads", "ff")):
        a, b, c, d = L
        # binary contraction (sum) and non-sum aggregations
        yield EinSpec(((a, b), (b, c)), (a, c), "mul", "sum")
        yield EinSpec(((a, b), (b, c)), (a,), "maximum", "max")
        yield EinSpec(((a, b), (b, c)), (c,), "add", "min")
        yield EinSpec(((a, b, c), (c, d)), (d, a, b), "sqdiff", "prod")
        # binary elementwise (empty agg), incl. transposed output
        yield EinSpec(((a, b, c), (a, b, c)), (c, b, a), "add", "")
        yield EinSpec(((a, b), (a, b)), (a, b), "div", "")
        # unary: full reduce to scalar, partial reduce, elementwise permute
        yield EinSpec(((a, b),), (), "id", "sum")
        yield EinSpec(((a, b, c),), (c, a), "exp", "prod")
        yield EinSpec(((a, b, c),), (b, c, a), "neg", "")
        yield EinSpec(((a,),), (a,), "square", "")
    # word mode with a spaceless single-label side
    yield EinSpec((("batch", "seq"), ("seq",)), ("batch",), "mul", "sum")
    # irreducible ambiguity: every side at most one multi-char label
    yield EinSpec((("batch",),), ("batch",), "id", "")
    yield EinSpec((("batch",), ("batch",)), (), "mul", "sum")


def _assert_roundtrip(spec: EinSpec):
    s = spec.pretty()
    ins, outs = parse_einsum(s)
    rebuilt = EinSpec(ins, outs, spec.combine, spec.agg)
    if s == spec.einsum_str() and (ins, outs) != (spec.in_labels, spec.out_labels):
        # documented fallback: canonical single-char rename — structurally
        # identical spec (same canonical key), different label names
        assert canon.spec_key(rebuilt) == canon.spec_key(spec)
    else:
        assert rebuilt == spec, f"{s!r}: {rebuilt} != {spec}"


@pytest.mark.parametrize("spec", list(_cases()),
                         ids=lambda s: s.pretty().replace(" ", ""))
def test_pretty_parse_roundtrip(spec):
    _assert_roundtrip(spec)


@pytest.mark.parametrize("spec", list(_cases()),
                         ids=lambda s: s.pretty().replace(" ", ""))
def test_einsum_str_parse_is_canonically_isomorphic(spec):
    """parse(einsum_str()) loses label names by design but must preserve
    structure exactly (same canonical spec key, same agg semantics)."""
    ins, outs = parse_einsum(spec.einsum_str())
    rebuilt = EinSpec(ins, outs, spec.combine, spec.agg)
    assert canon.spec_key(rebuilt) == canon.spec_key(spec)
    assert len(rebuilt.agg_labels) == len(spec.agg_labels)
    assert rebuilt.all_labels == tuple(dict.fromkeys(
        l for ls in (*rebuilt.in_labels, rebuilt.out_labels) for l in ls))


def test_word_mode_is_whole_expression():
    """A spaceless side inside a spaced expression is ONE label, never a
    character run (regression for the old per-side heuristic)."""
    ins, outs = parse_einsum("b s e, e -> b s")
    assert ins == (("b", "s", "e"), ("e",)) and outs == ("b", "s")
    # fully spaceless still parses per character
    ins, outs = parse_einsum("bse,ehd->bshd")
    assert ins == (("b", "s", "e"), ("e", "h", "d"))
    assert outs == ("b", "s", "h", "d")
    # scalar output sides parse to ()
    assert parse_einsum("i j -> ")[1] == ()
    assert parse_einsum("ij->")[1] == ()


# ---------------------------------------------------------------------------
# Fuzzed property (hypothesis optional)
# ---------------------------------------------------------------------------

if HAVE_HYP:
    _LABELS = st.sampled_from(
        ["i", "j", "k", "l", "batch", "seq", "heads", "dmodel"])

    @st.composite
    def _specs(draw):
        universe = draw(st.lists(_LABELS, min_size=1, max_size=5,
                                 unique=True))
        n_in = draw(st.integers(1, 2))
        ins = []
        for _ in range(n_in):
            ls = draw(st.lists(st.sampled_from(universe), min_size=1,
                               max_size=len(universe), unique=True))
            ins.append(tuple(ls))
        all_labels = [l for ls in ins for l in ls]
        all_unique = list(dict.fromkeys(all_labels))
        elementwise = draw(st.booleans())
        if elementwise:
            out = tuple(draw(st.permutations(all_unique)))
            agg = ""
        else:
            out = tuple(draw(st.permutations(
                draw(st.lists(st.sampled_from(all_unique), max_size=len(all_unique),
                              unique=True)))))
            agg = draw(st.sampled_from(AGGS))
        combine = draw(st.sampled_from(
            ["mul", "add", "sub", "div", "maximum"] if n_in == 2
            else ["id", "exp", "neg", "square"]))
        return EinSpec(tuple(ins), out, combine, agg)

    @settings(max_examples=200, deadline=None)
    @given(_specs())
    def test_roundtrip_property(spec):
        _assert_roundtrip(spec)
