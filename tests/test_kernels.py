"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul
from repro.kernels.moe_gmm import gmm

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


ATT_CASES = [
    # (b, hq, hkv, sq, sk, d, causal, window, dtype)
    (1, 4, 2, 128, 128, 64, True, 0, jnp.float32),
    (2, 2, 1, 256, 256, 32, True, 64, jnp.float32),
    (1, 2, 2, 128, 256, 64, False, 0, jnp.float32),
    (1, 8, 1, 128, 128, 128, True, 0, jnp.float32),
    (1, 4, 4, 128, 128, 64, True, 0, jnp.bfloat16),
    (2, 4, 2, 64, 64, 16, True, 32, jnp.float32),
]


@pytest.mark.parametrize("case", ATT_CASES)
def test_flash_attention_vs_oracle(case):
    b, hq, hkv, sq, sk, d, causal, win, dt = case
    q = _rand((b, hq, sq, d), dt)
    k = _rand((b, hkv, sk, d), dt)
    v = _rand((b, hkv, sk, d), dt)
    qoff = sk - sq if causal else 0
    out = flash_attention(q, k, v, causal=causal, window=win, q_offset=qoff,
                          blk_q=64, blk_k=64, interpret=True)
    want = ref.attention(q, k, v, causal=causal, window=win, q_offset=qoff)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    assert out.dtype == dt
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_shape_invariance():
    q = _rand((1, 2, 256, 64), jnp.float32)
    k = _rand((1, 2, 256, 64), jnp.float32)
    v = _rand((1, 2, 256, 64), jnp.float32)
    outs = [flash_attention(q, k, v, blk_q=bq, blk_k=bk, interpret=True)
            for bq, bk in ((64, 64), (128, 128), (256, 64), (64, 256))]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n,dt", [
    (128, 128, 128, jnp.float32),
    (256, 384, 128, jnp.float32),
    (128, 256, 512, jnp.bfloat16),
    (64, 64, 64, jnp.float32),
])
def test_matmul_vs_oracle(m, k, n, dt):
    x = _rand((m, k), dt)
    w = _rand((k, n), dt)
    out = matmul(x, w, interpret=True)
    want = ref.matmul(x, w)
    tol = 3e-2 if dt == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol,
                               atol=tol * 8)


@pytest.mark.parametrize("e,c,k,n,dt", [
    (4, 128, 256, 128, jnp.float32),
    (8, 128, 128, 384, jnp.float32),
    (2, 256, 128, 128, jnp.bfloat16),
])
def test_gmm_vs_oracle(e, c, k, n, dt):
    x = _rand((e, c, k), dt)
    w = _rand((e, k, n), dt)
    out = gmm(x, w, interpret=True)
    want = ref.gmm(x, w)
    tol = 3e-2 if dt == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol,
                               atol=tol * 8)


def test_attention_oracle_decode_consistency():
    """Prefill oracle vs step-by-step decode with q_offset must agree."""
    b, h, s, d = 1, 2, 16, 32
    q = _rand((b, h, s, d), jnp.float32)
    k = _rand((b, h, s, d), jnp.float32)
    v = _rand((b, h, s, d), jnp.float32)
    full = ref.attention(q, k, v, causal=True)
    for t in (0, 5, 15):
        one = ref.attention(q[:, :, t:t + 1], k[:, :, :s], v[:, :, :s],
                            causal=True, q_offset=t)
        np.testing.assert_allclose(np.asarray(one[:, :, 0]),
                                   np.asarray(full[:, :, t]),
                                   rtol=1e-5, atol=1e-5)
