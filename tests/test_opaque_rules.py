"""Per-shard opaque dispatch (core/opaque_rules.py + core/spmd.py).

Four layers of coverage:

1. **Ring-step numerics** (device-free): chaining the online-softmax
   ``attention_step`` over every kv block — in any visit order, with the
   matching ``kv_offset`` — reproduces dense attention for causal,
   sliding-window, and GQA configs at every ring offset (the classic
   ring-attention off-by-one), for both the jnp reference and the Pallas
   step kernel (interpret mode).

2. **Schedule assertions** (device-free): the ring rule requests co-sharded
   q/kv layouts and emits exactly 2·(r-1) ppermute hops; the a2a rule emits
   the counts all-gather + two all_to_alls and lands the dispatch output in
   the plan's expert-sharded layout; structural precondition failures fall
   back to replicate; unknown/mixed rule declarations fail at plan time.

3. **Execution equivalence** on whatever host mesh exists: ring attention
   and a2a MoE (including real capacity drops) vs the dense oracle; the
   multi-device CI job re-runs this under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

4. **Cost accounting**: for every zoo family, traced wire elems of each
   ring/a2a-ruled opaque node stay within ``decomp.opaque_node_bound`` (the
   per-node slice of the §7 objective) — the bench_spmd --check property.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import engine, opaque_rules, spmd
from repro.core.decomp import (Plan, eindecomp, opaque_node_bound, plan_cost)
from repro.core.einsum import EinGraph, eval_graph_dense
from repro.kernels import ref
from repro.launch.mesh import make_host_mesh
from repro.models.eingraphs import program_for
from repro.models.opaque_stubs import capacity_of, make_stub_opaques

RNG = np.random.default_rng(0)
N_DEV = len(jax.devices())


# ---------------------------------------------------------------------------
# 1. ring-step numerics: every offset, every config, any visit order
# ---------------------------------------------------------------------------

RING_CONFIGS = [
    # (causal, window, hq, hkv)
    (True, 0, 4, 4),    # causal MHA
    (True, 0, 4, 2),    # causal GQA
    (True, 0, 4, 1),    # causal MQA
    (True, 16, 4, 2),   # sliding window + GQA
    (False, 0, 4, 2),   # bidirectional
]


def _qkv(hq, hkv, b=2, s=32, d=16, scale=0.3):
    q = (RNG.normal(size=(b, hq, s, d)) * scale).astype(np.float32)
    k = (RNG.normal(size=(b, hkv, s, d)) * scale).astype(np.float32)
    v = (RNG.normal(size=(b, hkv, s, d)) * scale).astype(np.float32)
    return q, k, v


@pytest.mark.parametrize("r", [2, 4, 8])
@pytest.mark.parametrize("causal,window,hq,hkv", RING_CONFIGS)
def test_ring_chain_matches_dense_every_offset(causal, window, hq, hkv, r):
    """Fold the kv blocks in rotated ring order starting from every offset;
    each must reproduce the dense result (the rotation changes which blocks
    are causally masked — the off-by-one this test pins)."""
    q, k, v = _qkv(hq, hkv)
    s = q.shape[2]
    blk = s // r
    dense = np.asarray(ref.attention(q, k, v, causal=causal, window=window))
    for start in range(r):
        order = [(start - t) % r for t in range(r)]  # ring visit order
        carry = None
        for j in order:
            carry = ref.attention_step(
                q, k[:, :, j * blk:(j + 1) * blk],
                v[:, :, j * blk:(j + 1) * blk], carry,
                causal=causal, window=window, kv_offset=j * blk)
        got = np.asarray(ref.attention_finalize(carry, q.dtype))
        np.testing.assert_allclose(got, dense, rtol=1e-5, atol=1e-6,
                                   err_msg=f"ring offset {start}")


@pytest.mark.parametrize("causal,window,hq,hkv", RING_CONFIGS[:3])
def test_pallas_step_kernel_matches_ref_chain(causal, window, hq, hkv):
    from repro.kernels.flash_attention import flash_attention_step

    q, k, v = _qkv(hq, hkv)
    s = q.shape[2]
    r, blk = 4, s // 4
    dense = np.asarray(ref.attention(q, k, v, causal=causal, window=window))
    carry = None
    for j in [1, 3, 0, 2]:
        carry = flash_attention_step(
            q, k[:, :, j * blk:(j + 1) * blk],
            v[:, :, j * blk:(j + 1) * blk], carry,
            causal=causal, window=window, kv_offset=j * blk,
            blk_q=16, blk_k=8)
    got = np.asarray(ref.attention_finalize(carry, q.dtype))
    np.testing.assert_allclose(got, dense, rtol=1e-5, atol=1e-6)


def test_flash_attention_kernel_kv_offset():
    """The plain kernel's kv_offset shifts the mask exactly like the ref.
    Rows with no visible kv position are excluded: the kernel's block-skip
    outputs 0 there while the finite-NEG_INF reference averages (a corner
    no full-sequence chain ever hits)."""
    from repro.kernels.flash_attention import flash_attention

    q, k, v = _qkv(4, 2)
    blk = 8
    for off in (0, 8, 24):
        kb = k[:, :, off:off + blk]
        vb = v[:, :, off:off + blk]
        got = np.asarray(flash_attention(q, kb, vb, causal=True,
                                         kv_offset=off, blk_q=16, blk_k=8))
        want = np.asarray(ref.attention(q, kb, vb, causal=True,
                                        kv_offset=off))
        np.testing.assert_allclose(got[:, :, off:], want[:, :, off:],
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# helpers: hand-built graphs + plans
# ---------------------------------------------------------------------------

B, H, K, S, D = 2, 4, 2, 32, 16
E, CAP = 8, 4  # tiny capacity: 64 tokens, 32 slots -> real drops


def _attn_graph(window=0, kv_heads=K):
    g = EinGraph("ring")
    q = g.input("q", "b h s d", (B, H, S, D))
    k = g.input("k", "b k s d", (B, kv_heads, S, D))
    v = g.input("v", "b k s d", (B, kv_heads, S, D))
    o = g.opaque(
        "flash_attention", [q, k, v], "b h s d", (B, H, S, D),
        in_labels=[("b", "h", "s", "d"), ("b", "k", "s", "d"),
                   ("b", "k", "s", "d")],
        shardable={"b", "h", "k", "s"},
        comm=[{"kind": "ring", "label": "s", "input": 1, "rule": "ring"},
              {"kind": "ring", "label": "s", "input": 2, "rule": "ring"}],
        window=window)
    return g, o


def _moe_graph(seq=S):
    g = EinGraph("moe")
    x = g.input("x", "b s a", (B, seq, D))
    route = g.input("route", "b s e", (B, seq, E))
    disp = g.opaque(
        "moe_dispatch", [x, route], "e c a", (E, CAP, D),
        in_labels=[("b", "s", "a"), ("b", "s", "e")],
        shardable={"e", "c", "b", "s"},
        comm=[{"kind": "a2a", "label": "e", "input": 0, "rule": "a2a"}])
    comb = g.opaque(
        "moe_combine", [disp, route], "b s a", (B, seq, D),
        in_labels=[("e", "c", "a"), ("b", "s", "e")],
        shardable={"e", "c", "b", "s"},
        comm=[{"kind": "a2a", "label": "e", "input": -1, "rule": "a2a"}])
    return g, disp, comb


def _uniform_plan(g, axes_cfg, p=8):
    """Every non-input node gets the same label->axes map; graph inputs
    stay replicated (the executor then slices them locally, so the
    schedule assertions see only the rules' own collectives)."""
    plan = Plan(p=p, mode="mesh")
    for n in g.nodes:
        plan.d_by_node[n.nid] = {l: 1 for l in n.labels}
        plan.axes_by_node[n.nid] = {} if n.kind == "input" else dict(axes_cfg)
    return plan


# ---------------------------------------------------------------------------
# 2. schedule assertions (device-free)
# ---------------------------------------------------------------------------


def test_ring_schedule_ppermute_counts():
    g, o = _attn_graph()
    sizes = {"data": 2, "model": 4}
    plan = _uniform_plan(g, {"s": ("model",), "b": ("data",)})
    sched = spmd.build_schedule(g, plan, sizes, [o])
    tr = sched.trace
    assert tr.rule_by_node[o] == "ring"
    # 2 tensors x (r-1) hops, and never a kv all_gather
    assert tr.counts.get("ppermute", 0) == 2 * (4 - 1)
    assert tr.counts.get("all_gather", 0) == 0
    # q/k/v co-sharded: batch on data, sequence on model
    assert sched.layouts[o] == (("data",), (), ("model",), ())
    # ring wire == the declared (r-1) * numel per circulating tensor
    kv_numel = B * K * S * D
    perm_elems = sum(e.elems for e in tr.events if e.kind == "ppermute")
    assert perm_elems == 2 * (4 - 1) * kv_numel


def test_ring_schedule_local_when_sequence_unsharded():
    """b/h/k sharded, s unsharded: the rule runs fully local per shard —
    zero collectives, which is exactly what the DP priced (the replicated
    fallback would all_gather full K/V here)."""
    g, o = _attn_graph()
    sizes = {"data": 2, "model": 2}
    plan = _uniform_plan(g, {"b": ("data",), "h": ("model",)}, p=4)
    sched = spmd.build_schedule(g, plan, sizes, [o])
    assert sched.trace.rule_by_node[o] == "ring"
    assert len(sched.trace) == 0, sched.trace.summary()
    # kv heads co-sharded with q heads so the GQA group mapping is local
    assert sched.layouts[o] == (("data",), ("model",), (), ())


def test_ring_falls_back_when_heads_do_not_divide():
    g, o = _attn_graph()
    sizes = {"data": 2, "model": 4}
    # h sharded 4-way but only 2 kv heads: K % ph != 0 -> replicate
    plan = _uniform_plan(g, {"h": ("model",), "b": ("data",)})
    sched = spmd.build_schedule(g, plan, sizes, [o])
    assert sched.trace.rule_by_node[o] == "replicate"


def test_a2a_schedule_counts_and_layout():
    g, disp, comb = _moe_graph()
    sizes = {"data": 2, "model": 4}
    plan = _uniform_plan(g, {"e": ("data", "model")})
    sched = spmd.build_schedule(g, plan, sizes)
    tr = sched.trace
    assert tr.rule_by_node == {disp: "a2a", comb: "a2a"}
    per_node = {}
    for e in tr.events:
        per_node.setdefault(e.nid, []).append(e.kind)
    # dispatch: counts all-gather + slot a2a + payload a2a (inputs sliced
    # locally, never gathered)
    assert sorted(per_node[disp]) == ["all_gather", "all_to_all",
                                      "all_to_all"]
    # dispatch output lands expert-sharded: zero repartition into the
    # expert FFN einsums that want e on the mesh
    assert sched.layouts[disp] == (("data", "model"), (), ())
    # combine hands its consumers sequence-sharded tokens
    assert sched.layouts[comb] == ((), ("data", "model"), ())


def test_a2a_falls_back_when_sequence_does_not_divide():
    g, disp, comb = _moe_graph(seq=20)  # 20 % 8 != 0: no 8-way token shard
    sizes = {"data": 2, "model": 4}
    plan = _uniform_plan(g, {"e": ("data", "model")})
    sched = spmd.build_schedule(g, plan, sizes)
    assert sched.trace.rule_by_node[disp] == "replicate"


def test_unknown_rule_rejected_at_plan_time():
    g = EinGraph()
    x = g.input("x", "b s a", (2, 4, 8))
    g.opaque("mystery", [x], "b s a", (2, 4, 8),
             in_labels=[("b", "s", "a")],
             comm=[{"kind": "ring", "label": "s", "input": 0,
                    "rule": "warp-drive"}])
    with pytest.raises(ValueError, match="warp-drive"):
        eindecomp(g, 2)


def test_mixed_rules_rejected():
    g = EinGraph()
    x = g.input("x", "b s a", (2, 4, 8))
    g.opaque("mystery", [x], "b s a", (2, 4, 8),
             in_labels=[("b", "s", "a")],
             comm=[{"kind": "ring", "label": "s", "input": 0},
                   {"kind": "a2a", "label": "b", "input": 0}])
    with pytest.raises(ValueError, match="conflicting"):
        eindecomp(g, 2)


def test_bad_comm_kind_rejected():
    g = EinGraph()
    x = g.input("x", "b s a", (2, 4, 8))
    g.opaque("mystery", [x], "b s a", (2, 4, 8),
             in_labels=[("b", "s", "a")],
             comm=[{"kind": "broadcast", "label": "s", "input": 0,
                    "rule": "replicate"}])
    with pytest.raises(ValueError, match="broadcast"):
        eindecomp(g, 2)


def test_plan_repart_slices_before_all_to_all():
    """Replicated-prefix slices now run before the a2a pass: landing
    (data, model) on one dim when model arrives from another dim is
    slice + all_to_all, not gather + slice + slice."""
    steps = spmd.plan_repart(
        (("model",), (), ()), ((), ("data", "model"), ()))
    assert steps == [("slice", "data", 1), ("all_to_all", "model", 0, 1)]


# ---------------------------------------------------------------------------
# grouped reduce-scatter (satellite): one collective for two scattered axes
# ---------------------------------------------------------------------------


def _grouped_rs_graph():
    g = EinGraph("grouped")
    x = g.input("x", "b f g", (8, 8, 8))
    w = g.input("w", "f g c", (8, 8, 8))
    z = g.einsum("b f g, f g c -> b c", x, w)
    out = g.einsum("b c -> b c", z, combine="id", agg="")
    plan = Plan(p=8, mode="mesh")
    plan.d_by_node = {0: {"b": 1, "f": 2, "g": 4},
                      1: {"f": 2, "g": 4, "c": 1},
                      2: {"b": 1, "f": 2, "g": 4, "c": 1},
                      3: {"b": 2, "c": 4}}
    plan.axes_by_node = {0: {"f": ("data",), "g": ("model",)},
                         1: {"f": ("data",), "g": ("model",)},
                         2: {"f": ("data",), "g": ("model",)},
                         3: {"b": ("data",), "c": ("model",)}}
    return g, out, plan


def test_grouped_psum_scatter_schedule():
    """Two contracted axes scattering to distinct output dims fuse into ONE
    reduce-scatter event (regression-pinned count) at the same wire bytes
    as the sequential pair."""
    g, out, plan = _grouped_rs_graph()
    sched = spmd.build_schedule(g, plan, {"data": 2, "model": 4}, [out])
    assert sched.trace.counts == {"psum_scatter": 1}, sched.trace.counts
    prog = {p.nid: p for p in sched.programs}[2]
    assert prog.post_steps == [
        ("psum_scatter_grouped", (("data", 0), ("model", 1)))]
    assert sched.layouts[2] == (("data",), ("model",))
    # wire identical to the sequential pair: n*(k1k2-1)/(k1k2) summed
    n_loc = 8 * 8
    n_dev = 8
    assert sched.trace.total_elems == n_dev * (8 - 1) * n_loc // 8


def test_grouped_psum_scatter_executes_correctly():
    g, out, plan = _grouped_rs_graph()
    mesh = make_host_mesh((2, 4))
    fn = jax.jit(engine.make_runner(g, [out], plan=plan, mesh=mesh,
                                    executor="shard_map"))
    feeds = {n.nid: (RNG.normal(size=n.shape) * 0.3).astype(np.float32)
             for n in g.nodes if n.kind == "input"}
    got = np.asarray(fn(*[feeds[i] for i in g.input_ids()]))
    np.testing.assert_allclose(got, eval_graph_dense(g, feeds)[out],
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# 3. execution equivalence (adaptive to the host's device count)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("axes_cfg", [
    {"s": ("model",), "b": ("data",)},
    {"s": ("data", "model")},
    {"b": ("data",), "h": ("model",)},
], ids=["ring-model", "ring-all", "local-heads"])
def test_ring_execution_matches_dense(window, axes_cfg):
    # the local-heads case co-shards q and kv heads 4-way: MHA shapes
    g, o = _attn_graph(window=window,
                       kv_heads=H if "h" in axes_cfg else K)
    mesh = make_host_mesh((2, 4))
    sizes = engine.mesh_axes_dict(mesh)
    plan = _uniform_plan(g, axes_cfg, p=math.prod(sizes.values()))
    tr = spmd.CollectiveTrace()
    fn = jax.jit(engine.make_runner(g, [o], plan=plan, mesh=mesh,
                                    executor="shard_map",
                                    collective_trace=tr))
    feeds = {n.nid: (RNG.normal(size=n.shape) * 0.3).astype(np.float32)
             for n in g.nodes if n.kind == "input"}
    got = np.asarray(fn(*[feeds[i] for i in g.input_ids()]))
    want = eval_graph_dense(g, feeds)[o]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    if N_DEV >= 8 and "s" in axes_cfg:
        assert tr.counts.get("ppermute", 0) > 0  # a real ring ran
        assert tr.counts.get("all_gather", 0) == 0  # and no kv gather


@pytest.mark.parametrize("axes_cfg", [
    {"e": ("data", "model")},
    {"e": ("model",)},
], ids=["e-all", "e-model"])
def test_a2a_moe_with_drops_matches_dense(monkeypatch, axes_cfg):
    """Real capacity drops (64 tokens, 32 slots): the sharded a2a program
    must agree with the dense stub bit-for-bit on routing decisions."""
    g, disp, comb = _moe_graph()
    for kind, fn in make_stub_opaques(CAP).items():
        monkeypatch.setitem(engine.OPAQUE_FNS, kind, fn)
    mesh = make_host_mesh((2, 4))
    sizes = engine.mesh_axes_dict(mesh)
    plan = _uniform_plan(g, axes_cfg, p=math.prod(sizes.values()))
    tr = spmd.CollectiveTrace()
    fn = jax.jit(engine.make_runner(g, [comb], plan=plan, mesh=mesh,
                                    executor="shard_map",
                                    collective_trace=tr))
    feeds = {0: (RNG.normal(size=(B, S, D)) * 0.3).astype(np.float32),
             1: (RNG.normal(size=(B, S, E)) * 2.0).astype(np.float32)}
    got = np.asarray(fn(feeds[0], feeds[1]))
    want = eval_graph_dense(g, feeds)[comb]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    if N_DEV >= 8:
        assert tr.counts.get("all_to_all", 0) >= 2
        # the payload crosses the all_to_all; gathers on the a2a path are
        # metadata/route-sized, never the dominant token-buffer movement
        by_rule = tr.by_rule().get("a2a", {})
        assert by_rule.get("all_gather", {"bytes": 0})["bytes"] < \
            by_rule["all_to_all"]["bytes"]


def test_decode_ring_over_cache_time():
    """Decode-shaped attention: q has a singleton sequence, the ring rides
    the kv-cache time label t."""
    g = EinGraph("decode")
    q = g.input("q", "b h s d", (B, H, 1, D))
    k = g.input("k", "b k t d", (B, K, S, D))
    v = g.input("v", "b k t d", (B, K, S, D))
    o = g.opaque(
        "flash_attention", [q, k, v], "b h s d", (B, H, 1, D),
        in_labels=[("b", "h", "s", "d"), ("b", "k", "t", "d"),
                   ("b", "k", "t", "d")],
        shardable={"b", "h", "k", "t"},
        comm=[{"kind": "ring", "label": "t", "input": 1, "rule": "ring"},
              {"kind": "ring", "label": "t", "input": 2, "rule": "ring"}],
        causal=False)
    mesh = make_host_mesh((2, 4))
    sizes = engine.mesh_axes_dict(mesh)
    plan = _uniform_plan(g, {"t": ("model",), "b": ("data",)},
                         p=math.prod(sizes.values()))
    fn = jax.jit(engine.make_runner(g, [o], plan=plan, mesh=mesh,
                                    executor="shard_map"))
    feeds = {n.nid: (RNG.normal(size=n.shape) * 0.3).astype(np.float32)
             for n in g.nodes if n.kind == "input"}
    got = np.asarray(fn(*[feeds[i] for i in g.input_ids()]))
    np.testing.assert_allclose(got, eval_graph_dense(g, feeds)[o],
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# 4. cost accounting: zoo-wide per-node bound (device-free)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama-7b", "mixtral-8x7b", "xlstm-125m",
                                  "hymba-1.5b"])
def test_zoo_ruled_opaques_within_node_bound(arch):
    """For every DP-planned zoo cell, each ring/a2a-ruled opaque node's
    traced wire elems stay within its slice of the §7 objective
    (opaque_node_bound) — no full K/V or token-buffer gathers — and the
    whole program stays within plan_cost."""
    cfg = reduced(get_config(arch))
    shape = ShapeConfig("eq", "prefill", 32, 4)
    g = program_for(cfg, shape).graph
    axes = {"data": 2, "model": 4}
    plan = eindecomp(g, 8, mesh_axes=axes, offpath_repart=True)
    sched = spmd.build_schedule(g, plan, axes)
    tr = sched.trace
    assert tr.total_elems <= plan_cost(g, plan)
    ruled = 0
    for n in g.nodes:
        if n.kind != "opaque":
            continue
        if tr.rule_by_node.get(n.nid) in ("ring", "a2a"):
            ruled += 1
            traced = tr.elems_by_node.get(n.nid, 0)
            bound = opaque_node_bound(g, plan, n.nid)
            assert traced <= bound, (n.name, traced, bound)
    if arch != "xlstm-125m":  # xlstm has no attention/moe opaques
        assert ruled >= 1


def test_zoo_equivalence_ring_and_a2a_active(monkeypatch):
    """mixtral through the Program surface: shard_map (ring + a2a rules
    active) vs gspmd vs nothing gathered beyond the declared schedules."""
    cfg = reduced(get_config("mixtral-8x7b"))
    shape = ShapeConfig("eq", "prefill", 32, 4)
    prog = program_for(cfg, shape)
    g = prog.graph
    for kind, fn in make_stub_opaques(capacity_of(g)).items():
        monkeypatch.setitem(engine.OPAQUE_FNS, kind, fn)
    mesh = make_host_mesh((2, 4))
    feeds = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if str(np.dtype(n.dtype)) == "int32":
            feeds[n.name] = RNG.integers(0, cfg.vocab,
                                         size=n.shape).astype(np.int32)
        else:
            feeds[n.name] = (RNG.normal(size=n.shape) * 0.05).astype(
                np.float32)
    run_g = prog.compile(mesh=mesh)
    run_s = prog.compile(mesh=mesh, executor="shard_map")
    np.testing.assert_allclose(
        np.asarray(run_s(feeds)["logits"]),
        np.asarray(run_g(feeds)["logits"]), rtol=2e-4, atol=2e-4)
    by_rule = run_s.collectives_by_rule
    assert by_rule is not None
    if N_DEV >= 8:
        assert "a2a" in by_rule, by_rule  # expert parallelism realized
        rules = set(run_s.collectives.rule_by_node.values())
        assert "ring" in rules


# ---------------------------------------------------------------------------
# calibrated cost model (satellite)
# ---------------------------------------------------------------------------


def test_costmodel_with_measured_scales_prices(tmp_path):
    import json

    from repro.core.cost import CostModel

    measured = {"kinds": {"all_gather": {"ns_per_elem": 2.0},
                          "all_to_all": {"ns_per_elem": 4.0},
                          "psum_scatter": {"ns_per_elem": 6.0}}}
    path = tmp_path / "costs.json"
    path.write_text(json.dumps(measured))
    cm = CostModel.with_measured(path)
    assert cm.mode == "collective"
    assert cm.coeffs == {"all_gather": 1.0, "all_to_all": 2.0,
                         "psum_scatter": 3.0}
    base = CostModel("collective")
    # a pure gather reprices identically (coeff 1.0)...
    assert cm.repart((4, 1), (1, 1), (16, 8)) == \
        base.repart((4, 1), (1, 1), (16, 8))
    # ...a pure scatter doubles (coeff 2.0)
    assert cm.repart((1, 1), (4, 1), (16, 8)) == \
        2 * base.repart((1, 1), (4, 1), (16, 8))


def test_costmodel_instance_flows_through_compile():
    """Program.compile accepts a calibrated CostModel and the plan cache
    keys on its coefficients (calibrated != formula plans)."""
    from repro import frontend as ein
    from repro.core.cost import CostModel
    from repro.core.plancache import PlanCache

    x = ein.tensor("x", "b a", (8, 16))
    w = ein.tensor("w", "a f", (16, 32))
    prog = ein.Program({"y": ein.einsum("b a, a f -> b f", x, w)})
    cache = PlanCache()
    cm = CostModel.with_measured(
        {"kinds": {"all_gather": {"ns_per_elem": 1.0},
                   "all_to_all": {"ns_per_elem": 9.0}}})
    run1 = prog.compile(p=4, cost_model=cm, cache=cache)
    assert run1.plan is not None
    misses = cache.misses
    run2 = prog.compile(p=4, cost_model="collective", cache=cache)
    assert cache.misses == misses + 1  # different key: no false hit
    assert run2.plan is not None
