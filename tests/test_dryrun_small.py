"""Dry-run machinery at host scale: abstract build (no allocation), plan
determinism across processes, HLO collective parsing."""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.launch.hlo_analysis import parse_collectives


def test_plan_deterministic_across_processes():
    """Tie-optimal plans must not depend on PYTHONHASHSEED (set-order bug
    regression test)."""
    snippet = (
        "from repro.configs import get_config, SHAPES\n"
        "from repro.models.eingraphs import plan_for\n"
        "cfg = get_config('musicgen-large')\n"
        "g, plan, pol = plan_for(cfg, SHAPES['decode_32k'],"
        " {'data':16,'model':16})\n"
        "print(sorted(pol.label_axes.items()))\n")
    outs = set()
    for seed in ("0", "1", "2"):
        proc = subprocess.run(
            [sys.executable, "-c", snippet], capture_output=True, text=True,
            env={"PYTHONPATH": "src", "PYTHONHASHSEED": seed, "PATH": "/usr/bin:/bin"},
            timeout=240)
        assert proc.returncode == 0, proc.stderr[-800:]
        outs.add(proc.stdout.strip())
    assert len(outs) == 1, outs


def test_abstract_caches_do_not_allocate():
    """init_caches(abstract=True) must stay ShapeDtypeStructs end-to-end
    (the 77GB decode-cache OOM regression)."""
    from repro.models import transformer as tf

    cfg = get_config("paligemma-3b")
    caches = tf.init_caches(cfg, 128, 32768, abstract=True)
    for leaf in jax.tree.leaves(caches):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_collective_parser_wire_costs():
    hlo = """
HloModule test

ENTRY %main (p: f32[128,64]) -> f32[128,64] {
  %p = f32[128,64]{1,0} parameter(0)
  %ar = f32[128,64]{1,0} all-reduce(%p), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = f32[256,64]{1,0} all-gather(%ar), replica_groups=[64,4]<=[256], dimensions={0}
  ROOT %cp = f32[128,64]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    wire, by_kind, plain = parse_collectives(hlo, 256)
    ar = 128 * 64 * 4
    ag = 256 * 64 * 4
    cp = 128 * 64 * 4
    assert by_kind["all-reduce"] == pytest.approx(2 * 15 / 16 * ar)
    assert by_kind["all-gather"] == pytest.approx(3 / 4 * ag)
    assert by_kind["collective-permute"] == pytest.approx(cp)
    assert plain == ar + ag + cp


def test_collective_parser_while_trip_count():
    hlo = """
HloModule test

%cond (s: (s32[], f32[8])) -> pred[] {
  %s = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%s), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (s: (s32[], f32[8])) -> (s32[], f32[8]) {
  %s = (s32[], f32[8]) parameter(0)
  %x = f32[8]{0} get-tuple-element(%s), index=1
  %ar = f32[8]{0} all-reduce(%x), replica_groups=[1,4]<=[4], to_apply=%add
  %i = s32[] get-tuple-element(%s), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8]) tuple(%z, %p)
  %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8]{0} get-tuple-element(%w), index=1
}
"""
    wire, by_kind, plain = parse_collectives(hlo, 4)
    one = 2 * 3 / 4 * 8 * 4
    assert by_kind["all-reduce"] == pytest.approx(12 * one)


def test_build_cell_shapes_decode():
    """build_cell produces sharded ShapeDtypeStructs for a decode cell on a
    small forced-device mesh (smoke for the dry-run path)."""
    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import make_host_mesh

    cfg = get_config("xlstm-125m")
    shape = SHAPES["decode_32k"]
    mesh = make_host_mesh((1, 1))
    step, args, donate, plan, policy = build_cell(cfg, shape, mesh)
    for leaf in jax.tree.leaves(args):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    # optimizer-free decode: donate caches only
    assert donate == (2,)


def test_train_cell_optimizer_shardings_attached():
    """AdamW m/v ShapeDtypeStructs must carry the param shardings (the
    replicated-optimizer 374GB regression)."""
    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import make_host_mesh

    cfg = get_config("xlstm-125m")
    mesh = make_host_mesh((1, 1))
    step, (params, opt, batch), donate, plan, policy = build_cell(
        cfg, SHAPES["train_4k"], mesh)
    for leaf in jax.tree.leaves(opt.m):
        assert leaf.sharding is not None
