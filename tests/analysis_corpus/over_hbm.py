"""Plan whose per-device peak footprint exceeds the HBM bound (RA301).

A perfectly valid graph/plan/schedule — the only problem is physical:
with ``--max-hbm 64`` the per-device live set cannot fit.  RA302 also
fires (single buffers alone exceed the bound); the memory pass must at
minimum report the peak violation.
"""
from repro.analysis import analyze
from repro.core.decomp import eindecomp
from repro.core.einsum import EinGraph

EXPECT = "RA301"


def report():
    g = EinGraph("over_hbm")
    a = g.input("a", "ij", (8, 8))
    b = g.input("b", "jk", (8, 8))
    g.einsum("ij, jk -> ik", a, b, name="mm")
    plan = eindecomp(g, 2, mesh_axes={"data": 2})
    return analyze(g, plan, {"data": 2}, max_hbm=64)
