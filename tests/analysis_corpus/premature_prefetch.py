"""Lookahead prefetch issued before its producer has computed (RA208).

The graph-wide overlap pass may hoist a consumer's repartition chain to
an earlier node's iteration — but never to or before the chain's own
producer: the hoisted ``_run_steps`` would read a value that does not
exist yet.  Built by hand (``_hoist_prefetches`` clamps every issue point
at per-arg readiness, so ``build_schedule`` cannot emit this).
"""
from repro.analysis import analyze_schedule_only
from repro.core.einsum import EinGraph
from repro.core.spmd import (CollectiveTrace, NodeProgram, Prefetch,
                             Schedule)

EXPECT = "RA208"


def report():
    g = EinGraph("premature_prefetch")
    x = g.input("x", "a", (8,))
    h = g.map("relu", x, name="h")
    y = g.einsum("a -> a", h)
    trace = CollectiveTrace()
    trace.add("all_gather", ("model",), y, 1, 16, overlap=True,
              prefetch_for=y)
    # the issue point equals the producer's topo position: the chain runs
    # at the top of h's iteration, before h's compute has produced vals[h]
    sched = Schedule(
        programs=[NodeProgram(h, arg_steps=[[]], layout=((),)),
                  NodeProgram(y, arg_steps=[[("all_gather", "model", 0)]],
                              layout=((),))],
        layouts={x: ((),), h: ((),), y: ((),)},
        trace=trace,
        sizes={"model": 2},
        lookahead=1,
        prefetches=[Prefetch(consumer=y, arg=0, issue=h, elems=16)],
    )
    return analyze_schedule_only(g, sched)
