"""Plan whose recorded cost no longer matches plan_cost(g, plan) (RA107).

A plan edited (or deserialized from a stale cache entry) after pricing
silently breaks the cost-honesty contract the benches assert — the DP's
argmin claim is about the *recorded* cost.  The plan pass reprices and
compares.
"""
import dataclasses

from repro.analysis import analyze
from repro.core.decomp import eindecomp
from repro.core.einsum import EinGraph

EXPECT = "RA107"


def report():
    g = EinGraph("stale_cost")
    a = g.input("a", "ij", (8, 8))
    b = g.input("b", "jk", (8, 8))
    g.einsum("ij, jk -> ik", a, b, name="mm")
    plan = eindecomp(g, 2, mesh_axes={"data": 2})
    stale = dataclasses.replace(plan, cost=plan.cost + 12345)
    return analyze(g, stale)  # plan pass only — no mesh, no schedule
