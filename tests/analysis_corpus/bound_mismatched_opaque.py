"""Opaque node whose declared out_shape contradicts its OpDef (RA004).

``EinGraph.opaque`` is a raw constructor — it records whatever shape the
caller claims without consulting the registry (only the ein.* frontend
binds through ``opdef.bind_call``).  Here ``mlstm_scan`` (signature
``'b s f -> b s f'``) is given an output bound f=32 while its input has
f=16; the graph pass re-binds the signature and must flag the lie.
"""
from repro.analysis import analyze
from repro.core.einsum import EinGraph

EXPECT = "RA004"


def report():
    g = EinGraph("bound_mismatched_opaque")
    x = g.input("x", "bsf", (4, 8, 16))
    g.opaque("mlstm_scan", [x], "bsf", (4, 8, 32),
             in_labels=[("b", "s", "f")], name="scan")
    return analyze(g)
