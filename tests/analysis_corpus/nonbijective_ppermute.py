"""Hand-built schedule whose ppermute is not a bijection (RA201).

Two source devices target device 1 (shards collide: data loss) and no
one targets device 2 — the executor would deadlock waiting for a send
that never comes.  Built directly as a Schedule because build_schedule
can never emit this; the pass guards *deserialized or hand-edited*
schedules.
"""
from repro.analysis import analyze_schedule_only
from repro.core.einsum import EinGraph
from repro.core.spmd import CollectiveTrace, NodeProgram, Schedule

EXPECT = "RA201"


def report():
    g = EinGraph("nonbijective_ppermute")
    x = g.input("x", "a", (8,))
    y = g.map("relu", x, name="y")
    trace = CollectiveTrace()
    # 4-device group, but dsts = (1, 1, 3, 0): device 1 receives twice,
    # device 2 never receives
    trace.add("ppermute", ("model",), y, 16, 64, rule="ring",
              perm=((0, 1), (1, 1), (2, 3), (3, 0)))
    sched = Schedule(
        programs=[NodeProgram(y, arg_steps=[[]], layout=((),))],
        layouts={x: ((),), y: ((),)},
        trace=trace,
        sizes={"model": 4},
    )
    return analyze_schedule_only(g, sched)
