"""Stage-graph back-edge: a stage receives a later stage's tensor (RA401).

The partitioner cuts the topo sequence contiguously, so its chains are
dependency-closed by construction — this fixture swaps the two stages of
a two-node chain by hand (stage 0 holds the *consumer*, stage 1 the
producer), the cut a buggy partitioner reordering nodes would emit.  The
handoff would have to flow backwards over the pp ring: a cycle.
"""
from repro.analysis.findings import Report
from repro.analysis.pipeline_pass import analyze_pipeline_schedule
from repro.core.decomp import Plan
from repro.core.einsum import EinGraph
from repro.core.spmd import CollectiveTrace
from repro.pipeline.partition import PipelineSpec, _extract_stage
from repro.pipeline.schedule import PipelineSchedule

EXPECT = "RA401"


def report():
    g = EinGraph("stage_cycle")
    x = g.input("x", "a", (8,))
    a = g.map("relu", x, name="a")
    b = g.map("relu", a, name="b")
    # stage 0 = {b} (consumer), stage 1 = {a} (producer): b's handoff stub
    # receives a, which stage 1 — a LATER stage — produces
    stages = [_extract_stage(g, 0, [b]), _extract_stage(g, 1, [a])]
    psched = PipelineSchedule(
        spec=PipelineSpec(stages=2), stages=stages,
        stitched=Plan(p=1, mode="mesh"), cells=[(0, 0), (1, 0)],
        boundaries=[[]], trace=CollectiveTrace(), sizes={"pp": 2},
        out_ids=[b])
    r = Report(meta={"fixture": "stage_cycle"})
    r.extend(analyze_pipeline_schedule(g, psched))
    return r
