"""Ring schedule with more overlapped hops than the ring has steps (RA204).

On an r-device ring each circulating tensor needs exactly r-1 rotations;
a third hop on a 2-device ring hands every device data it already saw —
wasted wire and a latent off-by-one in the double-buffer loop.  Built by
hand (build_schedule always emits exactly r-1 per tensor).
"""
from repro.analysis import analyze_schedule_only
from repro.core.einsum import EinGraph
from repro.core.spmd import CollectiveTrace, NodeProgram, Schedule

EXPECT = "RA204"


def report():
    g = EinGraph("over_rotated_ring")
    x = g.input("x", "a", (8,))
    y = g.map("relu", x, name="y")
    trace = CollectiveTrace()
    perm = ((0, 1), (1, 0))  # valid 2-device rotation — bijective
    for _hop in range(3):  # limit on a 2-device ring is 1 per tensor
        trace.add("ppermute", ("model",), y, 4, 16, rule="ring",
                  overlap=True, perm=perm)
    trace.rule_by_node[y] = "ring"
    sched = Schedule(
        programs=[NodeProgram(y, arg_steps=[[]], layout=((),))],
        layouts={x: ((),), y: ((),)},
        trace=trace,
        sizes={"model": 2},
    )
    return analyze_schedule_only(g, sched)
