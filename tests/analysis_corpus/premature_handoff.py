"""Handoff issued before its producer cell completes (RA402).

``build_pipeline_schedule`` appends each boundary's ppermute events after
the producing cell's intra-stage events, so the executor ships values
that exist.  This fixture hand-orders the combined trace the other way:
cell (0, 0)'s handoff fires, THEN the cell issues a psum — the ppermute
would ship a partial sum the stage has not reduced yet.
"""
from repro.analysis.findings import Report
from repro.analysis.pipeline_pass import analyze_pipeline_schedule
from repro.core.decomp import Plan
from repro.core.einsum import EinGraph
from repro.core.spmd import CollectiveTrace
from repro.pipeline.partition import PipelineSpec, _extract_stage
from repro.pipeline.schedule import PipelineSchedule

EXPECT = "RA402"


def report():
    g = EinGraph("premature_handoff")
    x = g.input("x", "a", (8,))
    a = g.map("relu", x, name="a")
    b = g.map("relu", a, name="b")
    stages = [_extract_stage(g, 0, [a]), _extract_stage(g, 1, [b])]
    trace = CollectiveTrace()
    trace.add("ppermute", ("pp",), a, 16, 64, rule="handoff",
              perm=((0, 1), (1, 0)), stage=0, microbatch=0)
    trace.add("psum", ("data",), a, 16, 64, stage=0, microbatch=0)
    psched = PipelineSchedule(
        spec=PipelineSpec(stages=2), stages=stages,
        stitched=Plan(p=1, mode="mesh"), cells=[(0, 0), (1, 0)],
        boundaries=[[a]], trace=trace, sizes={"pp": 2, "data": 2},
        out_ids=[b])
    r = Report(meta={"fixture": "premature_handoff"})
    r.extend(analyze_pipeline_schedule(g, psched))
    return r
