"""Opaque node whose kind exists in no OpDef registry (RA005).

The graph would fail at execution time with a KeyError deep inside the
engine; the graph pass surfaces it at analysis time with the node's
source location instead.
"""
from repro.analysis import analyze
from repro.core.einsum import EinGraph

EXPECT = "RA005"


def report():
    g = EinGraph("unregistered_kind")
    x = g.input("x", "a", (8,))
    g.opaque("totally_unknown_op", [x], "a", (8,),
             in_labels=[("a",)], name="mystery")
    return analyze(g)
