"""Donated input read again after its aliasing step (RA202).

``x`` is donated to the jit runner, but its buffer feeds both ``h``
(the aliasing step — after it runs, the donation may have been
overwritten in place) and the later einsum, which would then read
garbage.  The schedule pass must refuse the donation cycle.
"""
from repro.analysis import analyze
from repro.core.decomp import eindecomp
from repro.core.einsum import EinGraph

EXPECT = "RA202"


def report():
    g = EinGraph("cyclic_donation")
    x = g.input("x", "a", (8,))
    h = g.map("relu", x, name="h")
    g.einsum("a, a -> a", x, h, name="out")
    plan = eindecomp(g, 2, mesh_axes={"data": 2})
    return analyze(g, plan, {"data": 2}, donate=("x",))
