"""Known-bad corpus for repro.analysis (ISSUE 8, satellite 1).

Each module builds ONE deliberately broken artifact — a graph, a plan, or
a hand-constructed Schedule — and exposes:

    EXPECT : str       the RA code the analyzer must raise (as an error)
    report() -> Report the analysis run over the fixture

The twin property (tests/test_analysis.py) is that the *entire model zoo*
analyzes clean while every fixture here trips its code: the corpus pins
the analyzer's sensitivity, the zoo pins its specificity.
"""
from tests.analysis_corpus import (bound_mismatched_opaque, cyclic_donation,
                                   nonbijective_ppermute, over_hbm,
                                   over_rotated_ring, premature_handoff,
                                   premature_prefetch, stage_cycle,
                                   stale_cost, unregistered_kind)

#: name -> fixture module; tests iterate this registry
FIXTURES = {
    "cyclic_donation": cyclic_donation,
    "nonbijective_ppermute": nonbijective_ppermute,
    "bound_mismatched_opaque": bound_mismatched_opaque,
    "over_hbm": over_hbm,
    "over_rotated_ring": over_rotated_ring,
    "premature_handoff": premature_handoff,
    "premature_prefetch": premature_prefetch,
    "stage_cycle": stage_cycle,
    "stale_cost": stale_cost,
    "unregistered_kind": unregistered_kind,
}
