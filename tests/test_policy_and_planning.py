"""ShardingPolicy construction, plan->policy projection, per-arch planning,
and small-mesh end-to-end sharded execution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, reduced
from repro.launch.mesh import make_host_mesh, mesh_axes_dict
from repro.models import transformer as tf
from repro.models.eingraphs import build_graph, plan_for
from repro.models.policy import ShardingPolicy, manual_policy, safe_spec


def test_safe_spec_drops_indivisible():
    mesh = make_host_mesh((1, 1))  # axes data=1, model=1 — trivially divides
    sp = safe_spec(P("data", "model"), (3, 5), mesh)
    assert sp == P("data", "model")


def test_act_spec_dedupes_axes():
    pol = manual_policy({"b": "data", "f": "data"})
    # both want 'data'; second occurrence must drop it
    assert pol.act_spec("b s f") == P("data", None, None)


def test_param_spec_fsdp_prefers_feature_dims():
    # fsdp must land on a non-contraction dim (h free -> h; h taken -> d)
    pol = ShardingPolicy(label_axes={}, fsdp_axes=("data",))
    assert pol.param_spec("L a h d") == P(None, None, "data", None)
    pol2 = ShardingPolicy(label_axes={"h": ("model",)}, fsdp_axes=("data",))
    assert pol2.param_spec("L a h d") == P(None, None, "model", "data")
    # only 'a' available -> falls back to 'a'
    assert pol.param_spec("L a") == P(None, "data")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_planning_all_archs_all_shapes(arch):
    """EinDecomp must produce a plan for every supported cell (256 chips)."""
    cfg = get_config(arch)
    for shape in SHAPES.values():
        if not cfg.supports(shape):
            continue
        g, plan, policy = plan_for(cfg, shape, {"data": 16, "model": 16})
        assert plan.cost >= 0
        # every non-input node got a partitioning
        for n in g.nodes:
            assert n.nid in plan.d_by_node, (arch, shape.name, n.name)
        # policy only references mesh axes
        for axes in policy.label_axes.values():
            assert set(axes) <= {"data", "model"}


def test_planning_multi_pod():
    cfg = get_config("mixtral-8x7b")
    g, plan, policy = plan_for(cfg, SHAPES["train_4k"],
                               {"pod": 2, "data": 16, "model": 16})
    for axes in policy.label_axes.values():
        assert set(axes) <= {"pod", "data", "model"}
    used = {a for axes in policy.label_axes.values() for a in axes}
    assert "pod" in used  # 512-way work exists


def test_sharded_training_step_runs_small_mesh():
    """End-to-end: EinDecomp policy -> shardings -> jit train step on the
    host mesh (1 device here, but exercises the whole sharding path)."""
    from repro.launch import steps
    from repro.optim import adamw_init

    cfg = reduced(get_config("yi-9b"))
    mesh = make_host_mesh((1, 1))
    _, plan, policy = plan_for(cfg, SHAPES["train_4k"],
                               mesh_axes_dict(mesh), fsdp=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    params = jax.device_put(params, tf.param_shardings(cfg, policy, mesh))
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    step = jax.jit(steps.make_train_step(cfg, policy=policy, mesh=mesh),
                   donate_argnums=(0, 1))
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_decode_graph_has_cache_inputs():
    cfg = get_config("yi-9b")
    g = build_graph(cfg, SHAPES["decode_32k"])
    names = [n.name for n in g.nodes]
    assert "k_cache" in names and "v_cache" in names


def test_plan_decomposes_expert_ffn_fully():
    """MoE: the expert FFN matmuls must be decomposed into exactly p pieces
    (expert / capacity / hidden sharding are all legitimate — mixtral's 8
    experts cannot take a 16-way axis, so the DP picks c/f instead)."""
    for arch in ("mixtral-8x7b", "qwen2-moe-a2.7b"):
        cfg = get_config(arch)
        g, plan, policy = plan_for(cfg, SHAPES["prefill_32k"],
                                   {"data": 16, "model": 16})
        for n in g.nodes:
            if n.name == "expert_up":
                d = plan.d_by_node[n.nid]
                work = 1
                for v in d.values():
                    work *= v
                assert work == 256, (arch, d)
