"""Launch-layer regression tests: the serve decode-loop off-by-one and the
dry-run XLA_FLAGS clobbering fix."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.launch.serve import decode_loop

# ---------------------------------------------------------------------------
# serve: decode loop
# ---------------------------------------------------------------------------


class _FakeDecode:
    """Deterministic decode stub: step i's argmax is (prev_token + 1); logits
    for a step are *only* correct if that step's call actually happened."""

    def __init__(self, vocab: int = 17):
        self.vocab = vocab
        self.calls = 0
        self.positions = []

    def __call__(self, params, tok, caches, pos):
        self.calls += 1
        self.positions.append(int(pos))
        nxt = (np.asarray(tok)[:, 0] + 1) % self.vocab
        logits = np.full((tok.shape[0], 1, self.vocab), -1e9, np.float32)
        logits[np.arange(tok.shape[0]), 0, nxt] = 0.0
        return jnp.asarray(logits), caches + 1


def test_decode_loop_runs_exactly_max_new_minus_one_steps():
    """max_new tokens out, max_new-1 decode calls — the final step's logits
    are consumed, not computed-and-discarded (the off-by-one regression)."""
    decode = _FakeDecode()
    first = jnp.asarray([[3], [10]], jnp.int32)
    gen, caches, steps = decode_loop(decode, None, 0, first,
                                     prompt_len=5, max_new=4)
    assert gen.shape == (2, 4)
    assert steps == decode.calls == 3          # not 4: no wasted step
    assert caches == 3                          # cache advanced per real step
    # greedy chain: every emitted token after the first came from a decode
    np.testing.assert_array_equal(gen[0], [3, 4, 5, 6])
    np.testing.assert_array_equal(gen[1], [10, 11, 12, 13])
    # positions advance from prompt_len
    assert decode.positions == [5, 6, 7]


def test_decode_loop_single_token_needs_no_decode():
    decode = _FakeDecode()
    gen, _, steps = decode_loop(decode, None, 0,
                                jnp.asarray([[2]], jnp.int32), 3, 1)
    assert gen.shape == (1, 1) and steps == 0 and decode.calls == 0
    np.testing.assert_array_equal(gen[0], [2])


def test_decode_loop_zero_tokens():
    decode = _FakeDecode()
    gen, _, steps = decode_loop(decode, None, 0,
                                jnp.asarray([[2]], jnp.int32), 3, 0)
    assert gen.shape == (1, 0) and steps == 0 and decode.calls == 0


# ---------------------------------------------------------------------------
# dryrun: XLA_FLAGS handling
# ---------------------------------------------------------------------------


def _run_snippet(body: str, env_extra: dict) -> str:
    # inherit the ambient env (JAX_PLATFORMS etc. — backend probing can hang
    # without it) but take explicit control of XLA_FLAGS, the var under test
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    env.update(env_extra)
    proc = subprocess.run([sys.executable, "-c", body], capture_output=True,
                          text=True, env=env, timeout=240)
    assert proc.returncode == 0, proc.stderr[-1000:]
    return proc.stdout.strip()


def test_dryrun_appends_instead_of_clobbering_user_flags():
    out = _run_snippet(
        "import os\n"
        "from repro.launch.dryrun import _force_host_devices\n"
        "_force_host_devices()\n"
        "print(os.environ['XLA_FLAGS'])\n",
        {"XLA_FLAGS": "--xla_cpu_enable_fast_math=false"})
    assert "--xla_cpu_enable_fast_math=false" in out
    assert "--xla_force_host_platform_device_count=512" in out


def test_dryrun_respects_existing_device_count_flag():
    out = _run_snippet(
        "import os\n"
        "from repro.launch.dryrun import _force_host_devices\n"
        "_force_host_devices()\n"
        "print(os.environ['XLA_FLAGS'])\n",
        {"XLA_FLAGS": "--xla_force_host_platform_device_count=4"})
    assert out == "--xla_force_host_platform_device_count=4"


def test_dryrun_leaves_env_alone_after_jax_initialized():
    out = _run_snippet(
        "import os, jax\n"
        "jax.devices()\n"  # initialize backends: too late for the flag
        "from repro.launch.dryrun import _force_host_devices\n"
        "_force_host_devices()\n"
        "print(os.environ.get('XLA_FLAGS', '<unset>'))\n",
        {})
    assert out == "<unset>"
