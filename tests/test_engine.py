"""Engine (jnp execution) and graph autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.autodiff import grad_graph
from repro.core.einsum import EinGraph, eval_graph_dense

RNG = np.random.default_rng(0)


def softmax_graph():
    """The paper's §3 softmax EinGraph (4 nodes)."""
    g = EinGraph("softmax")
    x = g.input("X", "ij", (8, 16))
    c = g.einsum("ij->i", x, combine="id", agg="max")
    e = g.einsum("ij,i->ij", x, c, combine="expsub", agg="")
    s = g.einsum("ij->i", e, combine="id", agg="sum")
    y = g.einsum("ij,i->ij", e, s, combine="div", agg="")
    return g, x, y


def test_softmax_as_einsum_graph():
    g, x, y = softmax_graph()
    X = RNG.normal(size=(8, 16)).astype(np.float32)
    vals = engine.run(g, {x: X})
    want = jax.nn.softmax(X, axis=-1)
    np.testing.assert_allclose(vals[y], want, rtol=1e-5, atol=1e-6)
    # dense numpy oracle agrees too
    dense = eval_graph_dense(g, {x: X})
    np.testing.assert_allclose(dense[y], want, rtol=1e-5, atol=1e-6)


def test_multihead_attention_graph_matches_reference():
    """§3 multi-headed attention as an EinGraph vs jnp reference."""
    b, s, a, h, d = 1, 8, 16, 2, 8
    g = EinGraph("mha")
    # single-batch (paper's formulation has no batch label)
    Q = g.input("Q", "s a", (s, a))
    WQ = g.input("WQ", "a h d", (a, h, d))
    WK = g.input("WK", "a h d", (a, h, d))
    WV = g.input("WV", "a h d", (a, h, d))
    WO = g.input("WO", "a h d", (a, h, d))
    qh = g.einsum("s a, a h d -> s h d", Q, WQ)
    kh = g.einsum("s a, a h d -> s h d", Q, WK)
    vh = g.einsum("s a, a h d -> s h d", Q, WV)
    t1 = g.einsum("s h d, z h d -> h s z", qh, kh)  # s' spelled z
    t2 = g.map("scale", t1, c=d ** -0.5)
    t3 = g.map("softmax_last", t2)
    o = g.einsum("h s z, z h d -> s h d", t3, vh)
    y = g.einsum("s h d, a h d -> s a", o, WO)

    feeds = {Q: RNG.normal(size=(s, a)).astype(np.float32)}
    for w in (WQ, WK, WV, WO):
        feeds[w] = (RNG.normal(size=(a, h, d)) * 0.1).astype(np.float32)
    vals = engine.run(g, feeds)

    # reference
    qr = np.einsum("sa,ahd->shd", feeds[Q], feeds[WQ])
    kr = np.einsum("sa,ahd->shd", feeds[Q], feeds[WK])
    vr = np.einsum("sa,ahd->shd", feeds[Q], feeds[WV])
    sc = np.einsum("shd,zhd->hsz", qr, kr) * d ** -0.5
    p = jax.nn.softmax(sc, axis=-1)
    orf = np.einsum("hsz,zhd->shd", np.asarray(p), vr)
    yr = np.einsum("shd,ahd->sa", orf, feeds[WO])
    np.testing.assert_allclose(vals[y], yr, rtol=1e-4, atol=1e-5)


def test_grad_graph_matches_jax_grad():
    g = EinGraph("ffnn")
    X = g.input("X", "bf", (16, 32))
    W1 = g.input("W1", "fh", (32, 64))
    W2 = g.input("W2", "hc", (64, 8))
    Y = g.input("Y", "bc", (16, 8))
    h1 = g.einsum("bf,fh->bh", X, W1)
    a1 = g.map("relu", h1)
    p = g.einsum("bh,hc->bc", a1, W2)
    diff = g.einsum("bc,bc->bc", p, Y, combine="sub", agg="")
    sq = g.map("square", diff)
    loss = g.einsum("bc->", sq, combine="id", agg="sum")
    gg, grads, seed = grad_graph(g, loss, [W1, W2])

    feeds = {X: RNG.normal(size=(16, 32)).astype(np.float32),
             W1: (RNG.normal(size=(32, 64)) * 0.1).astype(np.float32),
             W2: (RNG.normal(size=(64, 8)) * 0.1).astype(np.float32),
             Y: RNG.normal(size=(16, 8)).astype(np.float32),
             seed: np.ones(())}
    vals = engine.run(gg, feeds)

    def f(w1, w2):
        h = jnp.maximum(feeds[X] @ w1, 0)
        return jnp.sum((h @ w2 - feeds[Y]) ** 2)

    gw1, gw2 = jax.grad(f, argnums=(0, 1))(feeds[W1], feeds[W2])
    np.testing.assert_allclose(vals[grads[W1]], gw1, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(vals[grads[W2]], gw2, rtol=1e-4, atol=1e-5)


def test_engine_runs_under_mesh_plan():
    """Mesh-mode plan + with_sharding_constraint on host devices."""
    from repro.core.decomp import eindecomp
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh((1, 1))
    g = EinGraph()
    a = g.input("A", "ij", (16, 16))
    b = g.input("B", "jk", (16, 16))
    z = g.einsum("ij,jk->ik", a, b)
    plan = eindecomp(g, 1, mesh_axes={"data": 1, "model": 1})
    fn = engine.make_runner(g, [z], plan=plan, mesh=mesh)
    A = RNG.normal(size=(16, 16)).astype(np.float32)
    B = RNG.normal(size=(16, 16)).astype(np.float32)
    np.testing.assert_allclose(jax.jit(fn)(A, B), A @ B, rtol=1e-4)
