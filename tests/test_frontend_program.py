"""The declarative frontend (repro.frontend): symbolic tracing, operator
sugar, name-keyed I/O at the IR layer, and the Program compile lifecycle."""
import numpy as np
import pytest

from repro import frontend as ein
from repro.core import canon, engine
from repro.core.einsum import EinGraph, eval_graph_dense, resolve_feeds
from repro.core.plancache import PlanCache

RNG = np.random.default_rng(0)


def _chain_exprs():
    A = ein.tensor("A", "i j", (16, 32))
    B = ein.tensor("B", "j k", (32, 8))
    C = ein.tensor("C", "k l", (8, 4))
    AB = ein.einsum("i j, j k -> i k", A, B, name="AB")
    Z = ein.einsum("i k, k l -> i l", AB, C, name="Z")
    return A, B, C, Z


# ---------------------------------------------------------------------------
# Tracing
# ---------------------------------------------------------------------------


def test_trace_matches_imperative_builder():
    """The traced graph is node-for-node what the imperative builder writing
    the same calls produces — same canonical key, same names."""
    *_, Z = _chain_exprs()
    g, _ = ein.trace([Z], "chain")

    h = EinGraph("chain")
    a = h.input("A", "ij", (16, 32))
    b = h.input("B", "jk", (32, 8))
    c = h.input("C", "kl", (8, 4))
    ab = h.einsum("ij,jk->ik", a, b, name="AB")
    h.einsum("ik,kl->il", ab, c, name="Z")

    assert canon.graph_key(g) == canon.graph_key(h)
    assert [n.name for n in g.nodes] == [n.name for n in h.nodes]
    assert [n.kind for n in g.nodes] == [n.kind for n in h.nodes]


def test_trace_shared_subexpression_emitted_once():
    x = ein.tensor("x", "i", (8,))
    y = x.map("relu")
    z = ein.einsum("i, i -> i", y, y, combine="mul", agg="")
    g, ids = ein.trace([z])
    assert len(g.nodes) == 3  # x, relu, mul — y traced once
    assert g.nodes[ids[z]].inputs == (ids[y], ids[y])


def test_trace_duplicate_input_names_rejected():
    a = ein.tensor("w", "i", (4,))
    b = ein.tensor("w", "i", (4,))
    s = ein.einsum("i, i -> i", a, b, combine="add", agg="")
    with pytest.raises(ValueError, match="duplicate input name"):
        ein.trace([s])


def test_operator_sugar_semantics():
    x = ein.tensor("x", "i j", (4, 5))
    y = ein.tensor("y", "i j", (4, 5))
    exprs = {
        "add": x + y, "mul": x * y, "sub": x - y, "div": x / y,
        "maximum": ein.maximum(x, y),
        "scale": 2.0 * x, "shift": x - 3.0, "rsub": 3.0 - x,
        "neg": -x, "sq": x ** 2, "sdiv": x / 4.0,
    }
    prog = ein.Program(dict(exprs))
    run = prog.compile(jit=False)
    X = RNG.normal(size=(4, 5)).astype(np.float32)
    Y = (RNG.normal(size=(4, 5)).astype(np.float32) + 2.0)
    out = run({"x": X, "y": Y})
    want = {
        "add": X + Y, "mul": X * Y, "sub": X - Y, "div": X / Y,
        "maximum": np.maximum(X, Y),
        "scale": 2.0 * X, "shift": X - 3.0, "rsub": 3.0 - X,
        "neg": -X, "sq": X ** 2, "sdiv": X / 4.0,
    }
    for k, w in want.items():
        np.testing.assert_allclose(out[k], w, rtol=1e-6, atol=1e-6,
                                   err_msg=k)


def test_elementwise_requires_aligned_labels():
    x = ein.tensor("x", "i j", (4, 5))
    y = ein.tensor("y", "j i", (5, 4))
    with pytest.raises(ValueError, match="elementwise"):
        _ = x + y


# ---------------------------------------------------------------------------
# Name-keyed feeds at the IR layer (eval_graph_dense / engine.run)
# ---------------------------------------------------------------------------


def _small_graph():
    g = EinGraph("nk")
    a = g.input("A", "ij", (4, 8))
    b = g.input("B", "jk", (8, 2))
    z = g.einsum("ij,jk->ik", a, b)
    return g, a, b, z


def test_name_keyed_feeds_dense_and_engine():
    g, a, b, z = _small_graph()
    A = RNG.normal(size=(4, 8)).astype(np.float32)
    B = RNG.normal(size=(8, 2)).astype(np.float32)
    by_name = {"A": A, "B": B}
    by_id = {a: A, b: B}
    np.testing.assert_array_equal(eval_graph_dense(g, by_name)[z],
                                  eval_graph_dense(g, by_id)[z])
    np.testing.assert_array_equal(np.asarray(engine.run(g, by_name)[z]),
                                  np.asarray(engine.run(g, by_id)[z]))
    # mixed keys resolve too
    np.testing.assert_array_equal(
        np.asarray(engine.run(g, {"A": A, b: B})[z]),
        np.asarray(engine.run(g, by_id)[z]))


def test_resolve_feeds_errors():
    g, a, b, _ = _small_graph()
    A = np.zeros((4, 8), np.float32)
    B = np.zeros((8, 2), np.float32)
    with pytest.raises(KeyError, match="unknown input name"):
        resolve_feeds(g, {"A": A, "nope": B})
    with pytest.raises(ValueError, match="missing feeds"):
        resolve_feeds(g, {"A": A})
    # ambiguous names are an error only when actually used as keys
    g2 = EinGraph("dup")
    x1 = g2.input("w", "i", (4,))
    x2 = g2.input("w", "i", (4,))
    g2.einsum("i,i->i", x1, x2, combine="add", agg="")
    W = np.ones(4, np.float32)
    assert set(resolve_feeds(g2, {x1: W, x2: W})) == {x1, x2}
    with pytest.raises(ValueError, match="ambiguous"):
        resolve_feeds(g2, {"w": W, x2: W})


# ---------------------------------------------------------------------------
# Program lifecycle
# ---------------------------------------------------------------------------


def test_program_multi_output_and_named_io():
    A, B, C, Z = _chain_exprs()
    prog = ein.Program({"Z": Z})
    assert prog.input_names == ("A", "B", "C")
    run = prog.compile(p=4)
    feeds = {"A": RNG.normal(size=(16, 32)).astype(np.float32),
             "B": RNG.normal(size=(32, 8)).astype(np.float32),
             "C": RNG.normal(size=(8, 4)).astype(np.float32)}
    out = run(feeds)
    np.testing.assert_allclose(
        out["Z"], feeds["A"] @ feeds["B"] @ feeds["C"], rtol=1e-4, atol=1e-4)
    # keyword form and multi-output (intermediate + final)
    prog2 = ein.Program([Z])          # named after the expression
    assert prog2.output_names == ("Z",)
    AB = Z  # any expression (incl. intermediates) can be an output
    multi = ein.Program({"Z": Z, "also": AB}).compile(jit=False)
    res = multi(**feeds)
    assert set(res) == {"Z", "also"}
    np.testing.assert_array_equal(np.asarray(res["Z"]),
                                  np.asarray(res["also"]))


def test_program_compile_plans_through_cache():
    *_, Z = _chain_exprs()
    prog = ein.Program({"Z": Z})
    cache = PlanCache()
    r1 = prog.compile(p=8, cache=cache)
    assert cache.misses == 1 and cache.hits == 0
    r2 = prog.compile(p=8, cache=cache)
    assert cache.hits == 1
    assert r2.plan.d_by_node == r1.plan.d_by_node
    # an isomorphic program (fresh labels) is also a hit
    A = ein.tensor("A", "p q", (16, 32))
    B = ein.tensor("B", "q r", (32, 8))
    C = ein.tensor("C", "r t", (8, 4))
    Z2 = ein.einsum("p q, q r -> p r", A, B)
    Z2 = ein.einsum("p r, r t -> p t", Z2, C)
    ein.Program({"Z": Z2}).compile(p=8, cache=cache)
    assert cache.hits == 2
    with pytest.raises(ValueError, match="nothing to plan"):
        prog.compile(cache=cache)


def test_program_compile_mesh_mode_executes_sharded():
    from repro.launch.mesh import make_host_mesh

    *_, Z = _chain_exprs()
    run = ein.Program({"Z": Z}).compile(mesh=make_host_mesh((1, 1)))
    assert run.plan.mode == "mesh"
    feeds = {"A": RNG.normal(size=(16, 32)).astype(np.float32),
             "B": RNG.normal(size=(32, 8)).astype(np.float32),
             "C": RNG.normal(size=(8, 4)).astype(np.float32)}
    np.testing.assert_allclose(run(feeds)["Z"],
                               feeds["A"] @ feeds["B"] @ feeds["C"],
                               rtol=1e-4, atol=1e-4)
    pol = run.policy()
    for axes in pol.label_axes.values():
        assert set(axes) <= {"data", "model"}


def test_program_lower_introspection():
    *_, Z = _chain_exprs()
    run = ein.Program({"Z": Z}).compile(p=4)
    low = run.lower()
    assert low.plan is run.plan
    txt = low.as_text()
    assert "plan: p=4" in txt and "outputs: Z=" in txt
    # without planning inputs there is no plan (and no policy)
    bare = ein.Program({"Z": Z}).compile()
    assert bare.plan is None
    with pytest.raises(ValueError, match="without .* plan|no plan"):
        bare.policy()


def test_program_grad_matches_jax():
    import jax
    import jax.numpy as jnp

    X = ein.tensor("X", "b f", (8, 16))
    W = ein.tensor("W", "f h", (16, 4))
    Y = ein.tensor("Y", "b h", (8, 4))
    p = ein.einsum("b f, f h -> b h", X, W).map("relu")
    loss = ein.einsum("b h -> ", (p - Y) ** 2, agg="sum")
    grun = ein.Program({"loss": loss}).grad(wrt="W").compile(p=2)
    feeds = {"X": RNG.normal(size=(8, 16)).astype(np.float32),
             "W": RNG.normal(size=(16, 4)).astype(np.float32) * 0.1,
             "Y": RNG.normal(size=(8, 4)).astype(np.float32)}
    res = grun(feeds)  # dLoss_seed defaults to ones

    def ref(w):
        return jnp.sum((jnp.maximum(feeds["X"] @ w, 0) - feeds["Y"]) ** 2)

    np.testing.assert_allclose(res["grad_W"], jax.grad(ref)(feeds["W"]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(res["loss"], ref(feeds["W"]), rtol=1e-5)


def test_program_feed_errors():
    *_, Z = _chain_exprs()
    run = ein.Program({"Z": Z}).compile(jit=False)
    A = np.zeros((16, 32), np.float32)
    with pytest.raises(ValueError, match="missing feeds"):
        run({"A": A})
    with pytest.raises(KeyError, match="unknown inputs"):
        run({"A": A, "B": A, "C": A, "D": A})
    with pytest.raises(KeyError, match="grad: unknown inputs"):
        ein.Program({"Z": Z}).grad(wrt=["nope"])
