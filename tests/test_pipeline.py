"""Pipeline tier (repro.pipeline): partitioner, microbatch schedule,
ppermute handoffs, and the bit-identity contract.

Layers of the pin, mirroring the subsystem:

1. **Partitioner** — contiguous dependency-closed stages, min cut-edge
   bytes under the balance cap, identity fast path, multi-hop liveness.
2. **Planning** — repeated (structurally identical) stages hash equal
   and resolve warm through the canonical plan cache; p=1 lowers to the
   serial ``build_schedule`` verbatim.
3. **Static schedule** — GPipe cell order, (stage, microbatch) trace
   attribution, zero handoff collectives on a size-1 pp axis, the static
   bubble fraction (p-1)/(m+p-1).
4. **Execution** — pipelined outputs bit-identical to the unpipelined
   stitched-plan compile: random graphs and the full zoo across a
   (p, m) grid (mixtral pipelines at m=1: MoE capacity routing couples
   rows across the batch, which ``batch_splittable`` rejects).

Multi-device cells skip when the host has too few devices (the CI
multi-device matrix forces 8).
"""
import math

import jax
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_config, reduced
from repro.core import engine, spmd
from repro.core.cost import bubble_fraction, bubble_fraction_weighted
from repro.core.decomp import eindecomp
from repro.core.einsum import EinGraph
from repro.core.plancache import PlanCache
from repro.launch.mesh import make_mesh
from repro.launch.trajectory import FAMILIES
from repro.models.eingraphs import program_for
from repro.pipeline import (PipelineSpec, batch_splittable,
                            build_pipeline_schedule, partition_stages,
                            scale_graph_batch)

RNG = np.random.default_rng(0)
N_DEV = len(jax.devices())

needs4 = pytest.mark.skipif(N_DEV < 4, reason="needs >= 4 devices")
needs8 = pytest.mark.skipif(N_DEV < 8, reason="needs >= 8 devices")


# ---------------------------------------------------------------------------
# 1. partitioner
# ---------------------------------------------------------------------------


def _waist_graph():
    """Four balanced einsum hops with a narrow waist after the second —
    the min-byte cut must land on the waist tensor."""
    g = EinGraph("waist")
    x = g.input("x", "b a", (8, 64))
    w1 = g.input("w1", "a c", (64, 64))
    w2 = g.input("w2", "c d", (64, 4))
    w3 = g.input("w3", "d e", (4, 64))
    w4 = g.input("w4", "e f", (64, 64))
    h1 = g.einsum("b a, a c -> b c", x, w1)
    h2 = g.einsum("b c, c d -> b d", h1, w2)   # the waist: (8, 4)
    h3 = g.einsum("b d, d e -> b e", h2, w3)
    h4 = g.einsum("b e, e f -> b f", h3, w4)
    return g, [h1, h2, h3, h4]


def test_partition_p1_identity():
    g, _ = _waist_graph()
    stages = partition_stages(g, PipelineSpec(stages=1, microbatches=1))
    assert len(stages) == 1 and stages[0].graph is g
    assert stages[0].recv == []


def test_partition_cuts_at_the_waist():
    g, (h1, h2, h3, h4) = _waist_graph()
    stages = partition_stages(g, PipelineSpec(stages=2))
    assert [st.nids for st in stages] == [[h1, h2], [h3, h4]]
    # exactly the waist tensor crosses the boundary
    assert stages[1].recv == [h2]


def test_partition_stages_contiguous_and_closed():
    g, _ = _waist_graph()
    stages = partition_stages(g, PipelineSpec(stages=3))
    seq = [nid for nid in g.topo_order() if g.nodes[nid].kind != "input"]
    flat = [nid for st in stages for nid in st.nids]
    assert flat == seq  # contiguous cover, topo order, no node dropped
    stage_of = {gn: st.index for st in stages for gn in st.nids}
    for st in stages:
        assert st.nids, "empty stage"
        for gn in st.recv:
            assert stage_of[gn] < st.index  # chain: only earlier stages


def test_partition_too_many_stages_raises():
    g, _ = _waist_graph()
    with pytest.raises(ValueError, match="stages"):
        partition_stages(g, PipelineSpec(stages=5))


def test_multi_hop_tensor_lives_on_every_boundary():
    """A tensor consumed k stages downstream is charged at (and relayed
    over) every intermediate boundary."""
    g = EinGraph("relay")
    x = g.input("x", "b a", (8, 8))
    a = g.map("relu", x, name="a")
    b = g.map("relu", a, name="b")
    c = g.einsum("b a, b a -> b a", a, b)  # consumes stage-0's a at stage 2
    psc = build_pipeline_schedule(g, PipelineSpec(stages=3), {"pp": 3})
    assert [st.nids for st in psc.stages] == [[a], [b], [c]]
    assert psc.boundaries[0] == [a]
    assert psc.boundaries[1] == [a, b]
    relayed = [e.nid for e in psc.trace.events if e.rule == "handoff"]
    assert relayed.count(a) == 2 and relayed.count(b) == 1


def test_scale_graph_batch():
    g, _ = _waist_graph()
    gm = scale_graph_batch(g, 4, "b")
    assert gm.nodes[0].shape == (2, 64)       # b: 8 -> 2
    assert gm.nodes[1].shape == (64, 64)      # no batch label: untouched
    assert scale_graph_batch(g, 1, "b") is g
    with pytest.raises(ValueError, match="divisible"):
        scale_graph_batch(g, 3, "b")


def test_moe_batch_coupling_rejected():
    """MoE capacity routing couples rows across the batch: splittable is
    False and m > 1 partitioning raises; the dense families split fine."""
    moe = program_for(reduced(get_config("mixtral-8x7b")),
                      ShapeConfig("eq", "prefill", 8, 2)).graph
    dense = program_for(reduced(get_config("llama-7b")),
                        ShapeConfig("eq", "prefill", 8, 2)).graph
    assert not batch_splittable(moe, "b")
    assert batch_splittable(dense, "b")
    with pytest.raises(ValueError, match="couples rows"):
        partition_stages(moe, PipelineSpec(stages=2, microbatches=2))


# ---------------------------------------------------------------------------
# 2. planning: warm cache, serial verbatim
# ---------------------------------------------------------------------------


def _layered_graph(n_layers=4):
    """n structurally identical einsum layers — repeated-stage dedup."""
    g = EinGraph("layers")
    h = g.input("x", "b a", (8, 32))
    for i in range(n_layers):
        w = g.input(f"w{i}", "a c", (32, 32))
        h = g.einsum("b a, a c -> b a", h, w)
    return g


def test_repeated_stages_hash_equal_and_hit_warm():
    g = _layered_graph(4)
    cache = PlanCache()
    psc = build_pipeline_schedule(g, PipelineSpec(stages=2),
                                  {"pp": 2, "data": 2}, cache=cache)
    assert psc.stages[0].key == psc.stages[1].key
    # stage 1 is structurally stage 0 (handoff stub == input stub): its §8
    # plan resolves warm — one DP run plans both transformer halves
    assert psc.cache_stats["misses"] == 1
    assert psc.cache_stats["hits"] == 1


def test_p1_reproduces_serial_schedule_verbatim():
    g = _layered_graph(3)
    axes = {"data": 2, "model": 2}
    psc = build_pipeline_schedule(g, PipelineSpec(stages=1),
                                  {"pp": 1, **axes})
    direct = spmd.build_schedule(
        g, eindecomp(g, 4, mesh_axes=axes, offpath_repart=True), axes,
        g.outputs())
    st = psc.stages[0]
    assert st.graph is g
    assert st.sched.programs == direct.programs
    assert st.sched.layouts == direct.layouts
    assert st.sched.trace.events == direct.trace.events
    # and the combined trace is the stage trace, (0, 0)-tagged
    assert len(psc.trace.events) == len(direct.trace.events)
    assert all(e.stage == 0 and e.microbatch == 0
               for e in psc.trace.events)


# ---------------------------------------------------------------------------
# 3. static schedule: cells, attribution, bubble, zero-collective pp=1
# ---------------------------------------------------------------------------


def test_gpipe_cell_order():
    g = _layered_graph(4)
    psc = build_pipeline_schedule(g, PipelineSpec(stages=2, microbatches=4),
                                  {"pp": 2})
    assert psc.cells == [(0, 0), (0, 1), (1, 0), (0, 2), (1, 1),
                         (0, 3), (1, 2), (1, 3)]
    assert psc.bubble == bubble_fraction(2, 4) == pytest.approx(1 / 5)


def test_bubble_fraction_static_and_weighted():
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 1) == pytest.approx(3 / 4)
    assert bubble_fraction(2, 7) == pytest.approx(1 / 8)
    # balanced stages: the weighted fill/drain bubble IS the static one
    for p, m in [(2, 4), (3, 5), (4, 1)]:
        assert bubble_fraction_weighted([100] * p, m) == \
            pytest.approx(bubble_fraction(p, m))
    # imbalance only ever raises it
    assert bubble_fraction_weighted([100, 300], 4) > bubble_fraction(2, 4)
    assert bubble_fraction_weighted([0, 0], 4) == 0.0


def test_trace_attribution_and_handoff_tagging():
    g = _layered_graph(4)
    psc = build_pipeline_schedule(g, PipelineSpec(stages=2, microbatches=2),
                                  {"pp": 2, "data": 2})
    assert psc.trace.events, "expected a non-empty combined trace"
    for e in psc.trace.events:
        assert 0 <= e.stage < 2 and 0 <= e.microbatch < 2
    handoffs = [e for e in psc.trace.events if e.rule == "handoff"]
    # one boundary tensor x two microbatches, each a cyclic pp ppermute
    assert len(handoffs) == 2
    for e in handoffs:
        assert e.kind == "ppermute" and e.axes == ("pp",)
        assert sorted(e.perm) == [(0, 1), (1, 0)]
    # handoff fires after its producing cell's events (RA402 by order)
    idx = {id(e): i for i, e in enumerate(psc.trace.events)}
    for h in handoffs:
        for e in psc.trace.events:
            if (e.stage, e.microbatch) == (h.stage, h.microbatch) \
                    and e.rule != "handoff":
                assert idx[id(e)] < idx[id(h)]


@pytest.mark.parametrize("m", [1, 4])
def test_zero_handoff_collectives_on_size1_pp_axis(m):
    """A (1, ·) pp axis emits NO handoff collectives at all — pipelining
    degenerates to the plain schedule plus microbatch splitting."""
    g = _layered_graph(4)
    psc = build_pipeline_schedule(
        g, PipelineSpec(stages=1, microbatches=m), {"pp": 1, "data": 2})
    assert psc.handoff_elems == 0
    assert all(e.rule != "handoff" for e in psc.trace.events)
    assert all("pp" not in e.axes for e in psc.trace.events)
    assert psc.bubble == 0.0


def test_mesh_axis_size_must_match_stages():
    g = _layered_graph(4)
    with pytest.raises(ValueError, match="must agree"):
        build_pipeline_schedule(g, PipelineSpec(stages=2), {"pp": 1})


def test_stage_traced_within_priced():
    """Per stage: traced intra-stage wire for one microbatch stays within
    the §7 stage price (bench_pipeline --check's bound, statically)."""
    g = _layered_graph(4)
    psc = build_pipeline_schedule(g, PipelineSpec(stages=2, microbatches=2),
                                  {"pp": 2, "data": 2, "model": 2})
    for s in range(2):
        assert psc.stage_trace_elems(s) <= psc.stage_priced(s)


# ---------------------------------------------------------------------------
# 4. execution: bit-identical to the unpipelined stitched-plan compile
# ---------------------------------------------------------------------------


def _feeds(g, rng):
    feeds = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if str(np.dtype(n.dtype)) == "int32":
            feeds[n.name] = rng.integers(0, 4, size=n.shape).astype(np.int32)
        else:
            feeds[n.name] = rng.normal(size=n.shape).astype(np.float32)
    return feeds


def _run_pair(prog, p, m, intra_axes):
    """(pipelined outputs, stitched-baseline outputs, PipelineSchedule)."""
    shape = (p,) + tuple(intra_axes.values())
    mesh = make_mesh(shape, ("pp",) + tuple(intra_axes))
    run = prog.compile(mesh=mesh, executor="shard_map",
                       pipeline=PipelineSpec(stages=p, microbatches=m))
    base_mesh = make_mesh(tuple(intra_axes.values()), tuple(intra_axes))
    base = prog.compile(mesh=base_mesh, executor="shard_map",
                        plan=run.pipeline_schedule.stitched)
    return run, base


@needs4
@pytest.mark.parametrize("p,m", [(1, 1), (1, 2), (2, 1), (2, 2), (2, 4)])
def test_chain_pipelined_bit_identical_grid(p, m):
    from repro import frontend as ein

    g = _layered_graph(4)
    prog = ein.Program.from_graph(g, {"y": g.outputs()[-1]})
    run, base = _run_pair(prog, p, m, {"data": 2})
    feeds = _feeds(g, np.random.default_rng(p * 10 + m))
    out = np.asarray(run(feeds)["y"])
    ref = np.asarray(base(feeds)["y"])
    np.testing.assert_array_equal(out, ref)
    psc = run.pipeline_schedule
    assert psc.bubble == bubble_fraction(p, m)
    if p == 1:
        assert psc.handoff_elems == 0


def _random_batched_graph(rng):
    """Random einsum chain where every node keeps the batch label ``b``."""
    pool = ["i", "j", "k"]
    g = EinGraph("rand")
    nl = int(rng.integers(1, 3))
    labels = ["b"] + list(rng.choice(pool, size=nl, replace=False))
    h = g.input("x", labels, [8] * len(labels))
    nodes = [h]
    for t in range(int(rng.integers(2, 5))):
        la = g.nodes[nodes[-1]].labels
        nl = int(rng.integers(1, 3))
        wl = list(rng.choice(pool, size=nl, replace=False))
        w = g.input(f"w{t}", wl, [8] * nl)
        union = list(dict.fromkeys(list(la) + wl))
        keep = ["b"] + [l for l in union
                        if l != "b" and rng.random() < 0.6]
        expr = f"{' '.join(la)}, {' '.join(wl)} -> {' '.join(keep)}"
        nodes.append(g.einsum(expr, nodes[-1], w))
        if rng.random() < 0.3:
            nodes.append(g.map("relu", nodes[-1]))
    return g


@needs4
@pytest.mark.parametrize("seed", range(6))
def test_random_graphs_pipelined_bit_identical(seed):
    from repro import frontend as ein

    rng = np.random.default_rng(seed)
    g = _random_batched_graph(rng)
    n_stageable = sum(1 for n in g.nodes if n.kind != "input")
    p = 2 if n_stageable >= 2 else 1
    m = 2 if batch_splittable(g, "b") else 1
    prog = ein.Program.from_graph(
        g, {f"out{i}": o for i, o in enumerate(g.outputs())})
    run, base = _run_pair(prog, p, m, {"data": 2})
    feeds = _feeds(g, rng)
    out, ref = run(feeds), base(feeds)
    for k in out:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(ref[k]), err_msg=k)


@pytest.fixture()
def _stub_opaques(monkeypatch):
    from repro.models.opaque_stubs import capacity_of, make_stub_opaques

    def apply(g):
        for kind, fn in make_stub_opaques(capacity_of(g)).items():
            monkeypatch.setitem(engine.OPAQUE_FNS, kind, fn)

    return apply


@needs8
@pytest.mark.parametrize("phase", ["prefill", "decode"])
@pytest.mark.parametrize("arch", list(FAMILIES))
def test_zoo_pipelined_bit_identical(_stub_opaques, arch, phase):
    """Full zoo, prefill + decode: pipelined logits are bit-identical to
    the unpipelined stitched-plan compile (mixtral at m=1 — capacity
    routing couples the batch)."""
    cfg = reduced(get_config(arch))
    prog = program_for(cfg, ShapeConfig("eq", phase, 8, 2))
    g = prog.graph
    _stub_opaques(g)
    m = 1 if not batch_splittable(g, "b") else 2
    run, base = _run_pair(prog, 2, m, {"data": 2, "model": 2})
    feeds = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if str(np.dtype(n.dtype)) == "int32":
            feeds[n.name] = RNG.integers(0, cfg.vocab,
                                         size=n.shape).astype(np.int32)
        else:
            feeds[n.name] = (RNG.normal(size=n.shape) * 0.05).astype(
                np.float32)
    out = np.asarray(run(feeds)["logits"])
    ref = np.asarray(base(feeds)["logits"])
    np.testing.assert_array_equal(out, ref)
    psc = run.pipeline_schedule
    assert psc.handoff_elems > 0
    for s in range(2):
        assert psc.stage_trace_elems(s) <= psc.stage_priced(s)


def test_compile_pipeline_api_guards():
    from repro import frontend as ein

    g = _layered_graph(2)
    prog = ein.Program.from_graph(g, {"y": g.outputs()[-1]})
    spec = PipelineSpec(stages=1)
    with pytest.raises(ValueError, match="shard_map"):
        prog.compile(p=2, pipeline=spec)
    with pytest.raises(ValueError, match="donate"):
        mesh = make_mesh((1,), ("pp",))
        prog.compile(mesh=mesh, executor="shard_map", pipeline=spec,
                     donate=True)
    with pytest.raises(ValueError, match="mutually exclusive"):
        mesh = make_mesh((1,), ("pp",))
        prog.compile(mesh=mesh, executor="shard_map", pipeline=spec,
                     plan=object())
    with pytest.raises(ValueError):
        PipelineSpec(stages=0)
    with pytest.raises(ValueError):
        PipelineSpec(microbatches=0)


@needs4
def test_compiled_pipeline_surface():
    """.pipeline_schedule, .collectives (= the combined tagged trace), and
    .plan (= the stitched baseline plan) are all exposed."""
    from repro import frontend as ein

    g = _layered_graph(4)
    prog = ein.Program.from_graph(g, {"y": g.outputs()[-1]})
    run, _ = _run_pair(prog, 2, 2, {"data": 2})
    psc = run.pipeline_schedule
    assert run.collectives is psc.trace
    assert run.plan is psc.stitched
    assert run.plan.mode == "mesh"
    assert run.plan.p == 2  # intra-stage devices (pp rides on top)
    assert math.prod(psc.sizes.values()) == 4
