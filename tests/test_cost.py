"""Cost model (paper §7) against the paper's own worked examples."""
import pytest

from repro.core.cost import (cost_agg, cost_agg_collective, cost_join,
                             cost_join_collective, cost_repart,
                             cost_repart_collective, n_join_results,
                             node_cost, node_cost_collective)
from repro.core.einsum import EinSpec

MM = EinSpec((("i", "j"), ("j", "k")), ("i", "k"))
BOUNDS = {"i": 8, "j": 8, "k": 8}


def test_join_result_count_top_left():
    # Fig 1/2: every depicted partitioning yields 16 kernel calls
    for d in ({"i": 4, "j": 1, "k": 4}, {"i": 2, "j": 1, "k": 8},
              {"i": 2, "j": 4, "k": 2}, {"i": 2, "j": 2, "k": 4}):
        assert n_join_results(["i", "j"], ["j", "k"], d) == 16


def test_join_result_count_with_join_predicate():
    # §6: d = [16,2,2,4] -> 16*2*4 = 128 (the repeated j counts once)
    d = {"i": 16, "j": 2, "k": 4}
    assert n_join_results(["i", "j"], ["j", "k"], d) == 128


def test_cost_join_top_left():
    # §7 worked example: b_XY/d = [2,8,8,2]; n_X = n_Y = 16.
    # (The paper prints "8 x (16+16)" but its own figures count 16 kernel
    # calls for d=[4,1,1,4]; the formula is p*(n_X+n_Y) with p = N(d) = 16.)
    d = {"i": 4, "j": 1, "k": 4}
    assert cost_join(MM, d, BOUNDS) == 16 * (16 + 16)


def test_cost_agg_bottom_right():
    # §7: d = [2,2,2,4]: n_agg=2, n_Z=8, cost = (16/2)(2-1)8 = 64
    d = {"i": 2, "j": 2, "k": 4}
    assert cost_agg(MM, d, BOUNDS) == 64


def test_cost_agg_zero_when_join_dim_unsplit():
    d = {"i": 4, "j": 1, "k": 4}
    assert cost_agg(MM, d, BOUNDS) == 0


def test_cost_repart_paper_example():
    # §7: producer d_Z=[2,4], consumer d_X=[4,1] over bound [8,8]:
    # n_p=8, n_c=16, n_int=4, n=64 -> (4-1)(64/16)(16+8) + 8*(64/16) = 320
    assert cost_repart((2, 4), (4, 1), (8, 8)) == 320


def test_cost_repart_identity():
    assert cost_repart((2, 4), (2, 4), (8, 8)) == 0


def test_cost_repart_symmetry_structure():
    # repart to a refinement only moves the producer extraction term
    c = cost_repart((1, 1), (4, 4), (16, 16))
    assert c > 0


def test_collective_mode_cheaper_for_allgather():
    # un-sharding one dim: ring all-gather (k-1)/k*n vs paper's p2p bound
    paper = cost_repart((8, 1), (1, 1), (64, 64))
    coll = cost_repart_collective((8, 1), (1, 1), (64, 64))
    assert coll < paper


def test_collective_node_cost_includes_join_replication():
    """Regression: collective mode used to price nodes as
    ``cost_join(...) * 0 + cost_agg_collective(...)`` — silently dropping
    the join term, which made any replicating partitioning look free.  The
    dedicated ``node_cost_collective`` must charge (r-1)*numel per input."""
    from repro.core.decomp import CostModel

    b64 = {"i": 64, "j": 64, "k": 64}
    # d splits only k (absent from X=[i,j]): X is replicated 8x at the join,
    # nothing is aggregated (d_j = 1) — the old expression priced this at 0.
    d = {"i": 1, "j": 1, "k": 8}
    assert cost_agg_collective(MM, d, b64) == 0          # the old (buggy) total
    coll = node_cost_collective(MM, d, b64)
    assert coll == cost_join_collective(MM, d, b64) == 7 * 64 * 64
    # collective join = paper join minus the resident copies, never more
    assert 0 < coll <= node_cost(MM, d, b64)

    # both modes agree the two pieces compose the node cost
    cm_p, cm_c = CostModel("paper"), CostModel("collective")
    for dd in ({"i": 2, "j": 2, "k": 2}, {"i": 1, "j": 8, "k": 1},
               {"i": 8, "j": 1, "k": 1}):
        assert cm_p.node(MM, dd, b64) == node_cost(MM, dd, b64)
        assert cm_c.node(MM, dd, b64) == node_cost_collective(MM, dd, b64)
        assert cm_c.node(MM, dd, b64) <= cm_p.node(MM, dd, b64)

    # aggregation-heavy partitioning: reduce-scatter term still present
    dagg = {"i": 1, "j": 8, "k": 1}
    assert cost_agg_collective(MM, dagg, b64) > 0
    assert (node_cost_collective(MM, dagg, b64)
            == cost_join_collective(MM, dagg, b64)
            + cost_agg_collective(MM, dagg, b64))

    # unary nodes still move nothing at the join in either mode
    unary = EinSpec((("i", "j"),), ("i",), "id", "sum")
    assert cost_join_collective(unary, {"i": 4, "j": 2}, b64) == 0


# ---------------------------------------------------------------------------
# sites-aware repartition pricing (the fan-out fix)
# ---------------------------------------------------------------------------


def test_cost_repart_sites_surcharge():
    """sites counts distinct consumer placement groups: each group beyond
    the first receives the full tensor once more; sites=1 is byte-identical
    to the historical single-site bound."""
    da, ones, bound = (1, 2), (1, 1), (16, 8)
    base = cost_repart(da, ones, bound)
    assert cost_repart(da, ones, bound, sites=1) == base
    n = 16 * 8
    for sites in (2, 4, 8):
        assert cost_repart(da, ones, bound, sites) == base + (sites - 1) * n
    # identity reparts stay free regardless of fan-out
    assert cost_repart(da, da, bound, sites=8) == 0


def test_priced_covers_traced_on_fanout_gather():
    """Regression for the single-consumer-site assumption: a producer
    sharded 2-way over one axis of a 2x4 mesh feeds a replicated opaque —
    the realized gather replays on every one of the 8 placement groups, so
    the traced wire (n_dev * (k-1) * n_loc) exceeds the old single-site §7
    bound.  The sites-aware price restores priced >= traced."""
    import numpy as np

    from repro.core import spmd
    from repro.core.decomp import Plan, plan_cost_by_node
    from repro.core.einsum import EinGraph

    g = EinGraph("fanout")
    t = g.input("table", "v a", (16, 8))
    i = g.input("ids", "b", (4,), dtype=np.int32)
    r = g.map("relu", t)
    o = g.opaque("gather_rows", [r, i], "b a", (4, 8),
                 in_labels=[("v", "a"), ("b",)], shardable={"b", "a"})
    plan = Plan(p=8, mode="mesh")
    plan.d_by_node = {t: {"v": 1, "a": 2}, i: {"b": 1},
                      r: {"v": 1, "a": 2}, o: {"b": 1, "a": 1}}
    plan.axes_by_node = {t: {"a": ("data",)}, i: {},
                         r: {"a": ("data",)}, o: {}}
    sched = spmd.build_schedule(g, plan, {"data": 2, "model": 4}, [o])
    traced = sched.trace.elems_by_node[o]
    assert traced > 0
    old_price = cost_repart((1, 2), (1, 1), (16, 8))  # single-site bound
    new_price = plan_cost_by_node(g, plan)[o]
    assert old_price < traced <= new_price
