"""GRAD_MAPS completeness: every registered elementwise map op must be
differentiable through ``grad_graph``, and its graph-gradient must match
``jax.grad`` of the dense evaluation (the neg/add_const KeyError
regression — map ops the engine could run but nobody could train through).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.autodiff import GRAD_MAPS, grad_graph
from repro.core.einsum import EinGraph

RNG = np.random.default_rng(7)

#: params each op needs at build time (defaults exercised otherwise)
_PARAMS = {"scale": {"c": 1.7}, "add_const": {"c": 0.3},
           "rsqrt_eps": {"eps": 1e-3}}

#: ops needing a positive-domain input
_POSITIVE = {"rsqrt_eps"}


@pytest.mark.parametrize("op", sorted(GRAD_MAPS))
def test_every_grad_maps_op_matches_jax_grad(op):
    params = _PARAMS.get(op, {})
    g = EinGraph(f"grad_{op}")
    x = g.input("x", "i j", (4, 6))
    m = g.map(op, x, **params)
    loss = g.einsum("i j ->", m, combine="id", agg="sum")
    gg, grads, seed = grad_graph(g, loss, [x])

    X = (RNG.normal(size=(4, 6)) + 0.2).astype(np.float32)
    if op in _POSITIVE:
        X = np.abs(X) + 0.5
    vals = engine.run(gg, {x: X, seed: np.ones(())})

    def f(v):
        return jnp.sum(engine.MAP_FNS[op](v, **params))

    want = jax.grad(f)(jnp.asarray(X))
    np.testing.assert_allclose(np.asarray(vals[grads[x]]), np.asarray(want),
                               rtol=1e-4, atol=1e-5,
                               err_msg=f"grad mismatch for map op {op!r}")


def test_grad_maps_covers_all_elementwise_map_fns():
    """Every *forward* elementwise map op the engine registers must carry a
    GRAD_MAPS entry (derivative-only helpers and the non-elementwise
    softmax are exempt)."""
    derivative_helpers = set(GRAD_MAPS.values()) - set(GRAD_MAPS)
    exempt = derivative_helpers | {"softmax_last"}
    missing = [op for op in engine.MAP_FNS
               if op not in GRAD_MAPS and op not in exempt]
    assert not missing, f"map ops without gradients: {missing}"


def test_softmax_last_still_raises():
    """Non-diagonal Jacobian: must refuse, not silently mis-differentiate."""
    g = EinGraph()
    x = g.input("x", "i j", (4, 6))
    m = g.map("softmax_last", x)
    loss = g.einsum("i j ->", m, combine="id", agg="sum")
    with pytest.raises(NotImplementedError):
        grad_graph(g, loss, [x])
