"""EinDecomp (paper §8): counting, viability, DP optimality, linearization."""
import math

import numpy as np
import pytest

from repro.core.decomp import (Plan, count_partitionings, eindecomp,
                               eindecomp_tree, input_partitionings,
                               plan_cost, plan_data_parallel, plan_label,
                               plan_sqrt, viable_mesh, viable_pow2)
from repro.core.einsum import EinGraph


def chain_graph(n=3, size=64):
    g = EinGraph()
    prev = g.input("A0", "ij", (size, size))
    labels = "ijklmnop"
    for t in range(n):
        w = g.input(f"W{t}", labels[t + 1] + labels[t + 2], (size, size))
        prev = g.einsum(
            f"{labels[t]}{labels[t+1]},{labels[t+1]}{labels[t+2]}"
            f"->{labels[t]}{labels[t+2]}", prev, w)
    return g


def test_counting_formula_8_1():
    # §8.1: N=10 balls, D=6 buckets -> 3003
    assert count_partitionings(10, 6) == 3003
    g = EinGraph()
    x = g.input("X", "ij", (1 << 12, 1 << 12))
    y = g.input("Y", "jk", (1 << 12, 1 << 12))
    z = g.einsum("ij,jk->ik", x, y)
    for n in (3, 6, 10):
        assert len(viable_pow2(g, z, 1 << n)) == count_partitionings(n, 3)


def test_viable_exactly_p_kernel_calls():
    from repro.core.cost import n_join_results

    g = EinGraph()
    x = g.input("X", "ij", (64, 64))
    y = g.input("Y", "jk", (64, 64))
    z = g.einsum("ij,jk->ik", x, y)
    for d in viable_pow2(g, z, 16):
        assert n_join_results(("i", "j"), ("j", "k"), d) == 16


def test_viable_output_partitionings_8_2():
    # §8.2 lists output partitionings {[2,4],[4,2],[8,1],[1,8],[2,2],[4,1],
    # [1,4],[1,1]} for p=8 — all must be present.  (The paper's prose list
    # is non-exhaustive: its own §8.1 formula gives C(3+3-1,2)=10
    # partitionings, which add outputs (2,1) via d=[2,4,4,1] and (1,2).)
    g = EinGraph()
    x = g.input("X", "ij", (8, 8))
    y = g.input("Y", "jk", (8, 8))
    z = g.einsum("ij,jk->ik", x, y)
    assert len(viable_pow2(g, z, 8)) == count_partitionings(3, 3) == 10
    outs = {(d["i"], d["k"]) for d in viable_pow2(g, z, 8)}
    assert outs >= {(2, 4), (4, 2), (8, 1), (1, 8), (2, 2), (4, 1), (1, 4),
                    (1, 1)}


def test_viable_respects_divisibility():
    g = EinGraph()
    x = g.input("X", "ij", (6, 64))  # i=6: only 2 divides
    y = g.input("Y", "jk", (64, 64))
    z = g.einsum("ij,jk->ik", x, y)
    for d in viable_pow2(g, z, 8):
        assert d["i"] in (1, 2)


def test_tree_dp_beats_heuristics_on_skewed_chain():
    # the paper's Exp 1 skew: EinDecomp adapts, SQRT does not
    g = EinGraph()
    a = g.input("A", "ij", (256, 32))
    b = g.input("B", "jk", (32, 256))
    c = g.input("C", "kl", (256, 32))
    ab = g.einsum("ij,jk->ik", a, b)
    abc = g.einsum("ik,kl->il", ab, c)
    plan = eindecomp_tree(g, 16)
    sq = plan_sqrt(g, 16)
    assert plan.cost <= plan_cost(g, sq.d_by_node and sq)
    assert plan.cost <= sq.cost


def test_linearized_matches_tree_on_chains():
    g = chain_graph(4)
    t = eindecomp_tree(g, 16)
    l = eindecomp(g, 16, offpath_repart=True)
    assert l.cost == t.cost


def test_dp_vs_bruteforce_single_node():
    """For one matmul, the DP must find the global optimum over viable d."""
    from repro.core.cost import node_cost
    from repro.core.decomp import node_bounds

    g = EinGraph()
    x = g.input("X", "ij", (64, 16))
    y = g.input("Y", "jk", (16, 256))
    z = g.einsum("ij,jk->ik", x, y)
    plan = eindecomp_tree(g, 16)
    best = min(node_cost(g.nodes[z].spec, d, node_bounds(g, z))
               for d in viable_pow2(g, z, 16))
    assert plan.cost == best


def test_mesh_mode_uses_all_axes():
    g = chain_graph(2, size=64)
    plan = eindecomp(g, 8, mesh_axes={"data": 2, "model": 4})
    for nid, ax in plan.axes_by_node.items():
        if g.nodes[nid].kind == "einsum":
            used = [a for axes in ax.values() for a in axes]
            assert sorted(used) == ["data", "model"]


def test_mesh_mode_skips_indivisible_labels():
    g = EinGraph()
    x = g.input("X", "bh", (4, 25))  # h=25 not divisible by 4
    y = g.input("Y", "ha", (25, 32))
    z = g.einsum("bh,ha->ba", x, y)
    plan = eindecomp(g, 8, mesh_axes={"data": 2, "model": 4})
    d = plan.d_by_node[z]
    assert d["h"] == 1  # model axis cannot land on h


def test_plan_serialization_roundtrip():
    g = chain_graph(3)
    plan = eindecomp(g, 16, mesh_axes={"data": 4, "model": 4})
    js = plan.to_json()
    back = Plan.from_json(js)
    assert back.d_by_node == plan.d_by_node
    assert back.axes_by_node == plan.axes_by_node


def test_offpath_repart_no_worse_than_paper_linearization():
    """EinDecomp+ (charge cross-path reparts) should never produce a plan
    with higher exact cost than the paper-faithful §8.4 on DAG graphs."""
    from repro.configs import get_config, SHAPES
    from repro.models.eingraphs import build_graph

    cfg = get_config("llama-7b")
    for shape_name in ("train_4k", "prefill_32k"):
        g = build_graph(cfg, SHAPES[shape_name])
        plus = eindecomp(g, 256, mesh_axes={"data": 16, "model": 16},
                         offpath_repart=True)
        paper = eindecomp(g, 256, mesh_axes={"data": 16, "model": 16},
                          offpath_repart=False)
        assert plus.cost <= paper.cost


def test_input_partitionings_bounded_by_p():
    opts = input_partitionings((64, 64), 16)
    for o in opts:
        assert o[0] * o[1] <= 16
    assert (1, 1) in opts and (4, 4) in opts and (16, 1) in opts
