"""repro.analysis — the backend-free static verifier (ISSUE 8).

Four properties pinned here:

1. **Sensitivity** — every known-bad corpus fixture
   (tests/analysis_corpus/) trips exactly the RA code it documents.
2. **Specificity** — the entire model zoo (every family x
   prefill/decode/paged) analyzes clean, and the CLI that does so never
   initializes a jax backend (subprocess-pinned, same idiom as
   test_opdef's planning pin).
3. **Memory honesty** — the per-device peak the memory pass reports
   agrees with XLA's ``compiled.memory_analysis()`` within 10% on a
   shard_map-executed zoo cell (mixtral prefill, 8 forced host devices).
4. **Deterministic diagnostics** — resolve_feeds / EinSpec errors are
   stable and self-locating (sorted name lists, offending spec string),
   Expr-trace source locations survive into graph nodes, and every
   registered OpDef is VJP-complete (rule, grad, or an explicit
   ``vjp_reason``) — the lint twin of the ruff TID251 registry ban.
"""
import json
import re
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro import frontend as ein
from repro.analysis import CODES, ERROR, Finding, Report, WARNING, analyze
from repro.core import opdef
from repro.core.einsum import EinGraph, EinSpec, resolve_feeds

from tests.analysis_corpus import FIXTURES

_REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# 1. sensitivity: the known-bad corpus
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_corpus_fixture_trips_its_code(name):
    mod = FIXTURES[name]
    report = mod.report()
    assert report.has_errors, f"{name}: expected errors, got a clean report"
    assert mod.EXPECT in report.codes(), (
        f"{name}: expected {mod.EXPECT}, got {sorted(report.codes())}\n"
        + report.format())
    assert any(f.code == mod.EXPECT and f.severity == ERROR
               for f in report.findings)


def test_corpus_codes_are_documented():
    """Every fixture's expected code (and every code any fixture emits)
    exists in the CODES index the CLI prints with --list-codes."""
    for name, mod in FIXTURES.items():
        assert mod.EXPECT in CODES, f"{name}: {mod.EXPECT} not in CODES"
        for f in mod.report().findings:
            assert f.code in CODES, f"{name} emitted undocumented {f.code}"


# ---------------------------------------------------------------------------
# 2. specificity: the zoo is clean, and verification is backend-free
# ---------------------------------------------------------------------------


def test_cli_zoo_clean_and_backend_free(tmp_path):
    """``python -m repro.analysis`` over every family and mode completes
    with zero findings — without ever initializing a jax backend (graph
    construction, §8 planning, schedule lowering, and all four passes are
    pure Python over static shapes)."""
    report_path = tmp_path / "report.json"
    snippet = (
        "import sys\n"
        "from repro.analysis.__main__ import main\n"
        f"rc = main(['--json', {str(report_path)!r}])\n"
        "import jax\n"
        "assert not jax._src.xla_bridge._backends, 'backend initialized'\n"
        "sys.exit(rc)\n")
    proc = subprocess.run(
        [sys.executable, "-c", snippet], capture_output=True, text=True,
        env={"PYTHONPATH": "src"}, timeout=300, cwd=str(_REPO))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(report_path.read_text())
    assert payload["n_errors"] == 0 and payload["n_warnings"] == 0, \
        proc.stdout
    # 4 families x prefill/decode + 3 paged (serving families)
    assert len(payload["cells"]) == 11
    for cell in payload["cells"]:
        assert cell["findings"] == []
        assert cell["memory"]["peak_bytes"] > 0


def test_zoo_clean_under_lookahead_schedules():
    """The clean-zoo twin extends to graph-wide lookahead (ISSUE 9): every
    bench family's prefill cell analyzes clean at lookahead=2 (a wider
    window than the executor default), the schedules actually hoist
    (prefetch lifetimes land in the memory report), and the serial
    lookahead=0 lowering stays clean too."""
    from repro.analysis.__main__ import FAMILIES as AFAMS, _cell_program
    from repro.analysis.runner import analyze_program

    for family in AFAMS:
        prog = _cell_program(family, "prefill")
        for la in (0, 2):
            rep = analyze_program(prog, {"data": 2, "model": 4},
                                  lookahead=la)
            assert not rep.findings, f"{family}@{la}:\n{rep.format()}"
            n_pf = rep.memory["n_prefetches"]
            assert n_pf > 0 if la else n_pf == 0, (family, la, n_pf)


def test_cli_list_codes_covers_all_passes():
    from repro.analysis.__main__ import main

    assert main(["--list-codes"]) == 0
    prefixes = {c[:3] for c in CODES}
    assert prefixes == {"RA0", "RA1", "RA2", "RA3", "RA4"}
    for code, (sev, desc) in CODES.items():
        assert sev in (ERROR, WARNING) and desc


def test_zoo_clean_under_pipeline_pass():
    """The clean-zoo twin extends to the pipeline tier: every family and
    mode analyzes error-free with the RA4xx pass enabled across the
    (stages, microbatches) grid p in {1, 2} x m in {1, 4}.  The only
    tolerated finding is an RA404 imbalance *warning* — a true statement
    about a model whose weight concentrates in one node (mixtral decode),
    not a defect in the schedule."""
    from repro.analysis.__main__ import FAMILIES as AFAMS, _cell_program
    from repro.analysis.runner import analyze_program
    from repro.pipeline import PipelineSpec

    for family in AFAMS:
        for mode in ("prefill", "decode"):
            prog = _cell_program(family, mode)
            for p in (1, 2):
                for m in (1, 4):
                    spec = PipelineSpec(stages=p, microbatches=m)
                    rep = analyze_program(
                        prog, {"pp": p, "data": 1, "model": 2},
                        pipeline=spec)
                    assert not rep.errors, \
                        f"{family}/{mode} p={p} m={m}:\n{rep.format()}"
                    assert all(f.code == "RA404" for f in rep.warnings), \
                        f"{family}/{mode} p={p} m={m}:\n{rep.format()}"
                    if family == "mixtral-8x7b" and m > 1:
                        assert rep.meta.get("microbatches_clamped") == 1


# ---------------------------------------------------------------------------
# 3. memory honesty: static peak vs XLA's memory_analysis
# ---------------------------------------------------------------------------


def test_memory_report_matches_xla_within_10pct():
    """On a shard_map-executed zoo cell (mixtral prefill over a 2x4 host
    mesh) the static per-device peak agrees with what XLA actually
    allocates — argument + temp + output - alias, per device — within
    10%.  Subprocess because the device count must be forced before jax
    initializes."""
    snippet = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n"
        "import numpy as np\n"
        "import jax\n"
        "from jax.sharding import Mesh, NamedSharding\n"
        "from repro.configs import ShapeConfig, get_config, reduced\n"
        "from repro.models.eingraphs import program_for\n"
        "from repro.core.spmd import _pspec, build_schedule\n"
        "from repro.core.engine import mesh_axes_dict\n"
        "from repro.analysis import analyze_compiled\n"
        "cfg = reduced(get_config('mixtral-8x7b'))\n"
        "prog = program_for(cfg, ShapeConfig('t', 'prefill', 32, 4))\n"
        "mesh = Mesh(np.array(jax.devices()).reshape(2, 4),"
        " ('data', 'model'))\n"
        "compiled = prog.compile(mesh=mesh, executor='shard_map')\n"
        "g = compiled.program.graph\n"
        "sched = build_schedule(g, compiled.plan, mesh_axes_dict(mesh),\n"
        "    [compiled.program._out[k] for k in compiled.program._out])\n"
        "structs = [jax.ShapeDtypeStruct(g.nodes[i].shape,"
        " g.nodes[i].dtype,\n"
        "    sharding=NamedSharding(mesh, _pspec(sched.layouts[i])))\n"
        "    for i in g.input_ids()]\n"
        "ma = compiled._fn.lower(*structs).compile().memory_analysis()\n"
        "measured = (ma.argument_size_in_bytes + ma.temp_size_in_bytes\n"
        "            + ma.output_size_in_bytes - ma.alias_size_in_bytes)\n"
        "peak = analyze_compiled(compiled).memory['peak_bytes']\n"
        "ratio = peak / measured\n"
        "print('measured', measured, 'peak', peak, 'ratio', ratio)\n"
        "assert abs(ratio - 1.0) <= 0.10, (measured, peak, ratio)\n")
    import os

    # full parent env (PATH & co): XLA's compile path needs more than
    # PYTHONPATH — a bare env stalls the CPU client for minutes
    env = {**os.environ, "PYTHONPATH": "src"}
    env.pop("XLA_FLAGS", None)  # the snippet forces its own device count
    proc = subprocess.run(
        [sys.executable, "-c", snippet], capture_output=True, text=True,
        env=env, timeout=420, cwd=str(_REPO))
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ---------------------------------------------------------------------------
# 4a. Expr-trace source locations
# ---------------------------------------------------------------------------


def test_expr_srcloc_survives_into_graph_nodes():
    x = ein.tensor("x", "b s", (2, 4))
    y = ein.einsum("b s -> b", x, combine="id", agg="sum")  # pinned line
    prog = ein.Program({"y": y})
    node = prog.graph.nodes[prog._out["y"]]
    assert node.srcloc.startswith(str(Path(__file__)))
    # the recorded line is the einsum call above, not frontend internals
    line = int(node.srcloc.rsplit(":", 1)[1])
    src = Path(__file__).read_text().splitlines()
    assert "pinned line" in src[line - 1]


def test_srcloc_lands_in_findings():
    g = EinGraph("loc")
    x = g.input("x", "a", (8,))
    nid = g.opaque("totally_unknown_op", [x], "a", (8,),
                   in_labels=[("a",)], name="mystery")
    g.nodes[nid].srcloc = "model.py:42"
    report = analyze(g)
    bad = [f for f in report.findings if f.code == "RA005"]
    assert bad and "model.py:42" in bad[0].format()


# ---------------------------------------------------------------------------
# 4b. deterministic resolve_feeds / EinSpec diagnostics
# ---------------------------------------------------------------------------


def _two_input_graph():
    g = EinGraph("two")
    a = g.input("alpha", "ij", (4, 8))
    b = g.input("beta", "jk", (8, 2))
    g.einsum("ij, jk -> ik", a, b)
    return g


def test_resolve_feeds_missing_list_is_sorted():
    g = _two_input_graph()
    with pytest.raises(ValueError, match="missing feeds") as ei:
        resolve_feeds(g, {})
    msg = str(ei.value)
    assert msg.index("alpha") < msg.index("beta")
    # deterministic regardless of dict insertion order
    with pytest.raises(ValueError) as ei2:
        resolve_feeds(g, {})
    assert str(ei2.value) == msg


def test_program_missing_feeds_sorted():
    x = ein.tensor("zz", "i", (4,))
    y = ein.tensor("aa", "i", (4,))
    run = ein.Program({"s": x + y}).compile(jit=False)
    with pytest.raises(ValueError, match="missing feeds") as ei:
        run({})
    msg = str(ei.value)
    assert msg.index("aa") < msg.index("zz")


def test_einspec_errors_name_the_offending_spec():
    with pytest.raises(ValueError, match=re.escape("'i j, j k -> i q'")):
        EinSpec((("i", "j"), ("j", "k")), ("i", "q"), "mul", "sum")
    with pytest.raises(ValueError, match=re.escape("->")):
        EinSpec((("i", "j"),), ("i", "i"), "id", "")


# ---------------------------------------------------------------------------
# 4c. OpDef VJP-completeness (lint twin of the ruff TID251 ban)
# ---------------------------------------------------------------------------


def test_every_opdef_is_vjp_complete():
    """Every registered OpDef either participates in autodiff (a vjp rule,
    or a map-category grad) or carries an explicit ``vjp_reason`` string
    saying why not — no silently non-differentiable ops."""
    incomplete = []
    for kind in opdef.list_ops():
        od = opdef.require(kind)
        if od.vjp is not None:
            continue
        if od.category == "map" and od.grad is not None:
            continue
        if od.vjp_reason:
            continue
        incomplete.append(kind)
    assert not incomplete, (
        "OpDefs with neither a VJP path nor a vjp_reason (declare one via "
        f"defop(..., vjp_reason='...')): {sorted(incomplete)}")


def test_registry_ban_is_configured():
    """pyproject's TID251 list bans direct access to the unified registry
    dict itself (`repro.core.opdef._REGISTRY`) alongside the legacy
    views — the grep twin in test_opdef enforces it where ruff isn't
    installed."""
    text = (_REPO / "pyproject.toml").read_text()
    assert '"repro.core.opdef._REGISTRY"' in text


# ---------------------------------------------------------------------------
# launch / serving hooks
# ---------------------------------------------------------------------------


def test_bucket_registry_analyze_hook():
    """BucketRegistry.analyze() re-verifies every live bucket's compiled
    cell — backend-free, clean on real serving cells (prefill bucket +
    paged decode)."""
    import jax
    from jax.sharding import Mesh

    from repro.configs import get_config, reduced
    from repro.serving.buckets import BucketRegistry

    cfg = reduced(get_config("llama-7b"))
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    reg = BucketRegistry(cfg, mesh)
    reg.prefill(16, batch=2)
    reg.decode(16, 2, 8)
    reports = reg.analyze()
    assert len(reports) == 2
    for key, rep in reports.items():
        assert not rep.findings, f"{key}:\n{rep.format()}"
        assert rep.memory["peak_bytes"] > 0
    # an HBM bound below the paged pool turns into RA301 findings
    tight = reg.analyze(max_hbm=64)
    assert any(r.has_errors and "RA301" in r.codes()
               for r in tight.values())


def test_dryrun_records_analysis_verdict():
    """launch.dryrun attaches the static-analysis verdict to each cell
    record (counts + codes + peak bytes), without failing the sweep."""
    import jax
    from jax.sharding import Mesh

    from repro.configs import ShapeConfig, get_config, reduced
    from repro.launch.dryrun import _static_analysis
    from repro.models.eingraphs import program_for

    cfg = reduced(get_config("llama-7b"))
    shape = ShapeConfig("t", "prefill", 32, 4)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    from repro.core.engine import mesh_axes_dict
    from repro.core.decomp import eindecomp

    g = program_for(cfg, shape).graph
    plan = eindecomp(g, 1, mesh_axes=mesh_axes_dict(mesh))
    rec = _static_analysis(cfg, shape, mesh, plan)
    assert rec["n_errors"] == 0 and rec["n_warnings"] == 0
    assert rec["codes"] == [] and rec["peak_bytes_per_dev"] > 0


# ---------------------------------------------------------------------------
# findings plumbing
# ---------------------------------------------------------------------------


def test_finding_defaults_and_report_json():
    f = Finding("RA001", "dead node")
    assert f.severity == WARNING  # default severity comes from CODES
    r = Report()
    r.add(f)
    r.add(Finding("RA102", "bad parts", nid=3, node="mm"))
    assert r.has_errors and len(r.warnings) == 1
    payload = r.to_json()
    assert payload["n_errors"] == 1
    assert {d["code"] for d in payload["findings"]} == {"RA001", "RA102"}


def test_unknown_code_rejected():
    with pytest.raises(ValueError, match="RA999"):
        Finding("RA999", "no such pass")


def test_analyze_graph_only_smoke():
    g = _two_input_graph()
    report = analyze(g)
    assert not report.findings
    dead = g.input("unused", "q", (3,))
    report = analyze(g, out_ids=[o for o in g.outputs() if o != dead])
    assert "RA001" in report.codes() and not report.has_errors
