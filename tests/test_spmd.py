"""Explicit-collective shard_map executor (core/spmd.py).

Three layers of coverage:

1. **Schedule unit tests** — ``build_schedule``/``plan_repart`` are pure
   functions of (graph, plan, mesh shape), so collective-kind assertions
   (all_to_all detection, ppermute swaps, psum_scatter fusion, and the
   "an unsharded plan emits zero collectives" invariant) run on any host,
   no devices needed.

2. **Execution equivalence** — shard_map vs the GSPMD engine vs the dense
   oracle vs the TRA reference runtime on small graphs, randomized property
   graphs, and the model-zoo eingraphs, on whatever host mesh exists.  The
   multi-device CI job re-runs this file under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so every
   collective path is exercised on real device groups each PR.

3. **Cost accounting** — traced wire floats stay within the §7 ``plan_cost``
   upper bound (the property ``bench_spmd.py`` reports for the model zoo).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import engine, spmd
from repro.core.decomp import Plan, eindecomp, plan_cost
from repro.core.einsum import EinGraph, eval_graph_dense
from repro.core.tra import execute_graph_tra
from repro.launch.mesh import make_host_mesh
from repro.models.eingraphs import program_for

RNG = np.random.default_rng(0)
N_DEV = len(jax.devices())


def _feeds(g, scale=0.1):
    out = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if str(np.dtype(n.dtype)) == "int32":
            out[n.nid] = RNG.integers(0, max(n.shape[-1], 2),
                                      size=n.shape).astype(np.int32)
        else:
            out[n.nid] = (RNG.normal(size=n.shape) * scale).astype(np.float32)
    return out


def _mlp_graph():
    g = EinGraph("mlp")
    x = g.input("x", "b a", (8, 16))
    w1 = g.input("w1", "a f", (16, 32))
    w2 = g.input("w2", "f c", (32, 8))
    h = g.einsum("b a, a f -> b f", x, w1)
    h = g.map("relu", h)
    y = g.einsum("b f, f c -> b c", h, w2)
    return g, y


# ---------------------------------------------------------------------------
# 1. schedule unit tests (device-free)
# ---------------------------------------------------------------------------


def test_plan_repart_all_to_all():
    steps = spmd.plan_repart((("model",), ()), ((), ("model",)))
    assert steps == [("all_to_all", "model", 0, 1)]


def test_plan_repart_gather_then_slice():
    # model must leave dim 0 *and* data must arrive there: no pure move
    steps = spmd.plan_repart((("model",), ("data",)), (("data",), ()))
    kinds = [s[0] for s in steps]
    assert "all_gather" in kinds and "slice" in kinds


def test_plan_repart_ppermute_swap():
    steps = spmd.plan_repart((("data",), ()), (("model",), ()))
    assert steps == [("ppermute", "data", "model", 0)]


def test_plan_repart_ppermute_size_mismatch_falls_back():
    steps = spmd._plan_repart_sized((("data",), ()), (("model",), ()),
                                    {"data": 2, "model": 4})
    assert [s[0] for s in steps] == ["all_gather", "slice"]


def test_plan_repart_nested_axes_roundtrip():
    src = (("data", "model"), ())
    dst = (("data",), ("model",))
    steps = spmd.plan_repart(src, dst)
    # minor axis moves off dim 0 onto dim 1: a single all_to_all
    assert steps == [("all_to_all", "model", 0, 1)]
    # and the reverse direction comes home too
    back = spmd.plan_repart(dst, src)
    assert back == [("all_to_all", "model", 1, 0)]


def test_plan_repart_identity_is_empty():
    assert spmd.plan_repart((("data",), ()), (("data",), ())) == []


def test_unsharded_plan_emits_zero_collectives():
    """The all-``None`` plan — no label mapped to a >1 mesh axis — must
    lower to a schedule with no collectives at all."""
    g, y = _mlp_graph()
    plan = eindecomp(g, 1, mesh_axes={"data": 1, "model": 1})
    sched = spmd.build_schedule(g, plan, {"data": 1, "model": 1}, [y])
    assert len(sched.trace) == 0, sched.trace.summary()
    # empty axes_by_node entirely (plan missing axes) behaves the same
    bare = Plan(p=1, mode="mesh")
    bare.d_by_node = {n.nid: {l: 1 for l in n.labels} for n in g.nodes}
    sched2 = spmd.build_schedule(g, bare, {"data": 1, "model": 1}, [y])
    assert len(sched2.trace) == 0


def test_schedule_contraction_emits_psum():
    g, y = _mlp_graph()
    plan = eindecomp(g, 8, mesh_axes={"data": 2, "model": 4})
    sched = spmd.build_schedule(g, plan, {"data": 2, "model": 4}, [y])
    counts = sched.trace.counts
    assert counts.get("psum", 0) + counts.get("psum_scatter", 0) >= 1
    assert sched.trace.total_bytes > 0


def test_schedule_psum_scatter_fusion():
    """When every consumer wants the contracted mesh axis on the same output
    dim, the aggregation fuses to one reduce-scatter."""
    g = EinGraph()
    a = g.input("a", "b f", (8, 16))
    w = g.input("w", "f c", (16, 8))
    z = g.einsum("b f, f c -> b c", a, w)
    out = g.einsum("b c -> b c", z, combine="id", agg="")
    plan = Plan(p=4, mode="mesh")
    plan.d_by_node = {0: {"b": 1, "f": 4}, 1: {"f": 4, "c": 1},
                      2: {"b": 1, "f": 4, "c": 4}, 3: {"b": 1, "c": 4}}
    plan.axes_by_node = {0: {"f": ("model",)}, 1: {"f": ("model",)},
                         2: {"f": ("model",)}, 3: {"c": ("model",)}}
    sched = spmd.build_schedule(g, plan, {"model": 4}, [out])
    kinds = sched.trace.counts
    assert kinds == {"psum_scatter": 1}, kinds
    # the scattered layout rides to the consumer: no extra repartition
    assert sched.layouts[2] == ((), ("model",))


def test_schedule_opaque_gathers_then_reslices():
    g = EinGraph()
    t = g.input("table", "v a", (16, 8))
    i = g.input("ids", "b", (4,), dtype=np.int32)
    o = g.opaque("gather_rows", [t, i], "b a", (4, 8),
                 in_labels=[("v", "a"), ("b",)], shardable={"b", "a"})
    plan = Plan(p=4, mode="mesh")
    plan.d_by_node = {0: {"v": 1, "a": 4}, 1: {"b": 1}, 2: {"b": 1, "a": 4}}
    plan.axes_by_node = {0: {"a": ("model",)}, 1: {}, 2: {"a": ("model",)}}
    sched = spmd.build_schedule(g, plan, {"model": 4}, [o])
    assert sched.trace.counts == {"all_gather": 1}
    # output re-sliced to the plan layout, locally (free)
    assert sched.layouts[2] == ((), ("model",))


def test_trace_summary_and_aggregates():
    g, y = _mlp_graph()
    plan = eindecomp(g, 8, mesh_axes={"data": 2, "model": 4})
    sched = spmd.build_schedule(g, plan, {"data": 2, "model": 4}, [y])
    tr = sched.trace
    assert sum(tr.counts.values()) == len(tr)
    assert sum(tr.bytes_by_kind.values()) == tr.total_bytes
    assert "collectives" in tr.summary()


def test_traced_wire_elems_within_plan_cost_bound():
    """Ring-priced traced movement must not exceed the §7 p2p upper bound
    the DP optimized (the bench_spmd acceptance property)."""
    g, y = _mlp_graph()
    for axes in ({"data": 2, "model": 4}, {"data": 4, "model": 2},
                 {"data": 8, "model": 1}):
        plan = eindecomp(g, 8, mesh_axes=axes)
        sched = spmd.build_schedule(g, plan, axes, [y])
        predicted = plan_cost(g, plan)
        assert sched.trace.total_elems <= predicted, (
            axes, sched.trace.total_elems, predicted)


# ---------------------------------------------------------------------------
# 2. execution equivalence
# ---------------------------------------------------------------------------


def _compare_executors(g, out_ids, plan, mesh, feeds, *, atol=1e-5):
    """shard_map vs GSPMD vs dense oracle on one planned graph."""
    in_ids = g.input_ids()
    args = [feeds[i] for i in in_ids]
    tr = spmd.CollectiveTrace()
    f_spmd = jax.jit(engine.make_runner(
        g, out_ids, plan=plan, mesh=mesh, executor="shard_map",
        collective_trace=tr))
    f_gspmd = jax.jit(engine.make_runner(g, out_ids, plan=plan, mesh=mesh))
    outs_s = f_spmd(*args)
    outs_g = f_gspmd(*args)
    if len(out_ids) == 1:
        outs_s, outs_g = (outs_s,), (outs_g,)
    dense = eval_graph_dense(g, feeds)
    for o, vs, vg in zip(out_ids, outs_s, outs_g):
        np.testing.assert_allclose(np.asarray(vs), np.asarray(vg),
                                   rtol=1e-5, atol=atol,
                                   err_msg=f"shard_map vs gspmd at node {o}")
        np.testing.assert_allclose(np.asarray(vs), dense[o],
                                   rtol=1e-4, atol=atol,
                                   err_msg=f"shard_map vs dense at node {o}")
    return tr


def test_mlp_equivalence_all_executors():
    g, y = _mlp_graph()
    mesh = make_host_mesh((2, 4))
    axes = engine.mesh_axes_dict(mesh)
    plan = eindecomp(g, math.prod(axes.values()), mesh_axes=axes)
    tr = _compare_executors(g, [y], plan, mesh, _feeds(g))
    if N_DEV >= 8:
        assert len(tr) > 0  # a sharded contraction must move something


def test_softmax_attention_style_graph_equivalence():
    """Non-contraction combine/agg forms (max-agg, expsub, div) through the
    executor — the paper's §3 softmax composite."""
    g = EinGraph("softmax")
    x = g.input("X", "i j", (8, 16))
    c = g.einsum("i j -> i", x, combine="id", agg="max")
    e = g.einsum("i j, i -> i j", x, c, combine="expsub", agg="")
    s = g.einsum("i j -> i", e, combine="id", agg="sum")
    y = g.einsum("i j, i -> i j", e, s, combine="div", agg="")
    mesh = make_host_mesh((2, 4))
    axes = engine.mesh_axes_dict(mesh)
    plan = eindecomp(g, math.prod(axes.values()), mesh_axes=axes)
    feeds = _feeds(g, scale=1.0)
    _compare_executors(g, [y], plan, mesh, feeds)
    # cross-check against jax softmax
    f = jax.jit(engine.make_runner(g, [y], plan=plan, mesh=mesh,
                                   executor="shard_map"))
    np.testing.assert_allclose(
        np.asarray(f(feeds[0])), jax.nn.softmax(feeds[0], axis=-1),
        rtol=1e-5, atol=1e-6)


def test_grad_program_equivalence():
    """The backward EinGraph (broadcast_to opaques, accum adds) runs through
    the explicit-collective executor and matches jax.grad."""
    from repro import frontend as ein

    x = ein.tensor("x", "b a", (8, 16))
    w = ein.tensor("w", "a f", (16, 32))
    y = ein.einsum("b a, a f -> b f", x, w).map("relu")
    loss = ein.einsum("b f ->", y, combine="id", agg="sum")
    prog = ein.Program({"loss": loss}).grad("w")
    mesh = make_host_mesh((2, 4))
    run = prog.compile(mesh=mesh, executor="shard_map")
    X = (RNG.normal(size=(8, 16))).astype(np.float32)
    W = (RNG.normal(size=(16, 32)) * 0.1).astype(np.float32)
    got = run({"x": X, "w": W})["grad_w"]

    def ref(w):
        return jnp.sum(jnp.maximum(X @ w, 0))

    want = jax.grad(ref)(W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.skipif(N_DEV < 4, reason="ppermute swap needs a 2x2 mesh")
def test_ppermute_swap_executes_correctly():
    """Equal-size axis swap runs the real lax.ppermute (the (2,4) meshes
    elsewhere always demote it to gather+slice) — pins the linearized
    (ax_old, ax_new) perm construction at runtime."""
    g = EinGraph()
    x = g.input("x", "b f", (8, 16))
    h = g.einsum("b f -> b f", x, combine="id", agg="")
    y = g.einsum("b f -> b f", h, combine="id", agg="")
    plan = Plan(p=4, mode="mesh")
    plan.d_by_node = {0: {"b": 2}, 1: {"b": 2}, 2: {"b": 2}}
    plan.axes_by_node = {0: {"b": ("data",)}, 1: {"b": ("data",)},
                         2: {"b": ("model",)}}
    mesh = make_host_mesh((2, 2))
    tr = spmd.CollectiveTrace()
    fn = jax.jit(engine.make_runner(g, [y], plan=plan, mesh=mesh,
                                    executor="shard_map",
                                    collective_trace=tr))
    X = RNG.normal(size=(8, 16)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(fn(X)), X)
    assert tr.counts == {"ppermute": 1}, tr.counts


def test_tra_oracle_agreement():
    """shard_map and the literal §4.3 TRA reference runtime execute the same
    plan to the same result."""
    g, y = _mlp_graph()
    mesh = make_host_mesh((2, 4))
    axes = engine.mesh_axes_dict(mesh)
    plan = eindecomp(g, math.prod(axes.values()), mesh_axes=axes)
    feeds = _feeds(g)
    f = jax.jit(engine.make_runner(g, [y], plan=plan, mesh=mesh,
                                   executor="shard_map"))
    got = np.asarray(f(*[feeds[i] for i in g.input_ids()]))
    vals, _ = execute_graph_tra(g, plan.d_by_node, feeds)
    np.testing.assert_allclose(got, vals[y].to_dense(), rtol=1e-4, atol=1e-5)


def _random_graph(rng):
    """A random 3–6 node EinGraph over a small label pool (bounds all 8 so
    every pow2/mesh partitioning divides)."""
    pool = ["i", "j", "k", "l"]
    g = EinGraph("prop")
    n_in = int(rng.integers(2, 4))
    nodes = []
    for t in range(n_in):
        nl = int(rng.integers(1, 4))
        labels = list(rng.choice(pool, size=nl, replace=False))
        nodes.append(g.input(f"in{t}", labels, [8] * nl))
    for _ in range(int(rng.integers(1, 4))):
        a = int(rng.choice(nodes))
        b = int(rng.choice(nodes))
        la, lb = g.nodes[a].labels, g.nodes[b].labels
        union = list(dict.fromkeys(la + lb))
        keep = [l for l in union if rng.random() < 0.6] or [union[0]]
        expr = f"{' '.join(la)}, {' '.join(lb)} -> {' '.join(keep)}"
        try:
            nodes.append(g.einsum(expr, a, b))
        except ValueError:
            continue
        if rng.random() < 0.3:
            nodes.append(g.map("relu", nodes[-1]))
    return g


@pytest.mark.parametrize("seed", range(8))
def test_randomized_property_graphs(seed):
    rng = np.random.default_rng(seed)
    g = _random_graph(rng)
    outs = g.outputs()
    mesh = make_host_mesh((2, 4))
    axes = engine.mesh_axes_dict(mesh)
    plan = eindecomp(g, math.prod(axes.values()), mesh_axes=axes)
    _compare_executors(g, outs, plan, mesh, _feeds(g))


# ---------------------------------------------------------------------------
# model zoo: shard_map vs GSPMD vs dense on every migrated family
# ---------------------------------------------------------------------------


@pytest.fixture()
def _stub_opaques(monkeypatch):
    """graph -> registers the shared deterministic opaque stand-ins
    (repro.models.opaque_stubs) for the test's lifetime."""
    from repro.models.opaque_stubs import capacity_of, make_stub_opaques

    def apply(g):
        for kind, fn in make_stub_opaques(capacity_of(g)).items():
            monkeypatch.setitem(engine.OPAQUE_FNS, kind, fn)

    return apply


@pytest.mark.parametrize("arch", ["llama-7b", "mixtral-8x7b", "xlstm-125m",
                                  "hymba-1.5b"])
def test_model_zoo_shard_map_matches_gspmd(_stub_opaques, arch):
    cfg = reduced(get_config(arch))
    shape = ShapeConfig("eq", "prefill", 8, 2)
    prog = program_for(cfg, shape)
    g = prog.graph
    _stub_opaques(g)
    mesh = make_host_mesh((2, 4))
    feeds = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if str(np.dtype(n.dtype)) == "int32":
            feeds[n.name] = RNG.integers(0, cfg.vocab,
                                         size=n.shape).astype(np.int32)
        else:
            feeds[n.name] = (RNG.normal(size=n.shape) * 0.05).astype(
                np.float32)
    out_g = prog.compile(mesh=mesh)(feeds)["logits"]
    run_s = prog.compile(mesh=mesh, executor="shard_map")
    out_s = run_s(feeds)["logits"]
    assert run_s.collectives is not None
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_g),
                               rtol=2e-4, atol=2e-4)
    if N_DEV >= 8:
        # a real mesh must shard *something* in these cells
        assert run_s.plan.axes_by_node


# ---------------------------------------------------------------------------
# 3. wiring / validation
# ---------------------------------------------------------------------------


def test_make_runner_shard_map_self_plans_from_bare_mesh():
    """A bare mesh self-plans under shard_map (the executor cannot run
    unplanned, unlike gspmd which would just drop the constraints)."""
    g, y = _mlp_graph()
    mesh = make_host_mesh((2, 4))
    fn = jax.jit(engine.make_runner(g, [y], mesh=mesh, executor="shard_map"))
    feeds = _feeds(g)
    got = np.asarray(fn(*[feeds[i] for i in g.input_ids()]))
    np.testing.assert_allclose(got, eval_graph_dense(g, feeds)[y],
                               rtol=1e-4, atol=1e-5)


def test_make_runner_rejects_bad_executor():
    g, y = _mlp_graph()
    with pytest.raises(ValueError, match="unknown executor"):
        engine.make_runner(g, [y], executor="mpi")


def test_make_runner_shard_map_requires_mesh_mode_plan():
    g, y = _mlp_graph()
    mesh = make_host_mesh((1, 1))
    with pytest.raises(ValueError, match="shard_map"):
        engine.make_runner(g, [y], executor="shard_map")  # no mesh/plan
    plan = eindecomp(g, 4)  # pow2 mode: no axes
    with pytest.raises(ValueError, match="mesh-mode"):
        engine.make_runner(g, [y], plan=plan, mesh=mesh,
                           executor="shard_map")


def test_collective_trace_requires_shard_map():
    g, y = _mlp_graph()
    with pytest.raises(ValueError, match="collective_trace"):
        engine.make_runner(g, [y], collective_trace=spmd.CollectiveTrace())


def test_program_compile_shard_map_requires_mesh():
    from repro import frontend as ein

    x = ein.tensor("x", "a", (8,))
    with pytest.raises(ValueError, match="mesh"):
        ein.Program({"y": x.map("relu")}).compile(
            mesh_axes={"data": 2}, executor="shard_map")


def test_program_compile_rejects_unknown_executor():
    from repro import frontend as ein

    x = ein.tensor("x", "a", (8,))
    with pytest.raises(ValueError, match="unknown executor"):
        ein.Program({"y": x.map("relu")}).compile(p=2, executor="nope")
