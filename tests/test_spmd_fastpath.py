"""Executor fast path (fused repartition chains, buffer donation,
double-buffered ring overlap) — the harness that makes executor rewrites
safe.

Every fast-path transformation rewrites an already-priced schedule, so the
properties pinned here are exactly the ones a rewrite could silently break:

1. **Fused planner, device-free** — ``plan_repart_fused`` reaches the same
   layout as the unfused PR-3 chain on randomized (src, dst) pairs, the
   specific zoo chains collapse as designed (gather+re-slice → all_to_all),
   and ``plan_repart_best`` never moves more wire elems than the unfused
   chain.  Across the full model zoo (prefill + decode) the fused schedule
   is ≤ the unfused one in total *and per node*, and every ring/a2a/local
   opaque node stays within ``decomp.opaque_node_bound``.

2. **Execution equivalence** — fused vs unfused lowering is bit-identical
   (the fused steps are pure data-movement rewrites: no arithmetic changes)
   on random EinGraphs and the zoo; the double-buffered ring matches the
   serial ring bit-for-bit (only the collective issue order changes) and
   its hops carry the ``overlap`` trace mark.

3. **Donation** — a runner compiled with ``donate`` produces identical
   outputs, exposes its ``donate_argnums``, and the
   zero-collectives-on-unsharded-plan invariant survives donation.

4. **Cost-honesty trajectory** — the per-family predicted/traced ratio
   (deterministic: paper-mode plan + static schedule,
   ``repro.launch.trajectory``) is pinned against the committed
   BENCH_spmd.json.  Intentional changes update the file with
   ``REPRO_UPDATE_RATIOS=1 pytest tests/test_spmd_fastpath.py``.
"""
import json
import math
import os
import warnings
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import engine, spmd
from repro.core.decomp import Plan, eindecomp, opaque_node_bound
from repro.core.einsum import EinGraph, eval_graph_dense
from repro.launch.mesh import make_host_mesh
from repro.launch.trajectory import FAMILIES, MESH_AXES, family_ratio
from repro.models.eingraphs import program_for

RNG = np.random.default_rng(0)
N_DEV = len(jax.devices())
REPO_ROOT = Path(__file__).resolve().parent.parent
BENCH_JSON = REPO_ROOT / "BENCH_spmd.json"

# donation on the CPU backend is accepted but unimplemented (warns once)
warnings.filterwarnings("ignore", message=".*[Dd]onat")

AXES_POOL = ("data", "model")
SIZES = {"data": 2, "model": 4}


def _feeds(g, scale=0.1):
    out = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if str(np.dtype(n.dtype)) == "int32":
            out[n.nid] = RNG.integers(0, max(n.shape[-1], 2),
                                      size=n.shape).astype(np.int32)
        else:
            out[n.nid] = (RNG.normal(size=n.shape) * scale).astype(np.float32)
    return out


def _random_layout_pair(rng, sizes):
    """Random (src, dst) layouts of one tensor: each mesh axis shards at
    most one dim per side, shape divisible by every assignment."""
    rank = int(rng.integers(1, 4))
    lays = []
    for _ in range(2):
        lay = [[] for _ in range(rank)]
        for ax in rng.permutation(list(sizes)):
            if rng.random() < 0.6:
                lay[int(rng.integers(rank))].append(str(ax))
        lays.append(tuple(tuple(t) for t in lay))
    shape = tuple(int(math.prod(sizes.values())) * 2 for _ in range(rank))
    return lays[0], lays[1], shape


# ---------------------------------------------------------------------------
# 1. fused planner, device-free
# ---------------------------------------------------------------------------


def test_fused_lm_head_chain_collapses_to_all_to_all():
    """The zoo's lm_head repartition: gather+gather+slice fuses so the
    (data) gather+slice pair becomes one all_to_all at 1/k the wire."""
    src = (("model",), (), ("data",))
    dst = (("data",), (), ())
    assert spmd.plan_repart_fused(src, dst, SIZES) == [
        ("all_gather", "model", 0), ("all_to_all", "data", 2, 0)]
    loc = spmd.local_shape((64, 8, 64), src, SIZES)
    steps, fused = spmd.plan_repart_best(src, dst, SIZES, loc, 8)
    assert fused and steps[1][0] == "all_to_all"


def test_fused_dispatch_chain_collapses_to_double_all_to_all():
    """The mixtral dispatch arg chain — two axes landing stacked on one
    dim — fuses to two all_to_alls, no gather at all (the relaxed landing
    condition: an axis may arrive as the *next* prefix element)."""
    src = (("model",), (), ("data",), ())
    dst = ((), ("data", "model"), (), ())
    steps = spmd.plan_repart_fused(src, dst, SIZES)
    assert steps == [("all_to_all", "data", 2, 1),
                     ("all_to_all", "model", 0, 1)]


def test_fused_planner_identity_and_rank_mismatch():
    assert spmd.plan_repart_fused((("data",), ()), (("data",), ()),
                                  SIZES) == []
    with pytest.raises(ValueError, match="rank mismatch"):
        spmd.plan_repart_fused((("data",),), ((), ()), SIZES)


@pytest.mark.parametrize("seed", range(12))
def test_random_layout_pairs_fused_never_worse(seed):
    """plan_repart_best reaches the same final layout as the unfused chain
    at no more wire elems, on randomized layout pairs."""
    rng = np.random.default_rng(100 + seed)
    n_dev = math.prod(SIZES.values())
    for _ in range(40):
        src, dst, shape = _random_layout_pair(rng, SIZES)
        loc = spmd.local_shape(shape, src, SIZES)
        unfused = spmd._plan_repart_sized(src, dst, SIZES)
        best, fused_flag = spmd.plan_repart_best(src, dst, SIZES, loc, n_dev)
        cu = spmd._chain_wire_elems(unfused, loc, SIZES, n_dev)
        cb = spmd._chain_wire_elems(best, loc, SIZES, n_dev)
        assert cb <= cu, (src, dst, best, unfused)
        if fused_flag:
            assert best != unfused
        # both chains must land on the same local shape (= dst layout)
        want = spmd.local_shape(shape, dst, SIZES)
        got = loc
        for st in best:
            got = spmd._step_shape(got, st, SIZES)
        assert got == want, (src, dst, best)


def _zoo_schedules(arch, phase, fuse):
    from repro.models.opaque_stubs import capacity_of, make_stub_opaques

    cfg = reduced(get_config(arch))
    prog = program_for(cfg, ShapeConfig("bench", phase, 32, 4))
    g = prog.graph
    make_stub_opaques(capacity_of(g))
    plan = eindecomp(g, math.prod(MESH_AXES.values()), mesh_axes=MESH_AXES,
                     offpath_repart=True)
    out_ids = [prog._out[k] for k in prog._out]
    return g, plan, spmd.build_schedule(g, plan, MESH_AXES, out_ids,
                                        fuse=fuse)


@pytest.mark.parametrize("phase", ["prefill", "decode"])
@pytest.mark.parametrize("arch", list(FAMILIES))
def test_zoo_fused_schedule_static_bounds(arch, phase):
    """Across the full zoo: fused ≤ unfused wire elems in total AND per
    node (the fusion-replaced steps are never double-counted — satellite
    fix), and every ruled opaque node stays within its declared bound."""
    g, plan, fused = _zoo_schedules(arch, phase, fuse=True)
    _, _, unfused = _zoo_schedules(arch, phase, fuse=False)
    ft, ut = fused.trace, unfused.trace
    assert ft.total_elems <= ut.total_elems, (ft.total_elems, ut.total_elems)
    fb, ub = ft.elems_by_node, ut.elems_by_node
    for nid in set(fb) | set(ub):
        assert fb.get(nid, 0) <= ub.get(nid, 0), (
            f"{arch}/{phase} node {nid}: fused {fb.get(nid, 0):,} > "
            f"unfused {ub.get(nid, 0):,} — fused events must be attributed "
            "to the originating (d_from, d_to) pair only")
    # per-event accounting is complete: per-node sums == the total
    assert sum(fb.values()) == ft.total_elems
    for n in g.nodes:
        if n.kind != "opaque":
            continue
        if not plan.axes_by_node.get(n.nid):
            # fully replicated consumer: the §7 p2p edge price assumes one
            # consumer site, but gathering to full replication fans out to
            # every device — the bound only speaks for sharded nodes (the
            # same scope bench_spmd --check asserts)
            continue
        if ft.rule_by_node.get(n.nid) in ("ring", "a2a", "local"):
            bound = opaque_node_bound(g, plan, n.nid)
            assert fb.get(n.nid, 0) <= bound, (
                f"{arch}/{phase}/{n.name}: {fb.get(n.nid, 0):,} over "
                f"opaque_node_bound {bound:,}")


def test_fuse_off_restores_unfused_lowering():
    """fuse=False reproduces the PR-3 per-step chains verbatim — the
    baseline the equivalence suite diffs against stays available."""
    g, plan, sched = _zoo_schedules("llama-7b", "prefill", fuse=False)
    layouts = {}
    for p in sched.programs:
        n = g.nodes[p.nid]
        if n.kind == "einsum":
            for ls, a, steps in zip(n.spec.in_labels, n.inputs, p.arg_steps):
                req = tuple(
                    spmd._norm_axes(plan.axes_by_node.get(p.nid, {})
                                    .get(l, ()), SIZES) for l in ls)
                assert steps == spmd._plan_repart_sized(layouts[a], req,
                                                        SIZES)
        layouts[p.nid] = p.layout


# ---------------------------------------------------------------------------
# 2. execution equivalence: fused vs unfused bit-identical
# ---------------------------------------------------------------------------


def _run_fused_and_unfused(g, out_ids, plan, mesh, feeds):
    """(fused outputs, unfused outputs, fused trace, unfused trace)."""
    tf, tu = spmd.CollectiveTrace(), spmd.CollectiveTrace()
    ff = jax.jit(engine.make_runner(g, out_ids, plan=plan, mesh=mesh,
                                    executor="shard_map",
                                    collective_trace=tf))
    fu = jax.jit(engine.make_runner(g, out_ids, plan=plan, mesh=mesh,
                                    executor="shard_map", fuse=False,
                                    collective_trace=tu))
    args = [feeds[i] for i in g.input_ids()]
    of, ou = ff(*args), fu(*args)
    if len(out_ids) == 1:
        of, ou = (of,), (ou,)
    return of, ou, tf, tu


def _random_graph(rng):
    """Random 3–6 node EinGraph over a small label pool (bounds all 8)."""
    pool = ["i", "j", "k", "l"]
    g = EinGraph("prop")
    n_in = int(rng.integers(2, 4))
    nodes = []
    for t in range(n_in):
        nl = int(rng.integers(1, 4))
        labels = list(rng.choice(pool, size=nl, replace=False))
        nodes.append(g.input(f"in{t}", labels, [8] * nl))
    for _ in range(int(rng.integers(1, 4))):
        a = int(rng.choice(nodes))
        b = int(rng.choice(nodes))
        la, lb = g.nodes[a].labels, g.nodes[b].labels
        union = list(dict.fromkeys(la + lb))
        keep = [l for l in union if rng.random() < 0.6] or [union[0]]
        expr = f"{' '.join(la)}, {' '.join(lb)} -> {' '.join(keep)}"
        try:
            nodes.append(g.einsum(expr, a, b))
        except ValueError:
            continue
        if rng.random() < 0.3:
            nodes.append(g.map("relu", nodes[-1]))
    return g


@pytest.mark.parametrize("seed", range(8))
def test_random_graphs_fused_bit_identical(seed):
    rng = np.random.default_rng(seed)
    g = _random_graph(rng)
    outs = g.outputs()
    mesh = make_host_mesh((2, 4))
    axes = engine.mesh_axes_dict(mesh)
    plan = eindecomp(g, math.prod(axes.values()), mesh_axes=axes)
    feeds = _feeds(g)
    of, ou, tf, tu = _run_fused_and_unfused(g, outs, plan, mesh, feeds)
    for o, vf, vu in zip(outs, of, ou):
        np.testing.assert_array_equal(np.asarray(vf), np.asarray(vu),
                                      err_msg=f"node {o}")
    assert tf.total_elems <= tu.total_elems
    # and the fused path still matches the dense oracle
    dense = eval_graph_dense(g, feeds)
    for o, vf in zip(outs, of):
        np.testing.assert_allclose(np.asarray(vf), dense[o],
                                   rtol=1e-4, atol=1e-5)


@pytest.fixture()
def _stub_opaques(monkeypatch):
    from repro.models.opaque_stubs import capacity_of, make_stub_opaques

    def apply(g):
        for kind, fn in make_stub_opaques(capacity_of(g)).items():
            monkeypatch.setitem(engine.OPAQUE_FNS, kind, fn)

    return apply


@pytest.mark.parametrize("phase", ["prefill", "decode"])
@pytest.mark.parametrize("arch", list(FAMILIES))
def test_zoo_fused_bit_identical(_stub_opaques, arch, phase):
    """Full zoo, prefill + decode: the fused executor's logits are
    bit-identical to the unfused executor's (pure movement rewrite)."""
    cfg = reduced(get_config(arch))
    prog = program_for(cfg, ShapeConfig("eq", phase, 8, 2))
    g = prog.graph
    _stub_opaques(g)
    mesh = make_host_mesh((2, 4))
    feeds = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if str(np.dtype(n.dtype)) == "int32":
            feeds[n.name] = RNG.integers(0, cfg.vocab,
                                         size=n.shape).astype(np.int32)
        else:
            feeds[n.name] = (RNG.normal(size=n.shape) * 0.05).astype(
                np.float32)
    run_f = prog.compile(mesh=mesh, executor="shard_map")
    run_u = prog.compile(mesh=mesh, executor="shard_map", fuse=False)
    out_f = run_f(feeds)["logits"]
    out_u = run_u(feeds)["logits"]
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_u))
    assert run_f.collectives.total_elems <= run_u.collectives.total_elems


# ---------------------------------------------------------------------------
# 2b. double-buffered ring: bit-identical, overlap-attributed
# ---------------------------------------------------------------------------

B, H, K, S, D = 2, 4, 2, 32, 16


def _attn_graph(window=0):
    g = EinGraph("ring")
    q = g.input("q", "b h s d", (B, H, S, D))
    k = g.input("k", "b k s d", (B, K, S, D))
    v = g.input("v", "b k s d", (B, K, S, D))
    o = g.opaque(
        "flash_attention", [q, k, v], "b h s d", (B, H, S, D),
        in_labels=[("b", "h", "s", "d"), ("b", "k", "s", "d"),
                   ("b", "k", "s", "d")],
        shardable={"b", "h", "k", "s"},
        comm=[{"kind": "ring", "label": "s", "input": 1, "rule": "ring"},
              {"kind": "ring", "label": "s", "input": 2, "rule": "ring"}],
        window=window)
    return g, o


def _ring_plan(g, axes_cfg, p=8):
    plan = Plan(p=p, mode="mesh")
    for n in g.nodes:
        plan.d_by_node[n.nid] = {l: 1 for l in n.labels}
        plan.axes_by_node[n.nid] = {} if n.kind == "input" else dict(axes_cfg)
    return plan


def test_ring_overlap_trace_marks():
    """The double-buffered ring's K/V hops carry overlap=True; with the
    buffer off they don't — the statically auditable attribution."""
    from repro.core.opaque_rules import RingAttentionRule

    g, o = _attn_graph()
    plan = _ring_plan(g, {"s": ("model",), "b": ("data",)})
    sched = spmd.build_schedule(g, plan, SIZES, [o])
    tr = sched.trace
    assert tr.counts.get("ppermute", 0) == 2 * (4 - 1)
    assert tr.overlap_counts.get("ppermute", 0) == 2 * (4 - 1)
    assert tr.overlapped_elems == tr.elems_by_kind["ppermute"]
    try:
        RingAttentionRule.double_buffer = False
        sched2 = spmd.build_schedule(g, plan, SIZES, [o])
        assert sched2.trace.overlapped_elems == 0
        assert sched2.trace.counts == tr.counts  # same wire, same hops
    finally:
        RingAttentionRule.double_buffer = True


@pytest.mark.parametrize("window", [0, 8])
def test_ring_double_buffer_bit_identical(window):
    """Issue order is the only difference: double-buffered ring output ==
    serial ring output, bit for bit."""
    from repro.core.opaque_rules import RingAttentionRule

    g, o = _attn_graph(window=window)
    mesh = make_host_mesh((2, 4))
    sizes = engine.mesh_axes_dict(mesh)
    plan = _ring_plan(g, {"s": ("model",), "b": ("data",)},
                      p=math.prod(sizes.values()))
    feeds = {n.nid: (RNG.normal(size=n.shape) * 0.3).astype(np.float32)
             for n in g.nodes if n.kind == "input"}
    args = [feeds[i] for i in g.input_ids()]

    fn_db = jax.jit(engine.make_runner(g, [o], plan=plan, mesh=mesh,
                                       executor="shard_map"))
    out_db = np.asarray(fn_db(*args))
    try:
        RingAttentionRule.double_buffer = False
        fn_serial = jax.jit(engine.make_runner(g, [o], plan=plan, mesh=mesh,
                                               executor="shard_map"))
        out_serial = np.asarray(fn_serial(*args))
    finally:
        RingAttentionRule.double_buffer = True
    np.testing.assert_array_equal(out_db, out_serial)
    np.testing.assert_allclose(out_db, eval_graph_dense(g, feeds)[o],
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# 3. buffer donation
# ---------------------------------------------------------------------------


def _mlp_program():
    from repro import frontend as ein

    x = ein.tensor("x", "b a", (8, 16))
    w1 = ein.tensor("w1", "a f", (16, 32))
    w2 = ein.tensor("w2", "f c", (32, 8))
    y = ein.einsum("b a, a f -> b f", x, w1).map("relu")
    return ein.Program({"y": ein.einsum("b f, f c -> b c", y, w2)})


def test_donation_identical_outputs():
    prog = _mlp_program()
    mesh = make_host_mesh((2, 4))
    feeds = {"x": RNG.normal(size=(8, 16)).astype(np.float32),
             "w1": (RNG.normal(size=(16, 32)) * 0.1).astype(np.float32),
             "w2": (RNG.normal(size=(32, 8)) * 0.1).astype(np.float32)}
    run = prog.compile(mesh=mesh, executor="shard_map")
    run_d = prog.compile(mesh=mesh, executor="shard_map", donate=True)
    assert run.donate_argnums == ()
    assert run_d.donate_argnums == (0, 1, 2)
    out = run(feeds)["y"]
    out_d = run_d(feeds)["y"]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_d))
    # numpy feeds are copied to device: a second donating call still works
    np.testing.assert_array_equal(np.asarray(run_d(feeds)["y"]),
                                  np.asarray(out))


def test_donation_by_name_and_errors():
    prog = _mlp_program()
    mesh = make_host_mesh((2, 4))
    run = prog.compile(mesh=mesh, executor="shard_map", donate=["w1", "w2"])
    assert run.donate_argnums == (1, 2)
    with pytest.raises(KeyError, match="unknown inputs"):
        prog.compile(mesh=mesh, executor="shard_map", donate=["nope"])
    with pytest.raises(ValueError, match="jit"):
        prog.compile(mesh=mesh, executor="shard_map", donate=True, jit=False)


def test_donation_gspmd_executor_too():
    """Donation is a jit contract, not a shard_map one — the GSPMD runner
    donates the same way."""
    prog = _mlp_program()
    mesh = make_host_mesh((2, 4))
    feeds = {"x": RNG.normal(size=(8, 16)).astype(np.float32),
             "w1": (RNG.normal(size=(16, 32)) * 0.1).astype(np.float32),
             "w2": (RNG.normal(size=(32, 8)) * 0.1).astype(np.float32)}
    run = prog.compile(mesh=mesh)
    run_d = prog.compile(mesh=mesh, donate=True)
    np.testing.assert_array_equal(np.asarray(run(feeds)["y"]),
                                  np.asarray(run_d(feeds)["y"]))


def test_donation_preserves_zero_collective_invariant():
    """An unsharded plan emits zero collectives — and still does when the
    runner donates its inputs (donation must not change the schedule)."""
    prog = _mlp_program()
    mesh = make_host_mesh((1, 1))
    run_d = prog.compile(mesh=mesh, executor="shard_map", donate=True)
    assert run_d.donate_argnums == (0, 1, 2)
    assert len(run_d.collectives) == 0, run_d.collectives.summary()
    feeds = {"x": RNG.normal(size=(8, 16)).astype(np.float32),
             "w1": (RNG.normal(size=(16, 32)) * 0.1).astype(np.float32),
             "w2": (RNG.normal(size=(32, 8)) * 0.1).astype(np.float32)}
    got = np.asarray(run_d(feeds)["y"])
    want = np.maximum(feeds["x"] @ feeds["w1"], 0) @ feeds["w2"]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# 4. pinned predicted/traced ratio trajectory
# ---------------------------------------------------------------------------

RATIO_TOL = 1e-3  # ratios are deterministic; tolerance covers rounding only


def _recorded_ratios() -> dict[str, float]:
    rows = json.loads(BENCH_JSON.read_text())
    return {r["name"].split("/")[1]: float(r["value"]) for r in rows
            if r["metric"] == "predicted_over_traced"}


@pytest.mark.parametrize("arch", list(FAMILIES))
def test_ratio_trajectory_pinned(arch):
    """The per-family predicted/traced ratio must match the committed
    BENCH_spmd.json trajectory exactly (it is a pure function of the repo:
    paper plan + static schedule).  A *lower* current ratio means the
    executor started moving more than the trajectory records — a
    regression.  A higher one is an improvement that must be recorded:
    rerun with REPRO_UPDATE_RATIOS=1 to update the JSON."""
    current = family_ratio(arch)["ratio"]
    if os.environ.get("REPRO_UPDATE_RATIOS") == "1":
        rows = (json.loads(BENCH_JSON.read_text())
                if BENCH_JSON.exists() else [])
        name = f"spmd/{arch}/ratio"
        rows = [r for r in rows if r["name"] != name]
        rows.append({"name": name, "metric": "predicted_over_traced",
                     "value": current, "unit": "ratio"})
        BENCH_JSON.write_text(json.dumps(rows, indent=1))
        return
    recorded = _recorded_ratios()
    assert arch in recorded, (
        f"no pinned ratio for {arch} in {BENCH_JSON.name} — generate with "
        "REPRO_UPDATE_RATIOS=1 or run benchmarks/bench_spmd.py")
    assert current >= recorded[arch] - RATIO_TOL, (
        f"{arch}: predicted/traced ratio regressed to {current:.4f} "
        f"(pinned {recorded[arch]:.4f}) — the executor moves more wire "
        "elems per predicted elem than the committed trajectory")
    assert current <= recorded[arch] + RATIO_TOL, (
        f"{arch}: ratio improved to {current:.4f} (pinned "
        f"{recorded[arch]:.4f}) — record the new trajectory with "
        "REPRO_UPDATE_RATIOS=1 pytest tests/test_spmd_fastpath.py")
