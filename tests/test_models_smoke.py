"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, output shapes + no NaNs; plus
prefill->decode consistency and recurrent-vs-step equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config, reduced
from repro.configs.base import SMOKE_SHAPE, ShapeConfig
from repro.models import transformer as tf

RNG = np.random.default_rng(0)
B, S = 2, 32


def make_batch(cfg):
    T = S - cfg.prefix_len
    toks = RNG.integers(0, cfg.vocab, size=(B, T)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if cfg.prefix_len:
        batch["prefix_embeds"] = jnp.asarray(
            RNG.normal(size=(B, cfg.prefix_len, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS + ["llama-7b"])
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    logits, _, aux = tf.forward(params, batch["tokens"], cfg,
                                prefix_embeds=batch.get("prefix_embeds"))
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    loss, metrics = tf.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    # random-init loss should be near ln(vocab)
    assert float(metrics["ce"]) < np.log(cfg.vocab) + 2.0

    # one gradient step decreases nothing catastrophic (finite grads)
    grads = jax.grad(lambda p: tf.loss_fn(p, batch, cfg)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = reduced(get_config(arch))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    kv_len = cfg.window if cfg.window else 16
    caches = tf.init_caches(cfg, B, kv_len)
    tok = jnp.asarray(RNG.integers(0, cfg.vocab, size=(B, 1)).astype(np.int32))
    logits, caches2 = tf.decode_step(params, tok, caches, jnp.int32(0), cfg)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", ["yi-9b", "xlstm-125m", "hymba-1.5b",
                                  "mixtral-8x7b"])
def test_prefill_decode_consistency(arch):
    """Teacher-forced decode must reproduce full-forward logits."""
    cfg = reduced(get_config(arch))
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    T = 8
    toks = RNG.integers(0, cfg.vocab, size=(B, T)).astype(np.int32)
    full_logits, _, _ = tf.forward(params, jnp.asarray(toks), cfg,
                                   remat=False)

    kv_len = cfg.window if cfg.window else T
    caches = tf.init_caches(cfg, B, kv_len)
    outs = []
    for t in range(T):
        lg, caches = tf.decode_step(params, jnp.asarray(toks[:, t:t + 1]),
                                    caches, jnp.int32(t), cfg)
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        dec, np.asarray(full_logits, np.float32), rtol=2e-2, atol=2e-2)


def test_sliding_window_ring_buffer_decode():
    """Decode beyond the window: ring buffer must mask out evicted slots."""
    cfg = reduced(get_config("mixtral-8x7b"))
    assert cfg.window == 16
    params = tf.init_params(cfg, jax.random.PRNGKey(2))
    T = 40  # > 2x window
    toks = RNG.integers(0, cfg.vocab, size=(B, T)).astype(np.int32)
    full_logits, _, _ = tf.forward(params, jnp.asarray(toks), cfg,
                                   remat=False)
    caches = tf.init_caches(cfg, B, cfg.window)
    outs = []
    for t in range(T):
        lg, caches = tf.decode_step(params, jnp.asarray(toks[:, t:t + 1]),
                                    caches, jnp.int32(t), cfg)
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        dec, np.asarray(full_logits, np.float32), rtol=3e-2, atol=3e-2)


def test_abstract_init_matches_real_shapes():
    for arch in ("yi-9b", "mixtral-8x7b", "hymba-1.5b", "xlstm-125m"):
        cfg = reduced(get_config(arch))
        real = tf.init_params(cfg, jax.random.PRNGKey(0))
        abstract = tf.init_params(cfg, abstract=True)
        rs = jax.tree.map(lambda x: (x.shape, str(x.dtype)), real)
        as_ = jax.tree.map(lambda x: (x.shape, str(x.dtype)), abstract)
        assert rs == as_


def test_param_labels_cover_params():
    from repro.models.transformer import param_labels

    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch))
        params = tf.init_params(cfg, abstract=True)
        labels = param_labels(cfg)
        jax.tree.map(lambda sds, lab: None, params, labels)  # same structure
        flat_p = jax.tree.leaves(params)
        flat_l = jax.tree.leaves(labels)
        for sds, lab in zip(flat_p, flat_l):
            assert len(lab.split()) == len(sds.shape), (arch, lab, sds.shape)
