"""Numerical equivalence of the two API surfaces for every migrated model
family: the original imperative path (``build_graph`` + ``engine.make_runner``
with positional feeds) and the declarative path (``program_for`` +
``Program.compile`` with name-keyed feeds) must produce **bit-identical**
outputs on a small shape grid.

Opaque kinds without a registered production implementation (MoE dispatch/
combine, recurrent scans) get deterministic shape-correct stand-ins — the
test pins that both surfaces execute the *same* dataflow, not the ops'
numerics (those live in tests/test_models_smoke.py against the real model
stack).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import canon, engine
from repro.models.eingraphs import build_graph, plan_for, program_for

RNG = np.random.default_rng(0)

FAMILIES = ["llama-7b", "mixtral-8x7b", "xlstm-125m", "hymba-1.5b"]
GRID = [(1, 8), (2, 16)]  # (batch, seq)


@pytest.fixture()
def _stub_opaques(monkeypatch):
    """graph -> registers the shared deterministic opaque stand-ins
    (repro.models.opaque_stubs) for the test's lifetime."""
    from repro.models.opaque_stubs import capacity_of, make_stub_opaques

    def apply(g):
        for kind, fn in make_stub_opaques(capacity_of(g)).items():
            monkeypatch.setitem(engine.OPAQUE_FNS, kind, fn)

    return apply


def _feeds_for(g, cfg):
    feeds = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if str(n.dtype) == "int32":
            feeds[n.name] = RNG.integers(
                0, cfg.vocab, size=n.shape).astype(np.int32)
        else:
            feeds[n.name] = (RNG.normal(size=n.shape) * 0.05).astype(np.float32)
    return feeds


@pytest.mark.parametrize("arch", FAMILIES)
@pytest.mark.parametrize("bs", GRID, ids=lambda t: f"b{t[0]}s{t[1]}")
def test_old_and_new_paths_bit_identical(arch, bs, _stub_opaques):
    cfg = reduced(get_config(arch))
    shape = ShapeConfig("eq", "prefill", bs[1], bs[0])

    # -- old surface: imperative graph + positional runner -------------------
    g = build_graph(cfg, shape)
    _stub_opaques(g)
    feeds = _feeds_for(g, cfg)
    in_order = [g.nodes[i].name for i in g.input_ids()]
    old_fn = jax.jit(engine.make_runner(g))
    out_old = np.asarray(old_fn(*[feeds[n] for n in in_order]))

    # -- new surface: Program with name-keyed I/O ----------------------------
    prog = program_for(cfg, shape)
    out_new = np.asarray(prog.compile()(feeds)["logits"])

    assert out_old.shape == (bs[0], bs[1], cfg.vocab_padded)
    assert np.array_equal(out_old, out_new), (
        f"{arch} b{bs[0]} s{bs[1]}: old and new paths diverge "
        f"(max abs diff {np.abs(out_old - out_new).max()})")


@pytest.mark.parametrize("arch", FAMILIES)
def test_program_and_builder_graphs_canonically_identical(arch):
    """The frontend trace reproduces the imperative builder's graph exactly
    (same canonical key — so plan-cache entries transfer between surfaces)."""
    cfg = reduced(get_config(arch))
    shape = ShapeConfig("eq", "prefill", 16, 2)
    g = build_graph(cfg, shape)
    prog = program_for(cfg, shape)
    assert canon.graph_key(prog.graph) == canon.graph_key(g)


def test_plan_for_shim_agrees_with_program_compile():
    """The deprecation shim and the Program surface return the same plan
    (cost and per-node partitionings) for the same cell."""
    cfg = reduced(get_config("llama-7b"))
    shape = ShapeConfig("eq", "prefill", 16, 2)
    axes = {"data": 2, "model": 2}
    _, plan_old, policy_old = plan_for(cfg, shape, axes)
    compiled = program_for(cfg, shape).compile(mesh_axes=axes)
    assert compiled.plan.cost == plan_old.cost
    assert compiled.plan.d_by_node == plan_old.d_by_node
    assert compiled.policy().label_axes == policy_old.label_axes
