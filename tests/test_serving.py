"""Serving tier: paged KV OpDef, block allocator, shape buckets, the
continuous-batching engine, and the serve.py cache-preparation edge cases.

The engine's headline contract — continuous batching produces generations
bit-for-bit identical to sequential per-request ``serve()`` — is asserted
here on a small mixed-length workload; benchmarks/bench_serve.py runs the
full three-family version under 8 forced host devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels import ops
from repro.launch.serve import _ring_pack, prepare_decode_caches, serve
from repro.models import transformer as tf
from repro.models.attention import KVCache
from repro.serving import (BlockAllocator, BucketRegistry, ServingEngine,
                           bucket_len, pad_free)

# ---------------------------------------------------------------------------
# kv_block_gather: the paged-KV OpDef's dense semantics
# ---------------------------------------------------------------------------


def test_kv_block_gather_matches_manual_lookup():
    rng = np.random.default_rng(0)
    n, p, k, d = 7, 4, 2, 3
    pool = rng.normal(size=(n, p, k, d)).astype(np.float32)
    tables = np.array([[1, 3, 0], [6, 2, 5]], np.int32)   # (b=2, w=3)
    kv_len = 10                                           # truncates w*p=12
    out = np.asarray(ops.kv_block_gather(pool, tables, kv_len))
    assert out.shape == (2, k, kv_len, d)
    for b in range(2):
        rows = np.concatenate([pool[tables[b, j]] for j in range(3)], axis=0)
        want = rows[:kv_len].transpose(1, 0, 2)           # (k, t, d)
        np.testing.assert_array_equal(out[b], want)


def test_kv_block_gather_rejects_overlong_kv_len():
    pool = np.zeros((3, 2, 1, 1), np.float32)
    tables = np.zeros((1, 2), np.int32)
    with pytest.raises(ValueError):
        ops.kv_block_gather(pool, tables, kv_len=5)       # > w*p = 4


def test_kv_block_gather_opdef_checks():
    from repro.core import opdef

    opdef.check_impl("kv_block_gather")
    od = opdef.get("kv_block_gather")
    assert od is not None and od.shard_rule == "paged"


# ---------------------------------------------------------------------------
# block allocator
# ---------------------------------------------------------------------------


def test_block_allocator_reserves_scratch_and_recycles():
    al = BlockAllocator(n_blocks=5, block=8)              # blocks 1..4 free
    assert al.n_free == 4
    a = al.alloc(3)
    assert a == [1, 2, 3] and 0 not in a
    assert al.alloc(2) is None                            # all-or-nothing
    assert al.n_free == 1                                 # failed alloc kept
    al.release(a)
    assert al.n_free == 4
    with pytest.raises(ValueError):
        al.release([1])                                   # double free
    with pytest.raises(ValueError):
        al.release([0])                                   # scratch is not
    assert al.blocks_for(17) == 3                         #   allocatable


# ---------------------------------------------------------------------------
# bucket policy
# ---------------------------------------------------------------------------


def test_bucket_policy_pow2_only_when_pad_free():
    llama = reduced(get_config("llama-7b"))
    xlstm = reduced(get_config("xlstm-125m"))
    moe = reduced(get_config("mixtral-8x7b"))
    assert pad_free(llama) and not pad_free(xlstm) and not pad_free(moe)
    assert bucket_len(llama, 13) == 16                    # pow2 rounding
    assert bucket_len(llama, 16) == 16
    assert bucket_len(llama, 3) == 8                      # min bucket
    assert bucket_len(xlstm, 13) == 13                    # recurrent: exact
    assert bucket_len(moe, 13) == 13                      # capacity: exact
    assert bucket_len(llama, 13, mode="exact") == 13
    assert bucket_len(xlstm, 13, mode="pow2") == 16       # explicit override


def test_bucket_registry_warm_after_first_touch():
    from repro.core.plancache import PlanCache

    cfg = reduced(get_config("llama-7b"))
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    pc = PlanCache()
    reg = BucketRegistry(cfg, mesh, plan_cache=pc)
    e1 = reg.prefill(13)
    e2 = reg.prefill(14)                                  # same pow2 bucket
    assert e1 is e2 and e1.hits == 1
    assert reg.stats.compiles == 1 and reg.stats.lookups == 2
    assert e1.key[2] == 16 and e1.canonical_key
    # a second registry on the same plan cache skips the DP (warm hit)
    reg2 = BucketRegistry(cfg, mesh, plan_cache=pc)
    e3 = reg2.prefill(13)
    assert reg2.stats.plan_cache_hits == 1
    assert e3.canonical_key == e1.canonical_key


# ---------------------------------------------------------------------------
# serve.py cache preparation edge cases (_ring_pack / prepare_decode_caches)
# ---------------------------------------------------------------------------


def _fake_kv(L, b, s, kh, hd):
    k = np.arange(L * b * s * kh * hd, dtype=np.float32).reshape(
        L, b, s, kh, hd)
    return KVCache(jnp.asarray(k), jnp.asarray(k + 0.5))


def test_ring_pack_prompt_shorter_than_window():
    kv = _fake_kv(1, 1, 3, 1, 1)                          # prompt_len 3
    out = _ring_pack(kv, prompt_len=3, window=5)
    k = np.asarray(out.k)
    assert k.shape == (1, 1, 5, 1, 1)
    # slots 0..2 hold the prompt rows in order, the rest stay zero
    np.testing.assert_array_equal(k[0, 0, :3, 0, 0], [0, 1, 2])
    np.testing.assert_array_equal(k[0, 0, 3:, 0, 0], [0, 0])


def test_ring_pack_prompt_exactly_window():
    kv = _fake_kv(1, 1, 4, 1, 1)
    out = _ring_pack(kv, prompt_len=4, window=4)
    # (prompt_len - take + arange) % window == arange: identity layout
    np.testing.assert_array_equal(np.asarray(out.k)[0, 0, :, 0, 0],
                                  [0, 1, 2, 3])


def test_ring_pack_prompt_longer_than_window_wraps():
    kv = _fake_kv(1, 1, 6, 1, 1)                          # rows 0..5
    out = _ring_pack(kv, prompt_len=6, window=4)
    # last 4 rows (2,3,4,5) at slots (6-4+i) % 4 = (2,3,0,1)
    np.testing.assert_array_equal(np.asarray(out.k)[0, 0, :, 0, 0],
                                  [4, 5, 2, 3])


def test_prepare_decode_caches_pads_dense_path():
    cfg = reduced(get_config("llama-7b"))                 # no window
    kv = _fake_kv(1, 2, 3, 1, 1)
    out = prepare_decode_caches(cfg, [kv], prompt_len=3, kv_len=7)
    k = np.asarray(out[0].k)
    assert k.shape == (1, 2, 7, 1, 1)
    np.testing.assert_array_equal(k[:, :, :3], np.asarray(kv.k))
    assert (k[:, :, 3:] == 0).all()                       # zero tail


def test_prepare_decode_caches_hymba_tuple_keeps_state():
    cfg = reduced(get_config("hymba-1.5b"))               # windowed hybrid
    kv = _fake_kv(1, 1, 3, 1, 1)
    st = {"s": jnp.ones((1, 1, 4))}                       # opaque state tree
    out = prepare_decode_caches(cfg, [(kv, st)], prompt_len=3,
                                kv_len=cfg.window)
    kv2, st2 = out[0]
    assert np.asarray(kv2.k).shape[2] == cfg.window       # ring-packed
    assert st2 is st                                      # state untouched


# ---------------------------------------------------------------------------
# bucketed prefill: logit_index == last_logit_only on the real token
# ---------------------------------------------------------------------------


def test_forward_logit_index_matches_exact_prefill_bitwise():
    cfg = reduced(get_config("llama-7b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    plen, bucket = 13, 16
    toks = rng.integers(0, cfg.vocab, size=(2, plen)).astype(np.int32)
    padded = np.zeros((2, bucket), np.int32)
    padded[:, :plen] = toks

    exact, caches_e, _ = tf.forward(params, jnp.asarray(toks), cfg,
                                    collect_cache=True, remat=False,
                                    last_logit_only=True)
    buck, caches_b, _ = tf.forward(params, jnp.asarray(padded), cfg,
                                   collect_cache=True, remat=False,
                                   logit_index=jnp.int32(plen - 1))
    np.testing.assert_array_equal(np.asarray(exact), np.asarray(buck))
    # the real-token cache rows are bitwise too (pad rows are masked junk)
    k_e = np.asarray(caches_e[0][0])
    k_b = np.asarray(caches_b[0][0])
    np.testing.assert_array_equal(k_e, k_b[:, :, :plen])


# ---------------------------------------------------------------------------
# the engine: continuous batching == sequential serve(), bit for bit
# ---------------------------------------------------------------------------


def test_engine_matches_sequential_serve_bitwise():
    cfg = reduced(get_config("llama-7b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=(L,)).astype(np.int32)
               for L in (5, 9, 12)]
    max_new = 4

    eng = ServingEngine(cfg, batch=2, max_seq=24, block=8, params=params)
    rids = [eng.submit(p, max_new) for p in prompts]
    results, metrics = eng.run()
    assert metrics.prefills == 3
    assert metrics.tokens_generated == 3 * max_new
    assert len(metrics.ttft_s) == 3

    for rid, p in zip(rids, prompts):
        gen, _ = serve(cfg, p[None, :], max_new=max_new, params=params,
                       kv_len=eng.seq, mesh=eng.mesh)
        np.testing.assert_array_equal(results[rid], gen[0])


def test_engine_rejects_oversized_request_and_detects_deadlock():
    cfg = reduced(get_config("llama-7b"))
    eng = ServingEngine(cfg, batch=2, max_seq=16, block=8,
                        params=tf.init_params(cfg, jax.random.PRNGKey(0)))
    with pytest.raises(ValueError):
        eng.submit(np.zeros(20, np.int32), 8)             # > max_seq

    tiny = ServingEngine(cfg, batch=1, max_seq=24, block=8, n_blocks=2,
                         params=tf.init_params(cfg, jax.random.PRNGKey(0)))
    tiny.submit(np.zeros(12, np.int32), 8)                # needs 3 blocks,
    with pytest.raises(RuntimeError):                     # pool has 1
        tiny.run()
