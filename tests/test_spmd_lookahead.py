"""Graph-wide lookahead prefetch scheduling (ISSUE 9).

The lookahead pass only reorders *when* repartition chains issue — never
what they compute — so the properties pinned here are the ones a hoist
bug would break:

1. **Equivalence** — random EinGraphs and the full zoo (prefill + decode)
   are bit-identical at ``lookahead=0/1/2``: hoisting runs the same steps
   on the same values, only the traced issue order changes.
2. **Serial baseline** — ``lookahead=0`` restores today's lowering
   verbatim: same events (modulo the prefetch marks), same arg chains,
   no recorded lifetimes.
3. **Invariants** — an unsharded plan still emits zero collectives (and
   zero prefetches); the double-buffered ring composes with graph-level
   hoisting without double-counting ``overlapped_elems`` (ring events
   keep ``prefetch_for == -1``; prefetched and ring elems partition the
   overlap total).
4. **Memory honesty** — prefetch buffers widen live ranges: ``--max-hbm``
   RA301 fires on a lookahead schedule whose serial twin fits.
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.analysis import analyze_schedule_only
from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import engine, spmd
from repro.core.cost import exposed_wire
from repro.core.decomp import Plan, eindecomp
from repro.core.einsum import EinGraph, eval_graph_dense
from repro.launch.mesh import make_host_mesh
from repro.launch.trajectory import FAMILIES, MESH_AXES, family_ratio
from repro.models.eingraphs import program_for

RNG = np.random.default_rng(7)
SIZES = {"data": 2, "model": 4}
LOOKAHEADS = (0, 1, 2)


def _feeds(g, cfg=None, scale=0.1):
    out = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if str(np.dtype(n.dtype)) == "int32":
            hi = cfg.vocab if cfg is not None else max(n.shape[-1], 2)
            out[n.nid] = RNG.integers(0, hi, size=n.shape).astype(np.int32)
        else:
            out[n.nid] = (RNG.normal(size=n.shape) * scale).astype(
                np.float32)
    return out


def _random_graph(rng):
    pool = ["i", "j", "k", "l"]
    g = EinGraph("prop")
    n_in = int(rng.integers(2, 4))
    nodes = []
    for t in range(n_in):
        nl = int(rng.integers(1, 4))
        labels = list(rng.choice(pool, size=nl, replace=False))
        nodes.append(g.input(f"in{t}", labels, [8] * nl))
    for _ in range(int(rng.integers(1, 4))):
        a = int(rng.choice(nodes))
        b = int(rng.choice(nodes))
        la, lb = g.nodes[a].labels, g.nodes[b].labels
        union = list(dict.fromkeys(la + lb))
        keep = [l for l in union if rng.random() < 0.6] or [union[0]]
        expr = f"{' '.join(la)}, {' '.join(lb)} -> {' '.join(keep)}"
        try:
            nodes.append(g.einsum(expr, a, b))
        except ValueError:
            continue
        if rng.random() < 0.3:
            nodes.append(g.map("relu", nodes[-1]))
    return g


# ---------------------------------------------------------------------------
# 1. equivalence: bit-identical at lookahead 0/1/2
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_random_graphs_bit_identical_across_lookahead(seed):
    rng = np.random.default_rng(200 + seed)
    g = _random_graph(rng)
    outs = g.outputs()
    mesh = make_host_mesh((2, 4))
    axes = engine.mesh_axes_dict(mesh)
    plan = eindecomp(g, math.prod(axes.values()), mesh_axes=axes)
    feeds = _feeds(g)
    args = [feeds[i] for i in g.input_ids()]
    results = {}
    for la in LOOKAHEADS:
        fn = jax.jit(engine.make_runner(g, outs, plan=plan, mesh=mesh,
                                        executor="shard_map", lookahead=la))
        got = fn(*args)
        results[la] = got if len(outs) > 1 else (got,)
    for la in LOOKAHEADS[1:]:
        for o, v0, v in zip(outs, results[0], results[la]):
            np.testing.assert_array_equal(
                np.asarray(v0), np.asarray(v),
                err_msg=f"node {o} diverged at lookahead={la}")
    dense = eval_graph_dense(g, feeds)
    for o, v in zip(outs, results[1]):
        np.testing.assert_allclose(np.asarray(v), dense[o],
                                   rtol=1e-4, atol=1e-5)


@pytest.fixture()
def _stub_opaques(monkeypatch):
    from repro.models.opaque_stubs import capacity_of, make_stub_opaques

    def apply(g):
        for kind, fn in make_stub_opaques(capacity_of(g)).items():
            monkeypatch.setitem(engine.OPAQUE_FNS, kind, fn)

    return apply


@pytest.mark.parametrize("phase", ["prefill", "decode"])
@pytest.mark.parametrize("arch", list(FAMILIES))
def test_zoo_bit_identical_across_lookahead(_stub_opaques, arch, phase):
    """Full zoo, prefill + decode: logits at lookahead 0/1/2 are bitwise
    equal, and the lookahead schedules move exactly the same wire (the
    pass reorders issues; it never adds or removes events)."""
    cfg = reduced(get_config(arch))
    prog = program_for(cfg, ShapeConfig("eq", phase, 8, 2))
    g = prog.graph
    _stub_opaques(g)
    mesh = make_host_mesh((2, 4))
    feeds = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if str(np.dtype(n.dtype)) == "int32":
            feeds[n.name] = RNG.integers(0, cfg.vocab,
                                         size=n.shape).astype(np.int32)
        else:
            feeds[n.name] = (RNG.normal(size=n.shape) * 0.05).astype(
                np.float32)
    logits = {}
    traces = {}
    for la in LOOKAHEADS:
        run = prog.compile(mesh=mesh, executor="shard_map", lookahead=la)
        assert run.lookahead == la
        logits[la] = np.asarray(run(feeds)["logits"])
        traces[la] = run.collectives
    np.testing.assert_array_equal(logits[0], logits[1])
    np.testing.assert_array_equal(logits[0], logits[2])
    assert traces[0].total_elems == traces[1].total_elems \
        == traces[2].total_elems
    assert traces[0].elems_by_node == traces[1].elems_by_node
    assert traces[0].prefetched_elems == 0


# ---------------------------------------------------------------------------
# 2. lookahead=0 restores the serial lowering verbatim
# ---------------------------------------------------------------------------


def _zoo_schedule(arch, phase, lookahead):
    from repro.models.opaque_stubs import capacity_of, make_stub_opaques

    cfg = reduced(get_config(arch))
    prog = program_for(cfg, ShapeConfig("bench", phase, 32, 4))
    g = prog.graph
    make_stub_opaques(capacity_of(g))
    plan = eindecomp(g, math.prod(MESH_AXES.values()), mesh_axes=MESH_AXES,
                     offpath_repart=True)
    out_ids = [prog._out[k] for k in prog._out]
    return g, plan, spmd.build_schedule(g, plan, MESH_AXES, out_ids,
                                        lookahead=lookahead)


def test_lookahead_zero_is_serial_verbatim():
    """The lookahead=1 schedule differs from lookahead=0 only by the
    prefetch marks: stripping overlap/prefetch_for from hoisted events
    recovers the serial event list exactly, and every arg chain's steps
    are unchanged."""
    g, plan, s0 = _zoo_schedule("llama-7b", "prefill", lookahead=0)
    _, _, s1 = _zoo_schedule("llama-7b", "prefill", lookahead=1)
    assert s0.lookahead == 0 and not s0.prefetches
    assert not any(p.prefetch or p.prefetch_src for p in s0.programs)
    assert all(e.prefetch_for == -1 for e in s0.trace.events)
    assert s1.prefetches, "zoo prefill must hoist something"
    stripped = [dataclasses.replace(e, overlap=False, prefetch_for=-1)
                if e.prefetch_for >= 0 else e for e in s1.trace.events]
    assert stripped == s0.trace.events
    for p0, p1 in zip(s0.programs, s1.programs):
        assert p0.arg_steps == p1.arg_steps
        assert p0.post_steps == p1.post_steps


def test_prefetch_lifetimes_respect_readiness():
    """Every recorded lifetime is well-formed on the whole zoo: issue
    strictly before the consumer, at a computing node, never at or before
    the arg's own producer — and the RA208 pass agrees (clean)."""
    for arch in FAMILIES:
        for phase in ("prefill", "decode"):
            g, _, sched = _zoo_schedule(arch, phase, lookahead=2)
            for pf in sched.prefetches:
                n = g.nodes[pf.consumer]
                a = n.inputs[pf.arg]
                assert pf.issue < pf.consumer
                assert g.nodes[pf.issue].kind != "input"
                if g.nodes[a].kind != "input":
                    assert pf.issue > a, (arch, phase, pf)
            rep = analyze_schedule_only(g, sched)
            assert not rep.has_errors, f"{arch}/{phase}:\n{rep.format()}"


# ---------------------------------------------------------------------------
# 3. invariants: zero-collective plans, ring composition
# ---------------------------------------------------------------------------


def test_zero_collectives_invariant_survives_lookahead():
    from repro import frontend as ein

    x = ein.tensor("x", "b a", (8, 16))
    w1 = ein.tensor("w1", "a f", (16, 32))
    y = ein.einsum("b a, a f -> b f", x, w1).map("relu")
    prog = ein.Program({"y": y})
    mesh = make_host_mesh((1, 1))
    run = prog.compile(mesh=mesh, executor="shard_map", lookahead=2)
    assert len(run.collectives) == 0, run.collectives.summary()
    assert run.collectives.prefetched_elems == 0
    feeds = {"x": RNG.normal(size=(8, 16)).astype(np.float32),
             "w1": (RNG.normal(size=(16, 32)) * 0.1).astype(np.float32)}
    got = np.asarray(run(feeds)["y"])
    np.testing.assert_allclose(
        got, np.maximum(feeds["x"] @ feeds["w1"], 0), rtol=1e-4, atol=1e-5)


B, H, K, S, D = 2, 4, 2, 32, 16


def _attn_graph_with_projection():
    """Ring-attention graph whose q arrives through a wire-carrying chain
    with an independent compute node in between — so the schedule carries
    BOTH ring double-buffer hops and a graph-level prefetch."""
    g = EinGraph("ring+la")
    q = g.input("q", "b h s d", (B, H, S, D))
    k = g.input("k", "b k s d", (B, K, S, D))
    v = g.input("v", "b k s d", (B, K, S, D))
    mq = g.map("relu", q, name="mq")       # producer of the opaque's arg 0
    mk = g.map("relu", k, name="mk")       # independent intervening compute
    o = g.opaque(
        "flash_attention", [mq, k, v], "b h s d", (B, H, S, D),
        in_labels=[("b", "h", "s", "d"), ("b", "k", "s", "d"),
                   ("b", "k", "s", "d")],
        shardable={"b", "h", "k", "s"},
        comm=[{"kind": "ring", "label": "s", "input": 1, "rule": "ring"},
              {"kind": "ring", "label": "s", "input": 2, "rule": "ring"}])
    plan = Plan(p=8, mode="mesh")
    ring_axes = {"s": ("model",), "b": ("data",)}
    for n in g.nodes:
        plan.d_by_node[n.nid] = {l: 1 for l in n.labels}
        if n.nid == q:
            plan.axes_by_node[n.nid] = {"d": ("model",)}  # forces a gather
        elif n.kind == "input":
            plan.axes_by_node[n.nid] = {}
        else:
            plan.axes_by_node[n.nid] = dict(ring_axes)
    return g, o, mk, plan


def test_ring_composes_with_lookahead_no_double_count():
    """Ring hops stay ring-attributed (``prefetch_for == -1``); the
    hoisted q-gather is prefetch-attributed; ``overlapped_elems`` counts
    each exactly once — prefetched + ring elems partition the total."""
    g, o, mk, plan = _attn_graph_with_projection()
    sched = spmd.build_schedule(g, plan, SIZES, [o], lookahead=1)
    tr = sched.trace
    # overlap events partition by prefetch_for: -1 = ring double-buffer
    # hop, >= 0 = graph-level prefetch (an opaque's *arg chains* also
    # carry the rule tag, so the rule alone does not identify hops)
    ring_hops = [e for e in tr.events if e.overlap and e.prefetch_for < 0]
    ring_elems = sum(e.elems for e in ring_hops)
    assert ring_elems > 0, "ring double-buffer hops missing"
    assert all(e.kind == "ppermute" and e.rule == "ring" for e in ring_hops)
    assert sched.prefetches, "the q-gather chain must hoist"
    assert all(pf.consumer == o and pf.issue == mk
               for pf in sched.prefetches)
    assert tr.prefetched_elems > 0
    assert tr.overlapped_elems == tr.prefetched_elems + ring_elems
    # the schedule pass sees no hazard in the composition
    rep = analyze_schedule_only(g, sched)
    assert not rep.has_errors, rep.format()


def test_ring_with_lookahead_bit_identical():
    """The composed schedule still executes bit-identically to serial."""
    g, o, _, plan = _attn_graph_with_projection()
    mesh = make_host_mesh((2, 4))
    feeds = _feeds(g, scale=0.3)
    args = [feeds[i] for i in g.input_ids()]
    outs = {}
    for la in LOOKAHEADS:
        fn = jax.jit(engine.make_runner(g, [o], plan=plan, mesh=mesh,
                                        executor="shard_map", lookahead=la))
        outs[la] = np.asarray(fn(*args))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])
    np.testing.assert_allclose(outs[1], eval_graph_dense(g, feeds)[o],
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# 4. memory honesty: prefetch buffers widen live ranges (RA301)
# ---------------------------------------------------------------------------


def _prefetch_heavy_cell():
    """Two full-reduction consumers of one sharded activation: serially
    each gathers its 16 KiB copy in its own iteration; at lookahead=2
    both chains pile onto the same issue node."""
    g = EinGraph("hbm")
    x = g.input("x", "i j", (64, 64))
    r = g.map("relu", x, name="r")
    d = g.map("relu", r, name="d")
    c1 = g.einsum("i j -> ", r)
    c2 = g.einsum("i j -> ", r)
    comb = g.einsum(", -> ", c1, c2)
    sh = {"i": ("data",), "j": ("model",)}
    plan = Plan(p=8, mode="mesh",
                axes_by_node={x: dict(sh), r: dict(sh), d: dict(sh),
                              c1: {}, c2: {}, comb: {}},
                d_by_node={n.nid: {} for n in g.nodes})
    return g, plan, comb


def test_max_hbm_fires_on_lookahead_schedule_whose_serial_twin_fits():
    g, plan, comb = _prefetch_heavy_cell()
    serial = spmd.build_schedule(g, plan, SIZES, [comb], lookahead=0)
    hoisted = spmd.build_schedule(g, plan, SIZES, [comb], lookahead=2)
    rep_s = analyze_schedule_only(g, serial, max_hbm=30_000)
    rep_h = analyze_schedule_only(g, hoisted, max_hbm=30_000)
    assert not rep_s.has_errors, rep_s.format()
    assert "RA301" in rep_h.codes(), rep_h.format()
    # the widened ranges are visible in the report, not just the finding
    assert rep_h.memory["peak_bytes"] > rep_s.memory["peak_bytes"]
    assert rep_h.memory["n_prefetches"] == 2
    assert rep_h.memory["prefetch_hold_bytes"] > 0
    assert rep_s.memory["n_prefetches"] == 0


# ---------------------------------------------------------------------------
# 5. cost-model exposure term
# ---------------------------------------------------------------------------


def test_exposed_wire_bounded_by_compute_window():
    # hiding is per-site min(overlap, window); never negative
    assert exposed_wire(1000, {2: 300}, {2: 100}) == 900
    assert exposed_wire(1000, {2: 300}, {2: 10**9}) == 700
    assert exposed_wire(100, {1: 80, 2: 80}, {1: 10**9, 2: 10**9}) == 0
    assert exposed_wire(0, {}, {}) == 0
    # a site with no compute window hides nothing
    assert exposed_wire(1000, {5: 300}, {}) == 1000


@pytest.mark.parametrize("arch", list(FAMILIES))
def test_family_overlap_frac_positive_and_exposed_consistent(arch):
    """Every zoo family's prefill schedule hoists wire (the acceptance
    bar bench_spmd --check enforces), and the exposure term stays within
    [total − overlapped, total]."""
    row = family_ratio(arch, "prefill")
    assert row["overlap_frac"] > 0, row
    assert row["overlapped_elems"] > 0
    total = row["traced_elems"]
    assert total - row["overlapped_elems"] <= row["exposed_elems"] <= total
