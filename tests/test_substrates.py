"""Data pipeline, optimizer, schedules, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data.synthetic import SyntheticLM
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedules import cosine_schedule, wsd_schedule


def test_data_deterministic_replay():
    d1 = SyntheticLM(vocab=512, seq=64, global_batch=8, seed=3)
    d2 = SyntheticLM(vocab=512, seq=64, global_batch=8, seed=3)
    b1 = d1.global_batch_at(17)
    b2 = d2.global_batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps differ
    assert not np.array_equal(b1["tokens"], d1.global_batch_at(18)["tokens"])


def test_data_host_sharding_consistent_with_global():
    d = SyntheticLM(vocab=512, seq=32, global_batch=8, seed=0)
    g = d.global_batch_at(5)["tokens"]
    rows = []
    for host in range(4):
        rows.append(d.host_batch_at(5, host, 4)["tokens"])
    np.testing.assert_array_equal(np.concatenate(rows, axis=0), g)


def test_data_elastic_rescale_replays_same_batch():
    """Restart with a different host count must reproduce the global batch."""
    d = SyntheticLM(vocab=512, seq=32, global_batch=8, seed=0)
    two_hosts = np.concatenate(
        [d.host_batch_at(9, h, 2)["tokens"] for h in range(2)], axis=0)
    eight_hosts = np.concatenate(
        [d.host_batch_at(9, h, 8)["tokens"] for h in range(8)], axis=0)
    np.testing.assert_array_equal(two_hosts, eight_hosts)


def test_adamw_descends_quadratic():
    w = jnp.asarray([3.0, -2.0])
    params = {"w": w}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, _ = adamw_update(params, grads, state, lr=0.05,
                                        weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.15


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    total = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    assert float(total) == pytest.approx(1.0, rel=1e-3)


def test_schedules():
    c = [float(cosine_schedule(s, peak_lr=1.0, warmup=10, total=100))
         for s in range(100)]
    assert c[0] == 0.0 and max(c) == pytest.approx(1.0)
    assert c[99] < 0.2
    w = [float(wsd_schedule(s, peak_lr=1.0, warmup=10, stable=50, decay=20))
         for s in range(90)]
    assert w[30] == pytest.approx(1.0)  # stable plateau
    assert w[85] < 0.1                  # decayed


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    path = os.path.join(tmp_path, "ck")
    save_checkpoint(path, 7, tree, extra={"mesh": [2, 4]})
    step, back, extra = load_checkpoint(path, tree)
    assert step == 7 and extra == {"mesh": [2, 4]}
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert back["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_manager_gc_and_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((3,))}
    for s in (10, 20, 30):
        mgr.save(s, jax.tree.map(lambda x: x + s, tree), blocking=True)
    assert mgr.all_steps() == [20, 30]
    step, back, _ = mgr.restore_latest(tree)
    assert step == 30
    np.testing.assert_allclose(np.asarray(back["w"]), 30.0)


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore under different shardings (elastic restart)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh((1, 1))
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    path = os.path.join(tmp_path, "ck")
    save_checkpoint(path, 1, tree)
    shard = {"w": NamedSharding(mesh, P())}
    step, back, _ = load_checkpoint(path, tree, shardings=shard)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.arange(8))
    assert back["w"].sharding == shard["w"]


def test_grad_compression_halves_bytes():
    from repro.optim.adamw import compress_grads

    g = {"w": jnp.ones((128,), jnp.float32)}
    c = compress_grads(g, jax.random.PRNGKey(0))
    assert c["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(c["w"], np.float32), 1.0, rtol=0.02)
