"""TRA <-> dense-EinSum equivalence (paper §4): property-based.

For any EinSum expression and any valid partitioning vector d, the §4.3
join->aggregate rewrite over tensor relations must reproduce the dense
result exactly (same function, different implementation).

``hypothesis`` is optional: when it is installed the properties are fuzzed;
on a clean machine the same checks run over a deterministic sample grid so
the tier-1 suite never fails collection (see requirements-dev.txt for the
full dev toolchain).
"""
import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on dev environment
    HAVE_HYPOTHESIS = False

from repro.core.einsum import EinGraph, EinSpec, eval_einsum_dense
from repro.core.tra import (TensorRelation, execute_einsum_tra,
                            execute_graph_tra, ld_concat, project)

RNG = np.random.default_rng(0)


def test_project():
    # §3 example: b=[2,3,4], l1=[k,i], l2=[i,j,k] -> [4,2]
    assert project([2, 3, 4], ["k", "i"], ["i", "j", "k"]) == (4, 2)


def test_tensor_relation_roundtrip_4x2():
    # §4.1 worked example: d=[4,2] slices U into 8 column-ish blocks
    U = np.arange(1, 17).reshape(4, 4)
    tr = TensorRelation.from_dense(U, (4, 2))
    assert tr.n_blocks == 8
    assert tr.block_shape == (1, 2)
    np.testing.assert_array_equal(tr.to_dense(), U)
    tr2 = tr.repartition((2, 2))
    assert tr2.block_shape == (2, 2)
    np.testing.assert_array_equal(tr2.blocks[(0, 0)], [[1, 2], [5, 6]])


# -- property: every pow2 partitioning of matmul matches dense --------------


def check_tra_equivalence_binary(case):
    di, dj, dk, combine, agg = case
    spec = EinSpec((("i", "j"), ("j", "k")), ("i", "k"), combine, agg)
    X = RNG.normal(size=(8, 8)).astype(np.float32)
    Y = RNG.normal(size=(8, 8)).astype(np.float32)
    want = eval_einsum_dense(spec, X, Y)
    d = {"i": di, "j": dj, "k": dk}
    xr = TensorRelation.from_dense(X, (di, dj))
    yr = TensorRelation.from_dense(Y, (dj, dk))
    out, stats = execute_einsum_tra(spec, d, xr, yr)
    np.testing.assert_allclose(out.to_dense(), want, rtol=1e-5, atol=1e-5)
    # §6: the join produces prod(d over unique labels) kernel calls
    assert stats["kernel_calls"] == di * dj * dk


def check_tra_equivalence_rank3_contraction(db, di, dj, dk):
    # the §3 batch-matmul example: X[i,j,b] * Y[j,b,k] -> Z[i,k]
    spec = EinSpec((("i", "j", "b"), ("j", "b", "k")), ("i", "k"))
    X = RNG.normal(size=(4, 8, 4)).astype(np.float32)
    Y = RNG.normal(size=(8, 4, 8)).astype(np.float32)
    want = eval_einsum_dense(spec, X, Y)
    d = {"i": di, "j": dj, "b": db, "k": dk}
    xr = TensorRelation.from_dense(X, (di, dj, db))
    yr = TensorRelation.from_dense(Y, (dj, db, dk))
    out, _ = execute_einsum_tra(spec, d, xr, yr)
    np.testing.assert_allclose(out.to_dense(), want, rtol=1e-4, atol=1e-5)


if HAVE_HYPOTHESIS:

    @st.composite
    def matmul_case(draw):
        di = draw(st.sampled_from([2, 4, 8]))
        dj = draw(st.sampled_from([2, 4, 8]))
        dk = draw(st.sampled_from([2, 4, 8]))
        combine = draw(st.sampled_from(["mul", "sqdiff", "absdiff"]))
        agg = draw(st.sampled_from(["sum", "max"]))
        return di, dj, dk, combine, agg

    @given(matmul_case())
    @settings(max_examples=40, deadline=None)
    def test_tra_equivalence_binary(case):
        check_tra_equivalence_binary(case)

    @given(st.sampled_from([1, 2, 4]), st.sampled_from([1, 2, 4]),
           st.sampled_from([1, 2, 4]), st.sampled_from([1, 2]))
    @settings(max_examples=20, deadline=None)
    def test_tra_equivalence_rank3_contraction(db, di, dj, dk):
        check_tra_equivalence_rank3_contraction(db, di, dj, dk)

else:
    # deterministic fallback grid: every combine/agg pair at representative
    # partitionings, so the paper's §4 property is still exercised.
    _BINARY_CASES = [
        (di, dj, dk, combine, agg)
        for (di, dj, dk) in [(2, 2, 2), (4, 2, 8), (8, 8, 8), (2, 8, 4)]
        for combine in ("mul", "sqdiff", "absdiff")
        for agg in ("sum", "max")
    ]

    @pytest.mark.parametrize("case", _BINARY_CASES)
    def test_tra_equivalence_binary(case):
        check_tra_equivalence_binary(case)

    _RANK3_CASES = [
        (db, di, dj, dk)
        for db, di, dj, dk in itertools.product(
            [1, 2], [1, 4], [2, 4], [1, 2])
    ]

    @pytest.mark.parametrize("db,di,dj,dk", _RANK3_CASES)
    def test_tra_equivalence_rank3_contraction(db, di, dj, dk):
        check_tra_equivalence_rank3_contraction(db, di, dj, dk)


def test_l2_distance_einsum():
    # §3: Z_ik = sum_j (X_ij - Y_jk)^2
    spec = EinSpec((("i", "j"), ("j", "k")), ("i", "k"), "sqdiff", "sum")
    X = RNG.normal(size=(4, 8)).astype(np.float32)
    Y = RNG.normal(size=(8, 4)).astype(np.float32)
    want = ((X[:, :, None] - Y[None, :, :]) ** 2).sum(axis=1)
    np.testing.assert_allclose(eval_einsum_dense(spec, X, Y), want, rtol=1e-5)


def test_linf_distance_einsum():
    # §3: Z_ik = max_j |X_ij - Y_jk|
    spec = EinSpec((("i", "j"), ("j", "k")), ("i", "k"), "absdiff", "max")
    X = RNG.normal(size=(4, 8)).astype(np.float32)
    Y = RNG.normal(size=(8, 4)).astype(np.float32)
    want = np.abs(X[:, :, None] - Y[None, :, :]).max(axis=1)
    np.testing.assert_allclose(eval_einsum_dense(spec, X, Y), want, rtol=1e-5)


def test_graph_execution_with_repartition():
    """Chained matmuls with deliberately mismatched partitionings force
    repartitions; the result must still be exact."""
    g = EinGraph()
    a = g.input("A", "ij", (8, 8))
    b = g.input("B", "jk", (8, 8))
    c = g.input("C", "kl", (8, 8))
    ab = g.einsum("ij,jk->ik", a, b)
    abc = g.einsum("ik,kl->il", ab, c)
    plan = {a: {"i": 4, "j": 1}, b: {"j": 1, "k": 4}, c: {"k": 2, "l": 2},
            ab: {"i": 4, "j": 1, "k": 4}, abc: {"i": 1, "k": 2, "l": 2}}
    feeds = {n: RNG.normal(size=(8, 8)).astype(np.float32) for n in (a, b, c)}
    vals, stats = execute_graph_tra(g, plan, feeds)
    np.testing.assert_allclose(
        vals[abc].to_dense(), feeds[a] @ feeds[b] @ feeds[c], rtol=1e-4)
    assert stats["repartitions"] >= 1
