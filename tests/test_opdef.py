"""The unified OpDef API (core/opdef.py + ein.defop / @ein.op).

Five layers of coverage:

1. **Registration-time cross-validation**: duplicate kinds, dense-impl
   output shapes that contradict the signature, comm declarations
   referencing unregistered shard rules / unknown kinds / unknown labels,
   shard-rule conflicts, unbound output labels.

2. **Call-site inference**: ``ein.opaque`` infers out labels/shape/
   shardable from the signature (no caller-supplied ``out_shape``),
   validates label bounds across arguments, honors per-call instance
   renaming (flash attention's ring label ``l`` → ``s``/``t``), and
   rejects contradictions instead of trusting the caller.

3. **Single-registry equivalence**: OpDef-declared graphs plan
   bit-identically to the historical fully-explicit declarations (comm
   params on the node), and the legacy surfaces (``register_opaque``,
   ``engine.OPAQUE_FNS`` item assignment) still work as deprecation shims
   / live views over the one registry.

4. **Autodiff through opaques**: ``Program.grad`` works through ops with a
   VJP (auto ``jax.vjp`` of the impl — flash attention included) and
   raises an actionable error naming the op otherwise.

5. **End-to-end custom op, entirely outside core/**: one ``@ein.op``
   declaration (signature, dense impl, VJP, comm declaration, custom shard
   rule) runs through the dense, grad, and shard_map executor paths.

Plus the channel-parallel ``local`` scan rule (ROADMAP item): zero
collectives when only channel labels are sharded, replicate fallback when
its preconditions fail.
"""
import math
import re
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import frontend as ein
from repro.core import engine, opaque_rules, opdef, spmd
from repro.core.decomp import Plan, eindecomp, opaque_node_bound, plan_cost
from repro.core.einsum import EinGraph, eval_graph_dense
from repro.launch.mesh import make_host_mesh
from repro.models.opaque_stubs import make_stub_opaques

RNG = np.random.default_rng(0)
N_DEV = len(jax.devices())


@pytest.fixture
def defop_tmp():
    """defop wrapper that unregisters everything it created on teardown."""
    created = []

    def reg(kind, *a, **kw):
        od = opdef.defop(kind, *a, **kw)
        created.append(kind)
        return od

    yield reg
    for kind in created:
        opdef.unregister(kind)


# ---------------------------------------------------------------------------
# 1. registration-time cross-validation
# ---------------------------------------------------------------------------


def test_duplicate_kind_rejected(defop_tmp):
    defop_tmp("t_dup", "b a -> b a", fn=lambda x: jnp.asarray(x))
    with pytest.raises(opdef.OpDefError, match="already registered"):
        opdef.defop("t_dup", "b a -> b a", fn=lambda x: jnp.asarray(x))
    # explicit overwrite is allowed
    opdef.defop("t_dup", "b a -> b a", fn=lambda x: jnp.asarray(x),
                overwrite=True)


def test_impl_output_shape_mismatch_rejected():
    with pytest.raises(opdef.OpDefError, match="does not match the signature"):
        opdef.defop("t_badshape", "b a -> b a",
                    fn=lambda x: jnp.sum(jnp.asarray(x), axis=-1))
    assert opdef.get("t_badshape") is None  # nothing half-registered


def test_provide_impl_checks_against_signature(defop_tmp):
    defop_tmp("t_late", "b a -> b a")
    with pytest.raises(opdef.OpDefError, match="does not match the signature"):
        opdef.provide_impl("t_late", lambda x: jnp.asarray(x)[0])
    assert opdef.get("t_late").fn is None  # failed impl not kept
    opdef.provide_impl("t_late", lambda x: jnp.asarray(x) * 2)
    assert engine.OPAQUE_FNS["t_late"] is not None


def test_comm_unregistered_shard_rule_rejected():
    with pytest.raises(opdef.OpDefError, match="warp-drive"):
        opdef.defop("t_badrule", "b s -> b s", fn=lambda x: jnp.asarray(x),
                    comm=[{"kind": "ring", "label": "s", "input": 0,
                           "rule": "warp-drive"}])
    with pytest.raises(opdef.OpDefError, match="warp-drive"):
        opdef.defop("t_badrule2", "b s -> b s", shard_rule="warp-drive")


def test_comm_unknown_kind_label_input_rejected():
    with pytest.raises(opdef.OpDefError, match="broadcast"):
        opdef.defop("t_badkind", "b s -> b s",
                    comm=[{"kind": "broadcast", "label": "s", "input": 0}])
    with pytest.raises(opdef.OpDefError, match="absent from the signature"):
        opdef.defop("t_badlabel", "b s -> b s",
                    comm=[{"kind": "ring", "label": "z", "input": 0}])
    with pytest.raises(opdef.OpDefError, match="out of range"):
        opdef.defop("t_badinput", "b s -> b s",
                    comm=[{"kind": "ring", "label": "s", "input": 3}])


def test_conflicting_rules_rejected():
    with pytest.raises(opdef.OpDefError, match="conflicting"):
        opdef.defop("t_conflict", "b s, b s -> b s",
                    comm=[{"kind": "ring", "label": "s", "input": 0},
                          {"kind": "a2a", "label": "b", "input": 1}])
    with pytest.raises(opdef.OpDefError, match="disagrees"):
        opdef.defop("t_conflict2", "b s -> b s", shard_rule="replicate",
                    comm=[{"kind": "ring", "label": "s", "input": 0}])


def test_unbound_output_label_rejected():
    with pytest.raises(opdef.OpDefError, match="appears in no input"):
        opdef.defop("t_unbound", "b a -> b c")
    # ...unless bound by a call param (the MoE capacity pattern)
    od = opdef.defop("t_bound", "b a -> b c", param_bounds={"c": "cap"})
    try:
        assert od.param_bounds == {"c": "cap"}
    finally:
        opdef.unregister("t_bound")


def test_shardable_must_be_signature_labels():
    with pytest.raises(opdef.OpDefError, match="shardable"):
        opdef.defop("t_badshard", "b a -> b a", shardable="b z")


def test_comm_entry_missing_input_key_rejected():
    with pytest.raises(opdef.OpDefError, match="missing or out of range"):
        opdef.defop("t_noinput", "a b, b c -> a c", shard_rule="ring",
                    comm=[{"kind": "ring", "label": "b"}])


def test_grad_link_must_name_a_registered_map(defop_tmp):
    with pytest.raises(opdef.OpDefError, match="relu_gard"):
        opdef.defop("t_typo_grad", None, fn=lambda x: jnp.asarray(x),
                    category="map", grad="relu_gard")
    # self-derivative (exp-style) and registered targets are fine
    defop_tmp("t_selfgrad", None, fn=lambda x: jnp.asarray(x),
              category="map", grad="t_selfgrad")
    defop_tmp("t_linked", None, fn=lambda x: jnp.asarray(x),
              category="map", grad="one")


# ---------------------------------------------------------------------------
# 2. call-site inference
# ---------------------------------------------------------------------------


def test_opaque_infers_shape_dtype_shardable(defop_tmp):
    defop_tmp("t_scaleadd", "b s f, f -> b s f", shardable="b f",
              fn=lambda x, g: jnp.asarray(x) + jnp.asarray(g))
    x = ein.tensor("x", "b s f", (2, 8, 4))
    g = ein.tensor("g", "f", (4,))
    y = ein.opaque("t_scaleadd", [x, g])
    assert y.labels == ("b", "s", "f")
    assert y.shape == (2, 8, 4)
    assert y.shardable == frozenset({"b", "f"})
    # a caller-supplied out_shape is cross-checked, not trusted
    with pytest.raises(opdef.OpDefError, match="contradicts"):
        ein.opaque("t_scaleadd", [x, g], "b s f", (2, 8, 5))
    # inconsistent label bounds across arguments are a build-time error
    g_bad = ein.tensor("g_bad", "f", (5,))
    with pytest.raises(opdef.OpDefError, match="bound mismatch"):
        ein.opaque("t_scaleadd", [x, g_bad])


def test_opaque_instance_renaming_flash_attention():
    """Decode-style renaming: the signature's ring label l becomes the
    kv-cache-time t; the shardable set follows the renaming (q-seq s stays
    non-shardable in decode because only l is declared shardable)."""
    q = ein.tensor("q", "b h s d", (2, 4, 1, 8))
    k = ein.tensor("k", "b k t d", (2, 2, 16, 8))
    v = ein.tensor("v", "b k t d", (2, 2, 16, 8))
    att = ein.opaque("flash_attention", [q, k, v],
                     in_labels=[("b", "h", "s", "d"), ("b", "k", "t", "d"),
                                ("b", "k", "t", "d")])
    assert att.labels == ("b", "h", "s", "d")
    assert att.shape == (2, 4, 1, 8)
    assert att.shardable == frozenset({"b", "h", "k", "t"})


def test_opaque_param_bound_label(defop_tmp):
    defop_tmp("t_cap", "b a -> b c", param_bounds={"c": "cap"})
    x = ein.tensor("xc", "b a", (2, 8))
    y = ein.opaque("t_cap", [x], cap=5)
    assert y.shape == (2, 5)
    with pytest.raises(opdef.OpDefError, match="cap"):
        ein.opaque("t_cap", [x])  # param not passed
    with pytest.raises(opdef.OpDefError, match="out_labels"):
        ein.opaque("t_cap", [x], "e", cap=5)  # wrong output arity


def test_unregistered_kind_requires_explicit_metadata():
    x = ein.tensor("xu", "b a", (2, 8))
    with pytest.raises(ValueError, match="defop"):
        ein.opaque("t_never_registered", [x])
    # the historical fully-explicit form still works
    y = ein.opaque("t_never_registered", [x], "b a", (2, 8),
                   in_labels=[("b", "a")])
    assert y.shape == (2, 8)


# ---------------------------------------------------------------------------
# 3. single-registry equivalence + legacy shims
# ---------------------------------------------------------------------------

B, H, K, S, D = 2, 4, 2, 32, 16


def _attn_graph_explicit():
    """PR-4-style fully-explicit declaration (comm params on the node)."""
    g = EinGraph("explicit")
    q = g.input("q", "b h s d", (B, H, S, D))
    k = g.input("k", "b k s d", (B, K, S, D))
    v = g.input("v", "b k s d", (B, K, S, D))
    g.opaque("flash_attention", [q, k, v], "b h s d", (B, H, S, D),
             in_labels=[("b", "h", "s", "d"), ("b", "k", "s", "d"),
                        ("b", "k", "s", "d")],
             shardable={"b", "h", "k", "s"},
             comm=[{"kind": "ring", "label": "s", "input": 1,
                    "rule": "ring"},
                   {"kind": "ring", "label": "s", "input": 2,
                    "rule": "ring"}])
    return g


def _attn_graph_opdef():
    """The same attention, everything resolved from the OpDef."""
    q = ein.tensor("q", "b h s d", (B, H, S, D))
    k = ein.tensor("k", "b k s d", (B, K, S, D))
    v = ein.tensor("v", "b k s d", (B, K, S, D))
    att = ein.opaque("flash_attention", [q, k, v],
                     in_labels=[("b", "h", "s", "d"), ("b", "k", "s", "d"),
                                ("b", "k", "s", "d")])
    g, _ = ein.trace([att])
    return g


def test_opdef_comm_prices_identically_to_explicit_params():
    """The DP over an OpDef-declared graph is bit-identical (plan + cost)
    to the historical explicit comm-param declaration."""
    g_old, g_new = _attn_graph_explicit(), _attn_graph_opdef()
    for mesh_axes in ({"data": 2, "model": 4}, {"data": 4, "model": 2}):
        p_old = eindecomp(g_old, 8, mesh_axes=mesh_axes)
        p_new = eindecomp(g_new, 8, mesh_axes=mesh_axes)
        assert p_old.cost == p_new.cost
        assert p_old.d_by_node == p_new.d_by_node
        assert p_old.axes_by_node == p_new.axes_by_node
        assert plan_cost(g_old, p_old) == plan_cost(g_new, p_new)


def test_explicit_comm_param_overrides_opdef():
    """A per-node comm=[] still silences the OpDef template (the historical
    per-call override)."""
    g = EinGraph()
    q = g.input("q", "b h s d", (B, H, S, D))
    k = g.input("k", "b k s d", (B, K, S, D))
    v = g.input("v", "b k s d", (B, K, S, D))
    o = g.opaque("flash_attention", [q, k, v], "b h s d", (B, H, S, D),
                 in_labels=[("b", "h", "s", "d"), ("b", "k", "s", "d"),
                            ("b", "k", "s", "d")],
                 shardable={"b", "h", "k", "s"}, comm=[])
    assert opdef.comm_for_node(g.nodes[o]) == []
    assert opaque_rules.resolve_rule_name(g.nodes[o]) == "ring"  # shard_rule


def test_register_opaque_shims_are_deprecated_but_work():
    for surface in (engine.register_opaque, ein.register_opaque):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            surface("t_legacy", lambda x: jnp.asarray(x) * 3)
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
        assert "defop" in str(w[0].message)
        assert np.asarray(engine.OPAQUE_FNS["t_legacy"](np.ones(2)))[0] == 3
        del engine.OPAQUE_FNS["t_legacy"]
        assert "t_legacy" not in engine.OPAQUE_FNS


def test_impl_view_override_roundtrip():
    """monkeypatch.setitem semantics over the view: an override wins over
    the registered kernel/impl and deletion restores the original."""
    orig = engine.OPAQUE_FNS["flash_attention"]
    engine.OPAQUE_FNS["flash_attention"] = lambda *a, **k: "stub"
    assert engine.OPAQUE_FNS["flash_attention"](None) == "stub"
    del engine.OPAQUE_FNS["flash_attention"]
    assert engine.OPAQUE_FNS["flash_attention"] is orig
    assert opdef.get("flash_attention") is not None  # record survives


def test_builtin_impls_match_their_signatures():
    """The built-in catalog registers with check_impl=False (running an
    impl would initialize the jax backend inside the pure-planning path);
    this sweep runs the signature-vs-impl cross-validation for every
    builtin instead — including the stub-provided MoE/scan impls."""
    make_stub_opaques()
    for kind in opdef.list_ops():
        opdef.check_impl(kind)


def test_planning_never_initializes_the_jax_backend():
    """Loading the op catalog from the planner (comm pricing, rule
    validation) must not execute any impl: a DP run on a fresh registry
    performs zero jax array operations (the musicgen-subprocess hang
    regression — backend init probes TPU metadata and can stall for
    minutes in constrained environments)."""
    import subprocess
    import sys

    snippet = (
        "import sys\n"
        "from repro.models.eingraphs import build_graph\n"
        "from repro.configs import get_config, reduced, SHAPES\n"
        "from repro.core.decomp import eindecomp\n"
        "g = build_graph(reduced(get_config('mixtral-8x7b')),"
        " SHAPES['train_4k'])\n"
        "plan = eindecomp(g, 8, mesh_axes={'data': 2, 'model': 4})\n"
        "assert plan.cost > 0\n"
        "import jax\n"
        "assert not jax._src.xla_bridge._backends, 'backend initialized'\n")
    proc = subprocess.run(
        [sys.executable, "-c", snippet], capture_output=True, text=True,
        env={"PYTHONPATH": "src"}, timeout=120,
        cwd=str(Path(__file__).resolve().parent.parent))
    assert proc.returncode == 0, proc.stderr


def test_no_private_registry_use_outside_core():
    """The lightweight grep ban (mirrors the ruff TID251 config): no module
    outside core/ touches the private registries directly — everything
    goes through the OpDef API."""
    src = Path(__file__).resolve().parent.parent / "src" / "repro"
    banned = re.compile(
        r"OPAQUE_FNS|MAP_FNS|GRAD_MAPS|opaque_rules\.RULES|RULES\["
        r"|opdef\._REGISTRY")
    offenders = []
    for path in src.rglob("*.py"):
        if (src / "core") in path.parents:
            continue
        for i, line in enumerate(path.read_text().splitlines(), 1):
            if banned.search(line):
                offenders.append(f"{path.relative_to(src)}:{i}: {line.strip()}")
    assert not offenders, (
        "private registry use outside core/ (use ein.defop / "
        "opdef.provide_impl):\n" + "\n".join(offenders))


# ---------------------------------------------------------------------------
# 4. autodiff through opaques
# ---------------------------------------------------------------------------


def test_grad_without_vjp_names_the_op(defop_tmp):
    defop_tmp("t_novjp", "b s -> b s", fn=lambda x: jnp.asarray(x) * 2)
    x = ein.tensor("x", "b s", (2, 4))
    y = ein.opaque("t_novjp", [x])
    loss = ein.einsum("b s ->", y, combine="id", agg="sum")
    prog = ein.Program({"loss": loss})
    with pytest.raises(NotImplementedError, match="t_novjp.*vjp"):
        prog.grad("x")


def test_auto_vjp_matches_jax_grad(defop_tmp):
    defop_tmp("t_sq", "b s -> b s", vjp="auto",
              fn=lambda x: jnp.square(jnp.asarray(x)) * 0.5)
    x = ein.tensor("x", "b s", (3, 5))
    y = ein.opaque("t_sq", [x])
    loss = ein.einsum("b s ->", y, combine="id", agg="sum")
    run = ein.Program({"loss": loss}).grad("x").compile()
    X = RNG.normal(size=(3, 5)).astype(np.float32)
    got = run({"x": X})["grad_x"]
    np.testing.assert_allclose(np.asarray(got), X, rtol=1e-5, atol=1e-6)


def test_impl_view_rejects_cross_category_override():
    """Op kinds share one namespace: an opaque-view write over a registered
    *map* op would silently replace its execution everywhere (the old
    split dicts kept such writes inert), so it must be rejected."""
    with pytest.raises(opdef.OpDefError, match="registered as a map op"):
        engine.OPAQUE_FNS["relu"] = lambda x: x
    assert opdef.get("relu").impl_override is None


def test_auto_vjp_differentiates_the_dense_reference(defop_tmp):
    """The auto VJP must pull back through the dense reference impl, not
    the kernel dispatcher (which may route to a pallas_call with no AD
    rule on TPU) and not a test override."""
    defop_tmp("t_kerngrad", "b s -> b s", vjp="auto",
              fn=lambda x: jnp.square(jnp.asarray(x)),
              kernel=lambda x: jax.lax.stop_gradient(
                  jnp.square(jnp.asarray(x))))
    x = ein.tensor("x", "b s", (2, 4))
    loss = ein.einsum("b s ->", ein.opaque("t_kerngrad", [x]),
                      combine="id", agg="sum")
    run = ein.Program({"loss": loss}).grad("x").compile()
    X = RNG.normal(size=(2, 4)).astype(np.float32)
    # the kernel's stop_gradient would zero this; the reference gives 2x
    np.testing.assert_allclose(np.asarray(run({"x": X})["grad_x"]), 2 * X,
                               rtol=1e-5, atol=1e-6)


def test_grad_through_flash_attention():
    """Program.grad through the builtin flash-attention opaque (auto VJP):
    matches jax.grad of the dense composition for every q/k/v input."""
    b, h, s, d = 2, 2, 8, 4
    q = ein.tensor("q", "b h s d", (b, h, s, d))
    k = ein.tensor("k", "b k s d", (b, h, s, d))
    v = ein.tensor("v", "b k s d", (b, h, s, d))
    att = ein.opaque("flash_attention", [q, k, v],
                     in_labels=[("b", "h", "s", "d"), ("b", "k", "s", "d"),
                                ("b", "k", "s", "d")])
    loss = ein.einsum("b h s d ->", att, combine="id", agg="sum")
    run = ein.Program({"loss": loss}).grad(["q", "k", "v"]).compile()
    feeds = {n: (RNG.normal(size=(b, h, s, d)) * 0.3).astype(np.float32)
             for n in ("q", "k", "v")}
    got = run(feeds)

    from repro.kernels import ref

    def dense(qq, kk, vv):
        return jnp.sum(ref.attention(qq, kk, vv, causal=True))

    want = jax.grad(dense, argnums=(0, 1, 2))(
        feeds["q"], feeds["k"], feeds["v"])
    for name, w in zip(("q", "k", "v"), want):
        np.testing.assert_allclose(np.asarray(got[f"grad_{name}"]),
                                   np.asarray(w), rtol=1e-4, atol=1e-5,
                                   err_msg=f"grad_{name}")


def test_grad_skips_integer_inputs():
    """gather_rows: the table gets a scatter-add gradient, the int ids get
    none (and asking for one is a clear error, not a silent float0)."""
    table = ein.tensor("table", "v a", (8, 4))
    ids = ein.tensor("ids", "b s", (2, 3), dtype="int32")
    emb = ein.opaque("gather_rows", [table, ids])
    loss = ein.einsum("b s a ->", emb, combine="id", agg="sum")
    prog = ein.Program({"loss": loss})
    run = prog.grad("table").compile()
    T = RNG.normal(size=(8, 4)).astype(np.float32)
    ids_v = np.array([[1, 2, 1], [0, 7, 1]], np.int32)
    got = np.asarray(run({"table": T, "ids": ids_v})["grad_table"])
    want = np.zeros_like(T)
    np.add.at(want, ids_v.reshape(-1), 1.0)
    np.testing.assert_allclose(got, want)
    with pytest.raises(ValueError, match="no gradient path"):
        prog.grad("ids")


# ---------------------------------------------------------------------------
# 5. the channel-parallel `local` scan rule (ROADMAP item)
# ---------------------------------------------------------------------------


def _scan_graph(f=16):
    g = EinGraph("scan")
    h = g.input("h", "b s f", (4, 8, f))
    o = g.opaque("mlstm_scan", [h], "b s f", (4, 8, f),
                 in_labels=[("b", "s", "f")], shardable={"b", "f"})
    return g, o


def _uniform_plan(g, axes_cfg, sizes, p=8):
    """Every non-input node gets the same label->axes map (with the d
    vector the axes imply, so comm pricing sees the real shard counts);
    graph inputs stay replicated."""
    plan = Plan(p=p, mode="mesh")
    for n in g.nodes:
        if n.kind == "input":
            plan.d_by_node[n.nid] = {l: 1 for l in n.labels}
            plan.axes_by_node[n.nid] = {}
        else:
            plan.d_by_node[n.nid] = {
                l: math.prod(sizes[a] for a in axes_cfg.get(l, ()))
                for l in n.labels}
            plan.axes_by_node[n.nid] = dict(axes_cfg)
    return plan


def test_scan_local_rule_zero_collectives():
    """Channel-only sharding runs the scan fully locally — zero wire
    elements, where the replicate fallback gathered the full state."""
    g, o = _scan_graph()
    sizes = {"data": 2, "model": 4}
    plan = _uniform_plan(g, {"b": ("data",), "f": ("model",)}, sizes)
    sched = spmd.build_schedule(g, plan, sizes, [o])
    assert sched.trace.rule_by_node[o] == "local"
    assert len(sched.trace) == 0, sched.trace.summary()
    assert sched.layouts[o] == (("data",), (), ("model",))


def test_scan_local_rule_falls_back_on_indivisible_channel():
    g, o = _scan_graph(f=12)  # 12 % 8 != 0 under f=(data, model)
    sizes = {"data": 2, "model": 4}
    plan = _uniform_plan(g, {"f": ("data", "model")}, sizes)
    sched = spmd.build_schedule(g, plan, sizes, [o])
    assert sched.trace.rule_by_node[o] == "replicate"


def test_scan_local_execution_matches_dense():
    make_stub_opaques()
    g, o = _scan_graph()
    mesh = make_host_mesh((2, 4))
    sizes = engine.mesh_axes_dict(mesh)
    plan = _uniform_plan(g, {"b": ("data",), "f": ("model",)}, sizes,
                         p=math.prod(sizes.values()))
    fn = jax.jit(engine.make_runner(g, [o], plan=plan, mesh=mesh,
                                    executor="shard_map"))
    feeds = {0: (RNG.normal(size=(4, 8, 16))).astype(np.float32)}
    got = np.asarray(fn(feeds[0]))
    np.testing.assert_allclose(got, eval_graph_dense(g, feeds)[o],
                               rtol=1e-5, atol=1e-6)


def test_zoo_scans_lower_local_with_zero_wire():
    """The DP-planned xlstm/hymba cells: every scan node lowers through the
    local rule and moves zero wire elements (the bench_spmd --check
    property for the scan family)."""
    from repro.configs import get_config, reduced
    from repro.configs.base import ShapeConfig
    from repro.models.eingraphs import program_for

    for arch in ("xlstm-125m", "hymba-1.5b"):
        cfg = reduced(get_config(arch))
        g = program_for(cfg, ShapeConfig("eq", "prefill", 32, 4)).graph
        axes = {"data": 2, "model": 4}
        plan = eindecomp(g, 8, mesh_axes=axes, offpath_repart=True)
        sched = spmd.build_schedule(g, plan, axes)
        scans = [n for n in g.nodes if n.op.endswith("_scan")]
        assert scans
        for n in scans:
            assert sched.trace.rule_by_node[n.nid] == "local", (arch, n.name)
            assert sched.trace.elems_by_node.get(n.nid, 0) == 0, (arch, n.name)


# ---------------------------------------------------------------------------
# 6. end-to-end custom op, entirely outside core/
# ---------------------------------------------------------------------------


class _SeqMeanRule:
    """Custom shard rule for t_addmean: keep the plan layout, compute the
    per-shard partial sequence sum, psum it over the sequence axes."""

    name = "t_seqmean"

    def lower(self, g, node, ax_n, sizes):
        if len(node.inputs) != 1 or node.in_labels[0] != tuple(node.labels):
            return None
        b_l, s_l, f_l = node.labels
        layout = tuple(spmd._norm_axes(ax_n.get(l, ()), sizes)
                       for l in node.labels)
        s_axes = layout[1]
        seq_total = node.shape[1]
        events = []
        if s_axes:
            n_dev = math.prod(sizes.values())
            loc = spmd.local_shape(node.shape, layout, sizes)
            part = loc[0] * loc[2]  # the (b_loc, 1, f_loc) partial
            kk = math.prod(sizes[a] for a in s_axes)
            events.append(("psum", tuple(s_axes),
                           n_dev * 2 * (kk - 1) * part // kk,
                           n_dev * 2 * (kk - 1) * part // kk * 4))

        def run(args):
            from jax import lax

            (x,) = args
            part = jnp.sum(x, axis=1, keepdims=True)
            if s_axes:
                part = lax.psum(part, tuple(s_axes))
            return x + part / seq_total

        return opaque_rules.RuleLowering(
            arg_layouts=[layout], out_layout=layout, run=run, events=events)


def _addmean_dense(x):
    x = jnp.asarray(x)
    return x + jnp.mean(x, axis=1, keepdims=True)


@pytest.fixture
def addmean_op():
    """One declaration, zero core/ edits: signature, dense impl, VJP, comm
    declaration, and a custom shard rule."""
    opaque_rules.register_rule(_SeqMeanRule())

    @ein.op("t_addmean", "b s f -> b s f", shardable="b s f", vjp="auto",
            comm=[{"kind": "ring", "label": "s", "input": 0,
                   "rule": "t_seqmean"}])
    def addmean(x):
        return _addmean_dense(x)

    yield
    opdef.unregister("t_addmean")
    opaque_rules.RULES.pop("t_seqmean", None)


def test_custom_op_dense_grad_and_shard_map(addmean_op):
    b, s, f = 4, 16, 8
    X = (RNG.normal(size=(b, s, f))).astype(np.float32)

    # -- dense path ----------------------------------------------------------
    x = ein.tensor("x", "b s f", (b, s, f))
    y = ein.opaque("t_addmean", [x], name="addmean")  # shape inferred
    prog = ein.Program({"y": y})
    out = np.asarray(prog.compile()({"x": X})["y"])
    np.testing.assert_allclose(out, np.asarray(_addmean_dense(X)),
                               rtol=1e-6, atol=1e-6)

    # -- grad path (auto VJP through the custom impl) ------------------------
    loss = ein.einsum("b s f ->", y, combine="id", agg="sum")
    grad_run = ein.Program({"loss": loss}).grad("x").compile()
    got_g = np.asarray(grad_run({"x": X})["grad_x"])
    want_g = jax.grad(lambda v: jnp.sum(_addmean_dense(v)))(jnp.asarray(X))
    np.testing.assert_allclose(got_g, np.asarray(want_g),
                               rtol=1e-5, atol=1e-6)

    # -- shard_map executor: planned by the DP, lowered by the custom rule ---
    mesh = make_host_mesh((2, 4))
    run = prog.compile(mesh=mesh, executor="shard_map")
    got = np.asarray(run({"x": X})["y"])
    np.testing.assert_allclose(got, out, rtol=1e-5, atol=1e-6)
    g = prog.graph
    nid = next(n.nid for n in g.nodes if n.op == "t_addmean")
    tr = run.collectives
    assert tr.rule_by_node[nid] == "t_seqmean"
    # traced movement within the node's slice of the §7 objective
    assert tr.elems_by_node.get(nid, 0) <= \
        opaque_node_bound(g, run.plan, nid)

    # a plan that shards the sequence label exercises the rule's psum —
    # schedule assertions are device-free (explicit 8-way mesh sizes)
    sizes = {"data": 2, "model": 4}
    plan8 = _uniform_plan(g, {"s": ("model",), "b": ("data",)}, sizes)
    sched = spmd.build_schedule(g, plan8, sizes, [nid])
    assert sched.trace.rule_by_node[nid] == "t_seqmean"
    assert sched.trace.counts == {"psum": 1}
    assert sched.trace.elems_by_node[nid] <= opaque_node_bound(g, plan8, nid)
    # ...and the sharded program still computes the same values on
    # whatever host mesh exists (8 real devices on the multi-device job)
    plan_live = _uniform_plan(g, {"s": ("model",), "b": ("data",)},
                              engine.mesh_axes_dict(mesh))
    fn = jax.jit(engine.make_runner(g, None, plan=plan_live, mesh=mesh,
                                    executor="shard_map"))
    np.testing.assert_allclose(np.asarray(fn(X)), out, rtol=1e-5, atol=1e-6)
