"""Canonicalization + plan-cache invariants (core/canon.py, core/plancache.py).

The contract under test: the canonical hash is invariant under exactly the
transformations that leave the §8 plan space unchanged — per-node label
renaming, joint (label, bound) permutation, and commutative operand order —
and a plan pulled from the cache (in-memory or through the on-disk JSON
store) prices identically to a freshly planned one on every model-zoo graph.
"""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core import canon, engine
from repro.core.decomp import eindecomp, eindecomp_tree, plan_cost
from repro.core.einsum import EinGraph, EinSpec
from repro.core.plancache import PlanCache
from repro.models.eingraphs import build_graph

ZOO = ["llama-7b"] + list(ARCH_IDS)
MESH = {"data": 2, "model": 2}
P = 4


def chain_graph(labels=("i", "j", "k", "l"), name="chain", swap=False):
    i, j, k, l = labels
    g = EinGraph(name)
    a = g.input("A", (i, j), (64, 128))
    b = g.input("B", (j, k), (128, 64))
    c = g.input("C", (k, l), (64, 32))
    if swap:
        ab = g.einsum(f"{j}{k},{i}{j}->{i}{k}", b, a)
    else:
        ab = g.einsum(f"{i}{j},{j}{k}->{i}{k}", a, b)
    g.einsum(f"{i}{k},{k}{l}->{i}{l}", ab, c)
    return g


# ---------------------------------------------------------------------------
# canonical-hash invariants
# ---------------------------------------------------------------------------


def test_label_renamed_graphs_hash_identically():
    assert canon.graph_key(chain_graph()) == \
        canon.graph_key(chain_graph(labels=("p", "q", "r", "t")))


def test_relabel_graph_helper_hashes_identically():
    g = chain_graph()
    assert canon.graph_key(canon.relabel_graph(g)) == canon.graph_key(g)


def test_commutative_operand_swap_hashes_identically():
    assert canon.graph_key(chain_graph(swap=True)) == \
        canon.graph_key(chain_graph())


def test_non_commutative_operand_swap_differs():
    def build(order):
        g = EinGraph()
        a = g.input("A", "ij", (8, 8))
        b = g.input("B", "jk", (8, 8))
        args = (a, b) if order == 0 else (b, a)
        expr = "ij,jk->ik" if order == 0 else "jk,ij->ik"
        g.einsum(expr, *args, combine="sub", agg="sum")
        return g

    assert canon.graph_key(build(0)) != canon.graph_key(build(1))


def test_non_isomorphic_graphs_do_not_collide():
    keys = set()
    g1 = chain_graph()
    keys.add(canon.graph_key(g1))
    # different bounds
    g2 = EinGraph()
    a = g2.input("A", "ij", (64, 64))
    b = g2.input("B", "jk", (64, 64))
    g2.einsum("ij,jk->ik", a, b)
    keys.add(canon.graph_key(g2))
    # different aggregation
    g3 = EinGraph()
    a = g3.input("A", "ij", (64, 64))
    b = g3.input("B", "jk", (64, 64))
    g3.einsum("ij,jk->ik", a, b, agg="max")
    keys.add(canon.graph_key(g3))
    # different structure (extra map)
    g4 = EinGraph()
    a = g4.input("A", "ij", (64, 64))
    b = g4.input("B", "jk", (64, 64))
    ab = g4.einsum("ij,jk->ik", a, b)
    g4.map("relu", ab)
    keys.add(canon.graph_key(g4))
    assert len(keys) == 4


def test_zoo_graphs_have_distinct_keys():
    keys = {canon.graph_key(build_graph(get_config(a), SHAPES["train_4k"]))
            for a in ZOO}
    assert len(keys) == len(ZOO)


def test_spec_key_invariants():
    s = EinSpec((("i", "j"), ("j", "k")), ("i", "k"))
    renamed_swapped = EinSpec((("q", "r"), ("p", "q")), ("p", "r"))
    assert canon.spec_key(s) == canon.spec_key(renamed_swapped)
    noncomm = EinSpec((("i", "j"), ("j", "k")), ("i", "k"), "sub", "sum")
    noncomm_swapped = EinSpec((("j", "k"), ("i", "j")), ("i", "k"), "sub", "sum")
    assert canon.spec_key(noncomm) != canon.spec_key(noncomm_swapped)
    assert canon.spec_key(s) != canon.spec_key(noncomm)
    # bound signature distinguishes extents but not their label names
    assert canon.spec_key(s, {"i": 4, "j": 8, "k": 4}) == \
        canon.spec_key(renamed_swapped, {"p": 4, "q": 8, "r": 4})
    assert canon.spec_key(s, {"i": 4, "j": 8, "k": 4}) != \
        canon.spec_key(s, {"i": 8, "j": 8, "k": 4})


# ---------------------------------------------------------------------------
# cache behavior
# ---------------------------------------------------------------------------


def test_pow2_cache_hit_same_cost():
    g = chain_graph()
    cache = PlanCache()
    fresh = eindecomp(g, 8, cache=cache)
    warm = eindecomp(g, 8, cache=cache)
    assert cache.hits == 1
    assert plan_cost(g, warm) == fresh.cost


def test_renamed_graph_is_cache_hit():
    g = chain_graph()
    cache = PlanCache()
    fresh = eindecomp(g, 8, offpath_repart=True, cache=cache)
    g2 = canon.relabel_graph(g)
    hit = eindecomp(g2, 8, offpath_repart=True, cache=cache)
    assert cache.hits == 1 and cache.misses == 1
    assert plan_cost(g2, hit) == fresh.cost


def test_different_p_and_cost_mode_are_distinct_entries():
    g = chain_graph()
    cache = PlanCache()
    eindecomp(g, 4, cache=cache)
    eindecomp(g, 8, cache=cache)
    eindecomp(g, 8, cost_mode="collective", cache=cache)
    assert cache.hits == 0 and len(cache) == 3


def test_tree_planner_cached_separately():
    g = chain_graph()
    cache = PlanCache()
    dag = eindecomp(g, 8, cache=cache)
    tree = eindecomp_tree(g, 8, cache=cache)
    assert len(cache) == 2
    tree2 = eindecomp_tree(g, 8, cache=cache)
    assert tree2.cost == tree.cost
    assert plan_cost(g, dag) == dag.cost


def test_lru_eviction():
    g = chain_graph()
    cache = PlanCache(capacity=2)
    for p in (2, 4, 8):
        eindecomp(g, p, cache=cache)
    assert len(cache) == 2
    eindecomp(g, 2, cache=cache)  # evicted -> replanned, not an error
    assert cache.misses == 4


def test_disk_backed_eviction_revives_without_replanning(tmp_path):
    """A disk-backed cache holds evicted entries as JSON: looking one up
    again must revive it (a hit), never re-run the DP."""
    g = chain_graph()
    cache = PlanCache(capacity=1, path=str(tmp_path / "plans.json"))
    p2 = eindecomp(g, 2, cache=cache)
    eindecomp(g, 4, cache=cache)  # evicts the p=2 entry from the LRU
    revived = eindecomp(g, 2, cache=cache)
    assert cache.hits == 1 and cache.misses == 2
    assert plan_cost(g, revived) == p2.cost


@pytest.mark.parametrize("arch", ZOO)
def test_zoo_cache_roundtrip_cost_identical(arch, tmp_path):
    """Every model-zoo graph: plan fresh, round-trip through the on-disk
    JSON store in a new PlanCache (simulating a restart), and through a
    label-renamed copy; both must return plans with identical §7 cost."""
    cfg = get_config(arch)
    g = build_graph(cfg, SHAPES["train_4k"])
    store = str(tmp_path / "plans.json")

    cache = PlanCache(path=store)
    fresh = eindecomp(g, P, mesh_axes=MESH, offpath_repart=True, cache=cache)

    # restart: a brand-new cache object warm-started from the JSON file
    cache2 = PlanCache.open(store)
    warm = eindecomp(g, P, mesh_axes=MESH, offpath_repart=True, cache=cache2)
    assert cache2.hits == 1 and cache2.misses == 0
    assert plan_cost(g, warm) == fresh.cost

    # isomorphic transfer through the restarted cache
    g2 = canon.relabel_graph(g)
    renamed = eindecomp(g2, P, mesh_axes=MESH, offpath_repart=True,
                        cache=cache2)
    assert cache2.hits == 2
    assert plan_cost(g2, renamed) == fresh.cost
    # mesh-mode plans must come back with usable axis assignments
    assert renamed.axes_by_node


def test_lru_eviction_never_deletes_disk_entries(tmp_path):
    """The disk store only grows by use: evicting an entry from the
    in-memory LRU (or writing through a small-capacity cache) must not drop
    previously persisted plans from the JSON file."""
    store = str(tmp_path / "plans.json")
    cache = PlanCache(capacity=1, path=store)
    eindecomp(chain_graph(), 2, cache=cache)
    eindecomp(chain_graph(), 4, cache=cache)  # evicts the p=2 entry from RAM
    assert len(cache) == 1
    reloaded = PlanCache(capacity=8, path=store)
    assert len(reloaded) == 2  # both survive on disk
    warm = eindecomp(chain_graph(), 2, cache=reloaded)
    assert reloaded.hits == 1 and warm.p == 2


def test_eviction_with_deferred_save_persists_everything(tmp_path):
    """autosave=False bulk-planning (the documented pattern): entries
    evicted before the final save() must still reach the store."""
    store = str(tmp_path / "plans.json")
    cache = PlanCache(capacity=1, path=store, autosave=False)
    eindecomp(chain_graph(), 2, cache=cache)
    eindecomp(chain_graph(), 4, cache=cache)  # evicts p=2 before any save
    cache.save()
    assert len(PlanCache(capacity=8, path=store)) == 2


def test_corrupt_store_degrades_to_cold_start(tmp_path):
    """The cache is an optimization: a corrupt JSON file must warn and start
    cold, never crash the job, and be overwritten with a valid store."""
    store = tmp_path / "plans.json"
    store.write_text("{ this is not json")
    with pytest.warns(UserWarning, match="unreadable store"):
        cache = PlanCache.open(str(store))
    assert len(cache) == 0
    g = chain_graph()
    eindecomp(g, 8, cache=cache)
    reloaded = PlanCache.open(str(store))  # insert rewrote a valid file
    assert len(reloaded) == 1


def test_make_runner_plans_through_cache():
    g = chain_graph()
    cache = PlanCache()
    f = engine.make_runner(g, p=8, cache=cache)
    assert len(cache) == 1
    rng = np.random.default_rng(0)
    feeds = [rng.normal(size=n.shape).astype(np.float32)
             for n in g.nodes if n.kind == "input"]
    np.testing.assert_allclose(np.asarray(f(*feeds)),
                               feeds[0] @ feeds[1] @ feeds[2], rtol=1e-4)
    # second runner for an isomorphic graph: planning is a pure cache hit
    g2 = canon.relabel_graph(g)
    engine.make_runner(g2, p=8, cache=cache)
    assert cache.hits == 1
    # planning inputs with nothing to apply or warm are rejected
    with pytest.raises(ValueError, match="no effect"):
        engine.make_runner(g2, p=8)
    # ...and a cache with nothing to plan with is rejected, not ignored
    with pytest.raises(ValueError, match="nothing to plan with"):
        engine.make_runner(g2, cache=cache)


def test_insert_from_relabeled_graph_stays_canonical():
    """Plan entries must live in each node's *own* label space: inserting
    from a graph whose node-local labels differ across nodes (relabel_graph)
    and hitting from a third relabeling must return input/map entries keyed
    by the caller's labels, never the inserting graph's."""
    g = chain_graph()
    g_ins = canon.relabel_graph(g)
    cache = PlanCache()
    fresh = eindecomp(g_ins, 8, offpath_repart=True, cache=cache)
    g_hit = canon.relabel_graph(g, lambda nid, l: f"{l}_x{nid}")
    hit = eindecomp(g_hit, 8, offpath_repart=True, cache=cache)
    assert cache.hits == 1
    for n in g_hit.nodes:
        universe = set(n.labels)
        if n.spec is not None:
            for ls in n.spec.in_labels:
                universe.update(ls)
        assert set(hit.d_by_node[n.nid]) <= universe, (n.nid, hit.d_by_node[n.nid])
    assert plan_cost(g_hit, hit) == plan_cost(g_ins, fresh)


def test_path_memo_reuses_isomorphic_layers():
    """Two structurally identical attention+ffn periods inside one graph:
    the second period's path DP must hit the memo."""
    cfg = get_config("llama-7b")
    g = build_graph(cfg, SHAPES["train_4k"])
    cache = PlanCache()
    eindecomp(g, P, mesh_axes=MESH, offpath_repart=True, cache=cache)
    assert cache.path_hits >= 1, cache.stats
