"""Roofline table builder: reads artifacts/dryrun/*.json (written by
``python -m repro.launch.dryrun``) and renders the EXPERIMENTS.md §Roofline
markdown table plus CSV rows for benchmarks.run."""
from __future__ import annotations

import glob
import json
import os


def load(out_dir: str = "artifacts/dryrun") -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def rows(out_dir: str = "artifacts/dryrun") -> list[tuple]:
    out = []
    for r in load(out_dir):
        if not r.get("ok"):
            continue
        key = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r.get("tag"):
            key += f"_{r['tag']}"
        rl = r["roofline"]
        out.append((key + "_t_compute_s", rl["t_compute_s"],
                    r["bottleneck"]))
        out.append((key + "_t_memory_s", rl["t_memory_s"], ""))
        out.append((key + "_t_collective_s", rl["t_collective_s"], ""))
        out.append((key + "_frac", r["roofline_fraction"],
                    f"useful={rl['useful_flops_ratio']:.2f}"))
    return out


def markdown_table(out_dir: str = "artifacts/dryrun",
                   tag: str = "") -> str:
    lines = [
        "| arch | shape | mesh | GB/dev | t_compute | t_memory | t_coll |"
        " bound | roofline frac | useful FLOPs |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(out_dir):
        if r.get("tag", "") != tag:
            continue
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — |"
                f" — | *skipped: full attention* | — | — |")
            continue
        if not r.get("ok"):
            continue
        rl = r["roofline"]
        mem = r.get("memory", {}).get("per_device_gb", float("nan"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {mem:.2f} |"
            f" {rl['t_compute_s']:.3e} | {rl['t_memory_s']:.3e} |"
            f" {rl['t_collective_s']:.3e} | {r['bottleneck']} |"
            f" {r['roofline_fraction']:.2f} |"
            f" {rl['useful_flops_ratio']:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
