"""Planner-latency benchmark for the canonicalization + plan cache.

For every graph in the models/eingraphs.py model zoo, measures

  cold  — a fresh §8 EinDecomp run (what every request paid before caching)
  warm  — a cache hit: canonical hash + LRU lookup + label translation

and *asserts*:

  * the cached plan's exact §7 cost equals the freshly planned cost;
  * a label-renamed copy of the graph is a cache **hit** (canonicalization
    actually transfers plans across isomorphic graphs);
  * on the llama-block graph, warm latency is >= 10x lower than cold.

Run:
  PYTHONPATH=src python benchmarks/bench_plancache.py            # full zoo
  PYTHONPATH=src python benchmarks/bench_plancache.py --smoke    # CI subset

Rows are printed as ``PLANROW <graph> cold_ms warm_ms speedup`` so CI logs
diff cleanly across commits, and the run writes ``BENCH_plancache.json``
(``{name, metric, value, unit}`` rows) at the repo root so planner latency
is tracked across PRs.
"""
from __future__ import annotations

import argparse
import math
import time
from pathlib import Path

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core import canon
from repro.core.decomp import eindecomp, plan_cost
from repro.core.plancache import PlanCache
from repro.models.eingraphs import build_graph

SMOKE_ARCHS = ["llama-7b", "mixtral-8x7b", "xlstm-125m"]
REPO_ROOT = Path(__file__).resolve().parent.parent


def _time(fn, reps: int = 1) -> tuple[float, object]:
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_graph(name: str, g, mesh_axes: dict[str, int]) -> dict:
    p = math.prod(mesh_axes.values())

    t_cold, fresh = _time(
        lambda: eindecomp(g, p, mesh_axes=mesh_axes, offpath_repart=True))

    cache = PlanCache()
    eindecomp(g, p, mesh_axes=mesh_axes, offpath_repart=True, cache=cache)
    t_warm, cached = _time(
        lambda: eindecomp(g, p, mesh_axes=mesh_axes, offpath_repart=True,
                          cache=cache),
        reps=5)
    assert cache.hits >= 5, cache.stats

    # correctness: the cached plan prices identically to the fresh one
    c_fresh, c_cached = plan_cost(g, fresh), plan_cost(g, cached)
    assert c_cached == c_fresh, (name, c_cached, c_fresh)

    # transfer: a label-renamed isomorphic copy must hit, at the same cost
    g2 = canon.relabel_graph(g)
    hits_before = cache.hits
    t_ren, renamed = _time(
        lambda: eindecomp(g2, p, mesh_axes=mesh_axes, offpath_repart=True,
                          cache=cache))
    assert cache.hits == hits_before + 1, f"{name}: renamed copy missed"
    assert plan_cost(g2, renamed) == c_fresh, (name, "renamed cost drifted")

    return {"name": name, "cold_ms": t_cold * 1e3, "warm_ms": t_warm * 1e3,
            "renamed_ms": t_ren * 1e3, "cost": c_fresh,
            "speedup": t_cold / max(t_warm, 1e-9)}


def _bench_rows(rows: list[dict]) -> list[dict]:
    """{name, metric, value, unit} rows — the cross-PR perf trajectory."""
    out = []
    for r in rows:
        out += [
            {"name": f"plancache/{r['name']}/cold", "metric": "wall_clock",
             "value": round(r["cold_ms"], 3), "unit": "ms"},
            {"name": f"plancache/{r['name']}/warm", "metric": "wall_clock",
             "value": round(r["warm_ms"], 4), "unit": "ms"},
            {"name": f"plancache/{r['name']}/speedup", "metric": "ratio",
             "value": round(r["speedup"], 1), "unit": "x"},
        ]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: 3 archs on a 4x4 mesh")
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES))
    ap.add_argument("--bench-out",
                    default=str(REPO_ROOT / "BENCH_plancache.json"),
                    help="perf-trajectory JSON (default: repo root)")
    args = ap.parse_args()

    archs = SMOKE_ARCHS if args.smoke else ["llama-7b"] + list(ARCH_IDS)
    mesh_axes = {"data": 4, "model": 4} if args.smoke else \
                {"data": 16, "model": 16}
    shape = SHAPES[args.shape]

    rows = []
    for arch in archs:
        cfg = get_config(arch)
        if not cfg.supports(shape):
            continue
        g = build_graph(cfg, shape)
        row = bench_graph(arch, g, mesh_axes)
        rows.append(row)
        print(f"PLANROW {row['name']:18s} cold {row['cold_ms']:9.2f}ms  "
              f"warm {row['warm_ms']:7.3f}ms  renamed-hit "
              f"{row['renamed_ms']:7.3f}ms  speedup {row['speedup']:8.0f}x",
              flush=True)

    if not rows:
        raise SystemExit(f"no arch supports shape {args.shape!r}")
    if args.bench_out:
        from _bench_io import write_bench_json

        write_bench_json(_bench_rows(rows), Path(args.bench_out))
    llama = next((r for r in rows if r["name"] == "llama-7b"), None)
    if llama is not None:
        assert llama["speedup"] >= 10, (
            f"warm plan must be >=10x faster than cold on llama-block, got "
            f"{llama['speedup']:.1f}x")
    gmean = 1.0
    for r in rows:
        gmean *= r["speedup"]
    gmean **= 1.0 / len(rows)
    print(f"\n{len(rows)} graphs, mesh {mesh_axes}: geomean warm speedup "
          f"{gmean:.0f}x; all cached plans cost-identical to fresh; all "
          f"renamed copies were cache hits.  [OK]")


if __name__ == "__main__":
    main()
