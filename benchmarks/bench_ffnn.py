"""Paper Experiment 2 (Fig 9): high-dimensional FFNN classifier training.

AmazonCat-14K proportions: 597,540 input features, 8,192 hidden neurons,
14,588 labels; batch 128 / 512.  The training computation (fwd + bwd via
the graph autodiff of core/autodiff.py) is planned by EinDecomp and
compared against forced data-parallelism — the paper's headline result is
that DP broadcasts the giant model and loses.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.autodiff import grad_graph
from repro.core.decomp import eindecomp, plan_cost, plan_data_parallel
from repro.core.einsum import EinGraph

FEATS = 597_540
HIDDEN = 8_192
LABELS = 14_588


def ffnn_train_graph(batch: int, feats: int = FEATS, hidden: int = HIDDEN,
                     labels: int = LABELS) -> tuple[EinGraph, list[int]]:
    g = EinGraph("ffnn")
    X = g.input("X", "bf", (batch, feats))
    W1 = g.input("W1", "fh", (feats, hidden))
    W2 = g.input("W2", "hc", (hidden, labels))
    Y = g.input("Y", "bc", (batch, labels))
    h1 = g.einsum("bf,fh->bh", X, W1)
    a1 = g.map("relu", h1)
    p = g.einsum("bh,hc->bc", a1, W2)
    diff = g.einsum("bc,bc->bc", p, Y, combine="sub", agg="")
    sq = g.map("square", diff)
    loss = g.einsum("bc->", sq, combine="id", agg="sum")
    gg, grads, seed = grad_graph(g, loss, [W1, W2])
    return gg, [grads[W1], grads[W2]]


def run(p: int = 16) -> list[tuple]:
    rows = []
    for batch in (128, 512):
        # feature counts swept like Fig 9's x-axis (scaled to fit planning)
        for feats in (8_192, 65_536, 262_144, FEATS):
            gg, _ = ffnn_train_graph(batch, feats=feats)
            ein = eindecomp(gg, p, offpath_repart=True)
            dp = plan_data_parallel(gg, p, batch_label="b")
            rows.append((f"exp2_b{batch}_f{feats}_eindecomp_cost",
                         ein.cost, ""))
            rows.append((f"exp2_b{batch}_f{feats}_dataparallel_cost",
                         dp.cost, f"dp/ein={dp.cost / max(ein.cost, 1):.1f}x"))
    return rows


def run_training_wallclock(steps: int = 5) -> list[tuple]:
    """Actually train the (scaled-down) FFNN through the sharded engine and
    confirm the loss drops — end-to-end correctness of the planned
    training graph."""
    import jax
    import jax.numpy as jnp

    from repro.core import engine
    from repro.core.autodiff import grad_graph

    batch, feats, hidden, labels = 64, 4096, 512, 256
    g = EinGraph("ffnn_small")
    X = g.input("X", "bf", (batch, feats))
    W1 = g.input("W1", "fh", (feats, hidden))
    W2 = g.input("W2", "hc", (hidden, labels))
    Y = g.input("Y", "bc", (batch, labels))
    h1 = g.einsum("bf,fh->bh", X, W1)
    a1 = g.map("relu", h1)
    pr = g.einsum("bh,hc->bc", a1, W2)
    diff = g.einsum("bc,bc->bc", pr, Y, combine="sub", agg="")
    sq = g.map("square", diff)
    loss = g.einsum("bc->", sq, combine="id", agg="sum")
    gg, grads, seed = grad_graph(g, loss, [W1, W2])

    rng = np.random.default_rng(0)
    w1 = jnp.asarray(rng.normal(size=(feats, hidden)) * feats ** -0.5,
                     jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(hidden, labels)) * hidden ** -0.5,
                     jnp.float32)
    Xv = jnp.asarray(rng.normal(size=(batch, feats)), jnp.float32)
    true_w = rng.normal(size=(feats, labels)) * feats ** -0.5
    Yv = jnp.asarray(np.maximum(np.asarray(Xv) @ true_w, 0), jnp.float32)

    in_ids = gg.input_ids()
    out_ids = [loss, grads[W1], grads[W2]]
    runner = jax.jit(engine.make_runner(gg, out_ids))

    feeds = {X: Xv, Y: Yv, seed: jnp.ones(())}
    losses = []
    t0 = time.time()
    for _ in range(steps):
        args = [feeds.get(i) if i in feeds else (w1 if i == W1 else w2)
                for i in in_ids]
        l, g1, g2 = runner(*args)
        losses.append(float(l))
        w1 = w1 - 1e-2 * g1 / batch
        w2 = w2 - 1e-2 * g2 / batch
    dt = (time.time() - t0) / steps * 1e6
    assert losses[-1] < losses[0], f"loss did not drop: {losses}"
    return [("exp2_wall_train_step", dt,
             f"loss {losses[0]:.1f}->{losses[-1]:.1f}")]
