"""Paper Experiment 3 (Fig 10): LLaMA first-token inference (prefill),
EinDecomp vs the hand-written decompositions — Megatron (shard heads/ffn),
"sequence" (shard s), "attention" (shard heads only), data-parallel.

All plans are costed with the same §7 objective on the same llama-7b
prefill EinGraph (apples-to-apples, as the paper implements all baselines
on Einsummable).  Sweeps batch size at 4k tokens and GPU count at 1k/4k
tokens, mirroring the three panels of Fig 10.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.decomp import (Plan, eindecomp, node_bounds,
                               node_label_universe, plan_cost)
from repro.models.eingraphs import build_graph


def manual(g, p, assign: dict[str, int]) -> Plan:
    plan = Plan(p=p, mode="pow2")
    for n in g.nodes:
        labels = node_label_universe(n)
        bounds = node_bounds(g, n.nid)
        d = {l: 1 for l in labels}
        for l, ways in assign.items():
            if l in d and bounds[l] % ways == 0:
                d[l] = ways
        plan.d_by_node[n.nid] = d
    plan.cost = plan_cost(g, plan)
    return plan


def plans_for(g, p):
    return {
        "eindecomp": eindecomp(g, p, offpath_repart=True),
        "eindecomp_paper_lin": eindecomp(g, p, offpath_repart=False),
        "megatron": manual(g, p, {"b": 1, "h": p, "k": p, "f": p, "v": p}),
        "sequence": manual(g, p, {"s": p}),
        "attention": manual(g, p, {"h": p, "k": p}),
        "data_parallel": manual(g, p, {"b": p}),
    }


def _work_note(g, plan, p) -> str:
    """Manual plans may under-decompose some nodes (< p parallel pieces) —
    cheap on the §7 cost but idles devices; annotate for honesty."""
    starved = 0
    for n in g.nodes:
        if n.kind == "input":
            continue
        d = plan.d_by_node.get(n.nid, {})
        work = 1
        for v in d.values():
            work *= v
        if work < p:
            starved += 1
    return f"UNDERDECOMPOSED:{starved}nodes" if starved else ""


def run() -> list[tuple]:
    cfg = get_config("llama-7b")
    rows = []
    # panel 1: 8 devices, 4096 tokens, batch swept
    for batch in (1, 4, 16):
        g = build_graph(cfg, ShapeConfig("ftinf", "prefill", 4096, batch))
        for name, plan in plans_for(g, 8).items():
            rows.append((f"exp3_ftinf4k_b{batch}_p8_{name}", plan.cost,
                         _work_note(g, plan, 8)))
    # panels 2+3: batch 8 @1k and batch 4 @4k, device count swept
    for seq, batch in ((1024, 8), (4096, 4)):
        for p in (2, 4, 8):
            g = build_graph(cfg, ShapeConfig("ftinf", "prefill", seq, batch))
            for name, plan in plans_for(g, p).items():
                rows.append((f"exp3_s{seq}_b{batch}_p{p}_{name}",
                             plan.cost, _work_note(g, plan, p)))
    return rows


def run_wallclock() -> list[tuple]:
    """Wall-clock of a scaled-down llama prefill under the EinDecomp policy
    vs manual policies, through the production (GSPMD) path on host
    devices."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import reduced
    from repro.launch import steps as steps_mod
    from repro.launch.mesh import make_host_mesh, mesh_axes_dict
    from repro.models import transformer as tf
    from repro.models.eingraphs import plan_for
    from repro.models.policy import manual_policy

    cfg = reduced(get_config("llama-7b"))
    mesh = make_host_mesh((1, 1))
    shape = ShapeConfig("ftinf", "prefill", 128, 4)
    _, _, auto_policy = plan_for(cfg, shape, mesh_axes_dict(mesh))
    policies = {
        "eindecomp": auto_policy,
        "megatron": manual_policy({"h": "model", "f": "model", "v": "model",
                                   "b": "data"}),
        "sequence": manual_policy({"s": "model", "b": "data"}),
    }
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 128)), jnp.int32)
    rows = []
    for name, pol in policies.items():
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        params = jax.device_put(params, tf.param_shardings(cfg, pol, mesh))
        step = jax.jit(steps_mod.make_prefill_step(cfg, policy=pol, mesh=mesh))
        logits, _ = step(params, {"tokens": toks})  # compile
        jax.block_until_ready(logits)
        t0 = time.time()
        for _ in range(3):
            logits, _ = step(params, {"tokens": toks})
        jax.block_until_ready(logits)
        rows.append((f"exp3_wall_prefill_{name}",
                     (time.time() - t0) / 3 * 1e6, ""))
    return rows
