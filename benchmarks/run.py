"""Benchmark orchestrator: one function per paper table/figure.
Prints ``name,value,derived`` CSV (value is cost-model floats for plan
comparisons, microseconds for wall-clock rows, MB for memory rows)."""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    t0 = time.time()
    sections = []

    from benchmarks import (bench_ffnn, bench_llama_decomp, bench_matrix_chain,
                            bench_memory, roofline)

    sections.append(("Experiment 1: matrix chain (Figs 7-8)",
                     lambda: bench_matrix_chain.run()))
    sections.append(("Experiment 1: wall-clock (TRA runtime)",
                     lambda: bench_matrix_chain.run_wallclock()))
    sections.append(("Experiment 2: FFNN training (Fig 9)",
                     lambda: bench_ffnn.run()))
    sections.append(("Experiment 2: wall-clock training",
                     lambda: bench_ffnn.run_training_wallclock()))
    sections.append(("Experiment 3: LLaMA FTinf decompositions (Fig 10)",
                     lambda: bench_llama_decomp.run()))
    sections.append(("Experiment 3: wall-clock prefill",
                     lambda: bench_llama_decomp.run_wallclock()))
    sections.append(("Experiment 4: memory-constrained inference (Fig 11)",
                     lambda: bench_memory.run()))
    sections.append(("Roofline (from dry-run artifacts)",
                     lambda: roofline.rows()))

    failures = 0
    print("name,value,derived")
    for title, fn in sections:
        print(f"# {title}", flush=True)
        try:
            for name, value, derived in fn():
                print(f"{name},{value:.6g},{derived}", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
    print(f"# done in {time.time() - t0:.1f}s, {failures} section failures",
          flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
