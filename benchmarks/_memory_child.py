import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Child process for bench_memory: lowers a reduced llama prefill on an
8-device host mesh under two policies and prints per-device bytes.  Runs in
its own process because the parent's jax is already initialized with one
device."""
import jax

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh, mesh_axes_dict
from repro.models import transformer as tf
from repro.models.eingraphs import plan_for
from repro.models.policy import manual_policy


def main() -> None:
    cfg = reduced(get_config("llama-7b"))
    mesh = make_mesh((2, 4), ("data", "model"))
    for seq in (512, 2048, 8192):
        shape = ShapeConfig("mem", "prefill", seq, 8)
        _, _, auto = plan_for(cfg, shape, mesh_axes_dict(mesh))
        for name, pol in (("eindecomp", auto),
                          ("data_parallel", manual_policy({"b": "data"}))):
            params = tf.init_params(cfg, abstract=True)
            pshard = tf.param_shardings(cfg, pol, mesh)
            params = jax.tree.map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=sh),
                params, pshard)
            batch = tf.input_specs(cfg, shape)
            step = steps_mod.make_prefill_step(cfg, policy=pol, mesh=mesh)
            with mesh:
                compiled = jax.jit(step).lower(params, batch).compile()
            ma = compiled.memory_analysis()
            total = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                     + ma.output_size_in_bytes - ma.alias_size_in_bytes)
            print(f"MEMROW exp4_mem_s{seq}_{name} {total / 1e6:.3f}")


if __name__ == "__main__":
    main()
