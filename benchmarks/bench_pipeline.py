"""Pipeline-tier benchmark: bubble fractions, cut bytes, and the
per-stage traced <= priced contract, on a forced 8-device host mesh.

For every model-zoo family, across a (stages p, microbatches m) grid
(mixtral pipelines at m=1 only — MoE capacity routing couples rows across
the batch, which ``pipeline.batch_splittable`` rejects):

  1. build the static pipeline schedule (partition -> per-stage §8 DP
     through one shared plan cache -> GPipe cells + ppermute handoffs)
     and compile the pipelined runner over the combined (pp, data, model)
     mesh;
  2. compile the *unpipelined* baseline from the stitched full-graph plan
     on the intra-stage mesh and assert the pipelined logits are
     **bit-identical** to it (the tier's core contract);
  3. record the static bubble fraction (p-1)/(m+p-1) next to the
     **measured** one — ``bubble_fraction_weighted`` over the realized
     per-stage compute elems of the lowered stage schedules: the
     fill/drain bubble of the GPipe makespan ``sum(c) + (m-1)*max(c)``
     under the stage weights the executor actually runs (deterministic —
     forced-host CPU wall-clock would measure dispatch overhead, not
     pipeline idle time);
  4. assert, per stage, traced intra-stage wire (one microbatch) stays
     within ``pipeline.plan.stage_priced_cost`` — the per-stage analogue
     of bench_spmd's whole-program ``traced <= plan_cost``.

Rows print as ``PIPEROW <arch> ...`` and the run writes
``BENCH_pipeline.json`` (``{name, metric, value, unit}`` rows) at the
repo root, picked up by CI's ``BENCH_*.json`` artifact glob.

With ``--check`` the run asserts bit-identity, the per-stage bound, and
measured bubble <= 1.5x static for every p > 1 cell.

Usage:
  PYTHONPATH=src python benchmarks/bench_pipeline.py [--check]
      [--bench-out BENCH_pipeline.json]
"""
import argparse
from pathlib import Path

from repro.launch.hostdev import force_host_devices

force_host_devices(8)

import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core.cost import bubble_fraction
from repro.launch.mesh import make_mesh
from repro.models.eingraphs import program_for
from repro.pipeline import PipelineSpec, batch_splittable

FAMILIES = ["llama-7b", "mixtral-8x7b", "xlstm-125m", "hymba-1.5b"]
GRID = [(1, 1), (1, 4), (2, 1), (2, 4)]
REPO_ROOT = Path(__file__).resolve().parent.parent


def _feeds(g, vocab, rng):
    out = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if str(np.dtype(n.dtype)) == "int32":
            out[n.name] = rng.integers(0, vocab, size=n.shape).astype(np.int32)
        else:
            out[n.name] = (rng.normal(size=n.shape) * 0.05).astype(np.float32)
    return out


def bench_cell(arch: str, p: int, m: int, check: bool, cache) -> dict:
    from repro.models.opaque_stubs import capacity_of, make_stub_opaques

    rng = np.random.default_rng(0)
    cfg = reduced(get_config(arch))
    prog = program_for(cfg, ShapeConfig("bench", "prefill", 32, 4))
    g = prog.graph
    make_stub_opaques(capacity_of(g))

    clamped = m > 1 and not batch_splittable(g, "b")
    m_eff = 1 if clamped else m
    intra = {"data": 2, "model": 2} if p == 2 else {"data": 2, "model": 4}
    mesh = make_mesh((p,) + tuple(intra.values()), ("pp",) + tuple(intra))
    spec = PipelineSpec(stages=p, microbatches=m_eff)

    run = prog.compile(mesh=mesh, executor="shard_map", pipeline=spec,
                       cache=cache)
    psc = run.pipeline_schedule
    base_mesh = make_mesh(tuple(intra.values()), tuple(intra))
    base = prog.compile(mesh=base_mesh, executor="shard_map",
                        plan=psc.stitched)

    feeds = _feeds(g, cfg.vocab, rng)
    out = np.asarray(run(feeds)["logits"])
    ref = np.asarray(base(feeds)["logits"])
    bitwise = bool(np.array_equal(out, ref))

    itemsize = 4  # zoo activations are f32
    cut_bytes = sum(psc.cut_elems) * itemsize
    stage_rows = []
    for s in range(p):
        traced = psc.stage_trace_elems(s)
        priced = psc.stage_priced(s)
        stage_rows.append({"stage": s, "traced": traced, "priced": priced,
                           "ok": traced <= priced})

    row = {
        "arch": arch, "p": p, "m": m_eff, "clamped": clamped,
        "bubble_static": psc.bubble,
        "bubble_measured": psc.bubble_weighted,
        "cut_bytes": cut_bytes,
        "handoff_elems": psc.handoff_elems,
        "cache_hits": psc.cache_stats.get("hits", 0),
        "stages": stage_rows,
        "bitwise": bitwise,
    }
    tag = " (m clamped: MoE)" if clamped else ""
    print(f"PIPEROW {arch:14s} p={p} m={m_eff} "
          f"bubble={psc.bubble:.3f}/{psc.bubble_weighted:.3f} "
          f"cut={cut_bytes:>10,}B handoff={psc.handoff_elems:>10,} "
          f"bitwise={'==' if bitwise else '!='}{tag}", flush=True)
    for sr in stage_rows:
        print(f"        stage {sr['stage']}: traced={sr['traced']:>12,} "
              f"priced={sr['priced']:>12,} "
              f"{'OK' if sr['ok'] else 'OVER'}", flush=True)

    if check:
        assert bitwise, (
            f"{arch} p={p} m={m_eff}: pipelined logits diverge from the "
            "unpipelined stitched-plan baseline")
        for sr in stage_rows:
            assert sr["ok"], (
                f"{arch} p={p} m={m_eff} stage {sr['stage']}: traced "
                f"{sr['traced']:,} elems exceed the per-stage price "
                f"{sr['priced']:,}")
        assert psc.bubble == bubble_fraction(p, m_eff)
        if p > 1:
            assert psc.bubble_weighted <= 1.5 * psc.bubble, (
                f"{arch} p={p} m={m_eff}: measured bubble "
                f"{psc.bubble_weighted:.3f} is more than 1.5x the static "
                f"{psc.bubble:.3f} — stage cut badly imbalanced")
        if p == 1:
            assert psc.handoff_elems == 0
    return row


def main():
    from repro.core.plancache import PlanCache

    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--bench-out", default=str(REPO_ROOT / "BENCH_pipeline.json"))
    args = ap.parse_args()

    rows = []
    for arch in FAMILIES:
        cache = PlanCache(capacity=64)  # stage dedup within a family
        seen = set()
        for p, m in GRID:
            row = bench_cell(arch, p, m, args.check, cache)
            if (p, row["m"]) in seen:  # MoE clamp can fold m=4 onto m=1
                continue
            seen.add((p, row["m"]))
            rows.append(row)

    bench_rows = []
    for r in rows:
        name = f"pipeline/{r['arch']}/p{r['p']}m{r['m']}"
        worst = max((sr["traced"] / max(sr["priced"], 1)
                     for sr in r["stages"]), default=0.0)
        bench_rows.append({"name": name, "metric": "bubble_static",
                           "value": round(r["bubble_static"], 4),
                           "unit": "frac"})
        bench_rows.append({"name": name, "metric": "bubble_measured",
                           "value": round(r["bubble_measured"], 4),
                           "unit": "frac"})
        bench_rows.append({"name": name, "metric": "cut_bytes",
                           "value": r["cut_bytes"], "unit": "bytes"})
        bench_rows.append({"name": name, "metric": "handoff_elems",
                           "value": r["handoff_elems"], "unit": "elems"})
        bench_rows.append({"name": name,
                           "metric": "stage_traced_over_priced_max",
                           "value": round(worst, 4), "unit": "ratio"})
        bench_rows.append({"name": name, "metric": "bitwise_vs_unpipelined",
                           "value": int(r["bitwise"]), "unit": "bool"})

    from _bench_io import write_bench_json

    write_bench_json(bench_rows, Path(args.bench_out))
    if args.check:
        print("bench_pipeline: all checks passed", flush=True)


if __name__ == "__main__":
    main()
