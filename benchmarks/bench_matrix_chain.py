"""Paper Experiment 1 (Figs 7-8): matrix-chain (A·B) + (C·(D·E)).

Two regimes exactly as §9.2:
  * uniform — all matrices s x s
  * skewed  — A: s x .1s, B: .1s x s, C: s x .1s, D: .1s x 10s, E: 10s x s

Compared decompositions (all executed through the same machinery, like the
paper runs all baselines on Einsummable):
  * EinDecomp (this paper, + our consumer-aware linearization)
  * SQRT (slice first two dims sqrt(p) ways) — the paper's baseline
plus wall-clock on host devices via the sharded engine.

Outputs CSV rows: name,us_per_call,derived.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.decomp import eindecomp, plan_cost, plan_sqrt
from repro.core.einsum import EinGraph


def chain_graph(s: int, skewed: bool) -> EinGraph:
    g = EinGraph("chain")
    t = max(int(0.1 * s), 2)
    u = 10 * s if skewed else s
    if skewed:
        A = g.input("A", "ij", (s, t))
        B = g.input("B", "jk", (t, s))
        C = g.input("C", "il", (s, t))
        D = g.input("D", "lm", (t, u))
        E = g.input("E", "mk", (u, s))
    else:
        A = g.input("A", "ij", (s, s))
        B = g.input("B", "jk", (s, s))
        C = g.input("C", "il", (s, s))
        D = g.input("D", "lm", (s, s))
        E = g.input("E", "mk", (s, s))
    AB = g.einsum("ij,jk->ik", A, B, name="AB")
    DE = g.einsum("lm,mk->lk", D, E, name="DE")
    CDE = g.einsum("il,lk->ik", C, DE, name="CDE")
    g.einsum("ik,ik->ik", AB, CDE, combine="add", agg="", name="sum")
    return g


def run(p: int = 16, sizes=(256, 1024, 4096, 16384)) -> list[tuple]:
    rows = []
    for skewed in (False, True):
        regime = "skewed" if skewed else "uniform"
        for s in sizes:
            g = chain_graph(s, skewed)
            t0 = time.time()
            ein = eindecomp(g, p, offpath_repart=True)
            t_plan = (time.time() - t0) * 1e6
            sq = plan_sqrt(g, p)
            ratio = sq.cost / max(ein.cost, 1)
            rows.append((f"exp1_{regime}_s{s}_eindecomp_cost", ein.cost, ""))
            rows.append((f"exp1_{regime}_s{s}_sqrt_cost", sq.cost,
                         f"sqrt/eindecomp={ratio:.2f}x"))
            rows.append((f"exp1_{regime}_s{s}_plan_time", t_plan, "us"))
    return rows


def run_wallclock(p: int = 8, s: int = 512) -> list[tuple]:
    """Execute both plans through the TRA reference runtime and time them
    (CPU; the paper's CPU cluster analogue at container scale)."""
    from repro.core.tra import execute_graph_tra

    rng = np.random.default_rng(0)
    rows = []
    for skewed in (False, True):
        regime = "skewed" if skewed else "uniform"
        g = chain_graph(s, skewed)
        feeds = {n.nid: rng.normal(size=n.shape).astype(np.float32)
                 for n in g.nodes if n.kind == "input"}
        for name, plan in (("eindecomp", eindecomp(g, p, offpath_repart=True)),
                           ("sqrt", plan_sqrt(g, p))):
            t0 = time.time()
            vals, stats = execute_graph_tra(g, plan.d_by_node, feeds)
            dt = (time.time() - t0) * 1e6
            rows.append((f"exp1_wall_{regime}_{name}", dt,
                         f"kernel_calls={stats['kernel_calls']}"))
    return rows
