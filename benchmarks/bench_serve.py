"""Serving-tier benchmark: continuous batching vs sequential per-request
serve(), on a forced 8-device host mesh, with the paged-KV pricing check.

For each zoo family (dense attention, recurrent xLSTM, windowed hybrid):

  1. build a mixed-length workload and serve it twice from cold:
     sequentially (one ``launch.serve.serve`` call per request — each call
     re-jits its steps, the pre-serving-tier reality) and through
     ``repro.serving.ServingEngine`` (continuous batching over the paged
     KV pool, prefill through the shape-bucket registry);
  2. assert the engine's generations are **bit-for-bit identical** per
     request to the sequential baseline (the baseline is called with
     ``kv_len`` equal to the engine's gather extent so both attend over
     the same masked span — masked lanes are exact fp zeros, and the
     paged pool is time-ordered like the dense cache);
  3. with ``--check``, compile the paged decode graph with the shard_map
     executor and assert, per ``kv_block_gather`` node, that the rule is
     ``paged`` and the traced wire elems stay within
     ``decomp.opaque_node_bound`` — the planner's price is an upper bound
     on what the executor actually moves (bench_spmd's contract, extended
     to the serving tier's op).

Rows print as ``SERVEROW ...`` and land in ``BENCH_serve.json``
(``{name, metric, value, unit}``) at the repo root.

MoE archs are excluded from the bitwise assert (expert capacity couples
batch rows, so batched decode is not bitwise-equal to batch-1 decode by
construction); the three asserted families cover dense, recurrent and
windowed-hybrid cache handling.

Usage:
  PYTHONPATH=src python benchmarks/bench_serve.py [--check]
      [--requests 10] [--max-new 8] [--bench-out BENCH_serve.json]
"""
import argparse
import time
import warnings
from pathlib import Path

from repro.launch.hostdev import force_host_devices

force_host_devices(8)

warnings.filterwarnings("ignore", message=".*[Dd]onat")

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core.decomp import opaque_node_bound
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import serve
from repro.models import transformer as tf
from repro.models.eingraphs import program_for
from repro.serving import ServingEngine

FAMILIES = ["llama-7b", "xlstm-125m", "hymba-1.5b"]
REPO_ROOT = Path(__file__).resolve().parent.parent

BATCH = 4
BLOCK = 8
MAX_SEQ = 40          # per-request prompt+generated capacity ceiling


def _workload(cfg, n: int, rng) -> list[np.ndarray]:
    """Mixed prompt lengths, several repeating (bucket reuse) and several
    unique (bucket growth)."""
    lengths = [5, 13, 16, 9, 21, 5, 32, 13, 7, 16, 27, 9]
    return [rng.integers(0, cfg.vocab, size=(L,)).astype(np.int32)
            for L in lengths[:n]]


def _check_paged_pricing(cfg, arch: str, check: bool) -> list[dict]:
    """shard_map-compile the paged decode cell; per kv_block_gather node
    assert rule == 'paged' and traced <= priced."""
    from repro.models.opaque_stubs import capacity_of, make_stub_opaques

    rng = np.random.default_rng(0)
    W = MAX_SEQ // BLOCK
    shape = ShapeConfig("bench", "decode", W * BLOCK, BATCH)
    prog = program_for(cfg, shape, kv_block=BLOCK)
    g = prog.graph
    make_stub_opaques(capacity_of(g))
    mesh = make_host_mesh((2, 4))
    run_s = prog.compile(mesh=mesh, executor="shard_map")

    n_blocks = BATCH * W + 1
    feeds = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if n.name == "block_tables":
            feeds[n.name] = rng.integers(0, n_blocks,
                                         size=n.shape).astype(np.int32)
        elif str(np.dtype(n.dtype)) == "int32":
            feeds[n.name] = rng.integers(0, cfg.vocab,
                                         size=n.shape).astype(np.int32)
        else:
            feeds[n.name] = (rng.normal(size=n.shape) * 0.05).astype(
                np.float32)
    outs = run_s(feeds)
    jax.block_until_ready(list(outs.values()))

    traced = run_s.collectives
    rows = []
    for n in g.nodes:
        if n.kind != "opaque" or n.op != "kv_block_gather":
            continue
        row = {"nid": n.nid, "name": n.name,
               "rule": traced.rule_by_node.get(n.nid, "?"),
               "traced_elems": traced.elems_by_node.get(n.nid, 0),
               "bound_elems": opaque_node_bound(g, run_s.plan, n.nid)}
        rows.append(row)
        ok = ("OK" if row["rule"] == "paged"
              and row["traced_elems"] <= row["bound_elems"] else "BAD")
        print(f"        paged  {row['name']:12s} rule={row['rule']:9s} "
              f"traced={row['traced_elems']:>10,} "
              f"bound={row['bound_elems']:>10,} {ok}", flush=True)
        if check:
            assert row["rule"] == "paged", (
                f"{arch}/{row['name']}: kv_block_gather lowered through "
                f"{row['rule']!r}, not the paged rule")
            assert row["traced_elems"] <= row["bound_elems"], (
                f"{arch}/{row['name']}: paged rule moved "
                f"{row['traced_elems']:,} wire elems, over the priced "
                f"bound {row['bound_elems']:,}")
    has_attn = any(b in ("attn", "hymba") for b in cfg.block_pattern)
    if check and has_attn:
        assert rows, f"{arch}: no kv_block_gather nodes in the paged cell"
    return rows


def bench_family(arch: str, n_requests: int, max_new: int,
                 check: bool) -> dict:
    rng = np.random.default_rng(0)
    cfg = reduced(get_config(arch))
    prompts = _workload(cfg, n_requests, rng)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_host_mesh()

    # continuous batching from cold (jit + planning included: serving is
    # a from-process-start workload, and the registry's reuse across
    # requests is exactly what is being measured)
    t0 = time.perf_counter()
    eng = ServingEngine(cfg, batch=BATCH, max_seq=MAX_SEQ, block=BLOCK,
                        params=params, mesh=mesh)
    rids = [eng.submit(p, max_new) for p in prompts]
    results, metrics = eng.run()
    t_engine = time.perf_counter() - t0
    n_tok = sum(len(results[r]) for r in rids)

    # sequential per-request baseline, same params, same process; kv_len
    # pinned to the engine's gather extent for the bitwise comparison
    t0 = time.perf_counter()
    base = {}
    for rid, p in zip(rids, prompts):
        gen, _ = serve(cfg, p[None, :], max_new=max_new, params=params,
                       kv_len=eng.seq, mesh=mesh)
        base[rid] = gen[0]
    t_seq = time.perf_counter() - t0

    mismatched = [r for r in rids
                  if not np.array_equal(results[r], base[r])]
    if check:
        assert not mismatched, (
            f"{arch}: engine generations diverge from sequential serve() "
            f"for requests {mismatched}")
        assert t_engine < t_seq, (
            f"{arch}: continuous batching ({t_engine:.2f}s) did not beat "
            f"sequential serve() ({t_seq:.2f}s) on the mixed workload")

    row = {
        "arch": arch,
        "requests": len(rids),
        "tokens": n_tok,
        "t_engine_s": t_engine,
        "t_sequential_s": t_seq,
        "speedup": t_seq / max(t_engine, 1e-9),
        "engine_tok_per_s": n_tok / max(t_engine, 1e-9),
        "sequential_tok_per_s": n_tok / max(t_seq, 1e-9),
        "bitwise": not mismatched,
        "mean_occupancy": metrics.mean_occupancy,
        "mean_ttft_s": (float(np.mean(list(metrics.ttft_s.values())))
                        if metrics.ttft_s else 0.0),
        "decode_steps": metrics.decode_steps,
        "registry_compiles": eng.registry.stats.compiles,
        "registry_lookups": eng.registry.stats.lookups,
        "plan_time_s": eng.registry.stats.plan_time_s,
    }
    print(f"SERVEROW {arch:14s} reqs={row['requests']:<3d} "
          f"engine={t_engine:7.2f}s seq={t_seq:7.2f}s "
          f"speedup={row['speedup']:5.2f}x "
          f"tok/s={row['engine_tok_per_s']:7.2f} "
          f"occ={row['mean_occupancy']:.2f} "
          f"bitwise={'YES' if row['bitwise'] else 'NO'}", flush=True)
    row["paged_nodes"] = _check_paged_pricing(cfg, arch, check)
    return row


def _bench_rows(rows: list[dict]) -> list[dict]:
    out = []
    for r in rows:
        a = r["arch"]
        out += [
            {"name": f"serve/{a}/engine", "metric": "tok_per_s",
             "value": round(r["engine_tok_per_s"], 3), "unit": "tok/s"},
            {"name": f"serve/{a}/sequential", "metric": "tok_per_s",
             "value": round(r["sequential_tok_per_s"], 3), "unit": "tok/s"},
            {"name": f"serve/{a}/speedup", "metric": "throughput_ratio",
             "value": round(r["speedup"], 3), "unit": "ratio"},
            {"name": f"serve/{a}/ttft", "metric": "mean_ttft",
             "value": round(r["mean_ttft_s"] * 1e3, 1), "unit": "ms"},
            {"name": f"serve/{a}/occupancy", "metric": "mean_occupancy",
             "value": round(r["mean_occupancy"], 3), "unit": "ratio"},
            {"name": f"serve/{a}/bitwise", "metric": "generations_match",
             "value": int(r["bitwise"]), "unit": "bool"},
        ]
        for o in r["paged_nodes"]:
            out.append({"name": f"serve/{a}/paged/{o['name']}",
                        "metric": "wire_elems",
                        "value": o["traced_elems"], "unit": "elems"})
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--arch", default=None, help="one family (default: all)")
    ap.add_argument("--check", action="store_true",
                    help="assert bitwise generations, engine < sequential "
                    "wall-clock, and traced <= priced per paged node")
    ap.add_argument("--bench-out",
                    default=str(REPO_ROOT / "BENCH_serve.json"),
                    help="perf-trajectory JSON (default: repo root)")
    args = ap.parse_args()

    print(f"devices: {len(jax.devices())}")
    fams = [args.arch] if args.arch else FAMILIES
    rows = [bench_family(a, args.requests, args.max_new, args.check)
            for a in fams]
    ok = sum(r["bitwise"] for r in rows)
    print(f"\n{ok}/{len(rows)} families bitwise vs sequential serve()")
    if args.bench_out:
        from _bench_io import write_bench_json

        write_bench_json(_bench_rows(rows), Path(args.bench_out))


if __name__ == "__main__":
    main()
