"""Shared writer for the cross-PR perf-trajectory artifacts.

Every benchmark that tracks numbers across PRs emits the same schema — a
flat list of ``{name, metric, value, unit}`` rows — to ``BENCH_<bench>.json``
at the repo root, which CI uploads as an artifact.  One writer, so the
artifacts cannot drift apart.
"""
from __future__ import annotations

import json
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def write_bench_json(rows: list[dict], path: Path) -> None:
    """Write ``{name, metric, value, unit}`` rows (pre-built by the bench)."""
    for r in rows:
        assert set(r) == {"name", "metric", "value", "unit"}, r
    path.write_text(json.dumps(rows, indent=1))
    print(f"wrote {path} ({len(rows)} rows)", flush=True)
