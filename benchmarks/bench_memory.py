"""Paper Experiment 4 analogue (Fig 11): memory-constrained LLM inference.

ZeRO-Inference / FlexGen are GPU-RAM-paging PyTorch systems and cannot run
here; the transferable question is *per-device memory of the decomposed
computation vs sequence length* — the artifact a paging engine like TURNIP
would consume.  A child process (fresh jax, 8 forced host devices) lowers a
reduced llama prefill under (a) the EinDecomp plan and (b) forced
data-parallel, and reports ``memory_analysis`` per device: the automatic
plan keeps the footprint far below DP as the context grows (the paper's
OOM-avoidance story, Fig 11's x-axis).
"""
from __future__ import annotations

import os
import subprocess
import sys


def run() -> list[tuple]:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks._memory_child"],
        capture_output=True, text=True, env=env, timeout=520)
    if proc.returncode != 0:
        raise RuntimeError(f"memory child failed:\n{proc.stderr[-2000:]}")
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("MEMROW "):
            _, name, mb = line.split()
            rows.append((name, float(mb), "MB/device"))
    return rows
