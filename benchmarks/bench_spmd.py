"""Explicit-collective executor benchmark: predicted vs traced movement,
and wall-clock vs the GSPMD path, on a forced 8-device host mesh.

For every model-zoo family (plus a plain MLP):

  1. plan the cell once, compile it with both executors;
  2. compare the §7 ``plan_cost`` the DP optimized against the wire floats
     the shard_map executor's static collective schedule will actually move
     (ring-priced).  The plan cost is an upper bound — ``traced <=
     predicted`` is the property that makes the DP's prices trustworthy
     (Deinsum's argument: emit the schedule you costed).  With ``--check``
     the bound is additionally asserted **per ring/a2a/local-ruled opaque
     node** against ``decomp.opaque_node_bound`` — ring attention and a2a
     expert parallelism never fall back to gathering full K/V or token
     buffers, and the channel-parallel recurrent scans (ssm/mlstm/slstm,
     the ``local`` rule) move **zero** wire elements on channel-only
     sharding, where the old replicate fallback gathered full state (the
     scan-family rows land in BENCH_spmd.json alongside ring/a2a);
  3. time both executors end-to-end (jit warm, best of N).

Rows print as ``SPMDROW <arch> ...`` so CI logs diff commit over commit,
and the run writes ``BENCH_spmd.json`` (``{name, metric, value, unit}``
rows) at the repo root so perf is tracked across PRs.

``--emit-costs out.json`` additionally micro-benchmarks each collective
kind on the live mesh and writes measured ns-per-element constants —
``core.cost.CostModel.with_measured(out.json)`` then prices the DP with
observed numbers instead of the ring formulas.  ``--calibrate`` (the CI
default invocation) runs that micro-benchmark first, builds the calibrated
``with_measured`` model, and prices every cell's *traced schedule* in
measured time (``sum over kinds of wire elems x ns/elem``), recorded next
to the measured wall-clock as ``spmd/<arch>/calibrated_comm``.  Planning
itself stays on the deterministic paper-mode DP: the ``--check`` contract
is a §7 statement about that plan, and on forced-host CPU "devices" the
measured constants reflect dispatch overhead rather than interconnect
bandwidth, so re-ranking plans with them rewards wire-wasteful gathers
(planning with a calibrated model is exercised by
``Program.compile(cost_model=CostModel.with_measured(...))`` in
tests/test_opaque_rules.py).  The per-family predicted/traced **ratio
trajectory** recorded into BENCH_spmd.json is likewise deterministic: it
is recomputed from the paper-mode plan and the static schedule
(``repro.launch.trajectory``), the numbers
``tests/test_spmd_fastpath.py`` pins.

The shard_map runner is compiled with ``donate=True`` (every input buffer
donated via ``jax.jit(donate_argnums=...)``), the fused repartition
planner on, and the default graph-wide ``lookahead=1`` overlap window —
``--check`` additionally asserts the fused schedule moves no more wire
elems than the unfused PR-3 lowering, that every family's executed
schedule overlaps some wire (``overlap_frac > 0``), and that the
lookahead schedule's logits are bit-identical to a ``lookahead=0`` serial
twin compiled from the same cached plan.  The per-family ``overlap_frac``
and exposed-wire rows land in BENCH_spmd.json next to the ratio
trajectory (both deterministic: static schedule, no devices).

Usage:
  PYTHONPATH=src python benchmarks/bench_spmd.py [--check] [--reps 5]
      [--calibrate] [--emit-costs out.json] [--bench-out BENCH_spmd.json]
"""
import argparse
import json
import time
import warnings
from pathlib import Path

from repro.launch.hostdev import force_host_devices

# 8 host devices so collectives are real (append-only, pre-jax-init)
force_host_devices(8)

# the shard_map runner donates its input buffers; the CPU backend accepts
# but ignores donation, warning once per compile — noise in CI logs
warnings.filterwarnings("ignore", message=".*[Dd]onat")

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core.decomp import opaque_node_bound, plan_cost
from repro.launch.mesh import make_host_mesh
from repro.models.eingraphs import program_for

FAMILIES = ["llama-7b", "mixtral-8x7b", "xlstm-125m", "hymba-1.5b"]
REPO_ROOT = Path(__file__).resolve().parent.parent


def _feeds(g, vocab, rng):
    out = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if str(np.dtype(n.dtype)) == "int32":
            out[n.name] = rng.integers(0, vocab, size=n.shape).astype(np.int32)
        else:
            out[n.name] = (rng.normal(size=n.shape) * 0.05).astype(np.float32)
    return out


def _time(run, feeds, reps):
    """(best wall-clock over reps, last outputs) — warm jit first."""
    outs = run(feeds)  # warm/compile
    jax.block_until_ready(list(outs.values()))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        outs = run(feeds)
        jax.block_until_ready(list(outs.values()))
        best = min(best, time.perf_counter() - t0)
    return best, outs


def bench_cell(arch: str, reps: int, check: bool,
               kinds: dict | None = None) -> dict:
    from repro.core import spmd
    from repro.core.engine import mesh_axes_dict
    from repro.core.plancache import PlanCache
    from repro.models.opaque_stubs import capacity_of, make_stub_opaques

    rng = np.random.default_rng(0)
    cfg = reduced(get_config(arch))
    shape = ShapeConfig("bench", "prefill", 32, 4)
    prog = program_for(cfg, shape)
    g = prog.graph
    # registers through the unified OpDef path (opdef.provide_impl)
    make_stub_opaques(capacity_of(g))
    mesh = make_host_mesh((2, 4))

    # one §8 DP per cell: the second compile is a plan-cache hit, and the
    # traced-vs-predicted comparison provably prices the *same* plan.
    # the shard_map runner donates every input buffer (numpy feeds are
    # copied to device, so repeated timed calls stay safe).
    # planning is always the deterministic paper-mode §7 DP — that is the
    # plan whose cost the within_bound contract pins.  (Feeding the
    # measured collective constants to the DP instead is possible via
    # Program.compile(cost_model=CostModel.with_measured(...)), but on
    # forced-host CPU "devices" the constants reflect dispatch overhead,
    # not interconnect bandwidth, and re-rank plans toward wire-wasteful
    # gathers; the calibrated model's CI role is pricing the *time* of the
    # traced schedule below.)
    cache = PlanCache(capacity=4)
    run_g = prog.compile(mesh=mesh, cache=cache)
    run_s = prog.compile(mesh=mesh, cache=cache,
                         executor="shard_map", donate=True)
    # serial twin: identical plan, lookahead=0 — the graph-wide overlap
    # pass must be a pure issue-order rewrite (bit-identical logits)
    run_s0 = prog.compile(mesh=mesh, cache=cache, executor="shard_map",
                          lookahead=0)
    assert run_s.plan.d_by_node == run_g.plan.d_by_node
    predicted = plan_cost(g, run_s.plan)
    traced = run_s.collectives
    out_ids = [prog._out[k] for k in prog._out]
    unfused = spmd.build_schedule(g, run_s.plan, mesh_axes_dict(mesh),
                                  out_ids, fuse=False).trace.total_elems

    feeds = _feeds(g, cfg.vocab, rng)
    t_g, outs_g = _time(run_g, feeds, reps)
    t_s, outs_s = _time(run_s, feeds, reps)
    max_diff = float(np.abs(np.asarray(outs_g["logits"])
                            - np.asarray(outs_s["logits"])).max())
    logits_s0 = np.asarray(run_s0(feeds)["logits"])
    bitwise_vs_serial = bool(
        np.array_equal(np.asarray(outs_s["logits"]), logits_s0))

    # calibrated time price of the traced schedule: sum over collective
    # kinds of (traced wire elems) x (measured ns per wire elem) — how much
    # of the wall-clock the calibrated CostModel accounts for
    cal_pred_ms = None
    if kinds:
        cal_pred_ms = sum(
            traced.elems_by_kind.get(k, 0) * v["ns_per_elem"]
            for k, v in kinds.items()) / 1e6

    # per-node accounting for the ruled opaques (ring / a2a)
    opaques = []
    for n in g.nodes:
        if n.kind != "opaque":
            continue
        rule = traced.rule_by_node.get(n.nid, "?")
        opaques.append({
            "nid": n.nid, "name": n.name, "rule": rule,
            "traced_elems": traced.elems_by_node.get(n.nid, 0),
            "bound_elems": opaque_node_bound(g, run_s.plan, n.nid),
        })

    row = {
        "arch": arch,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "predicted_elems": int(predicted),
        "traced_elems": traced.total_elems,
        "traced_bytes": traced.total_bytes,
        "unfused_elems": int(unfused),
        "fused_event_elems": traced.fused_elems,
        "overlapped_elems": traced.overlapped_elems,
        "prefetched_elems": traced.prefetched_elems,
        "overlap_frac": round(traced.overlapped_elems
                              / max(traced.total_elems, 1), 4),
        "bitwise_vs_serial": bitwise_vs_serial,
        "donated_args": len(run_s.donate_argnums),
        "collectives": dict(traced.counts),
        "by_rule": traced.by_rule(),
        "opaques": opaques,
        "t_gspmd_ms": t_g * 1e3,
        "t_shard_map_ms": t_s * 1e3,
        "t_calibrated_pred_ms": cal_pred_ms,
        "max_abs_diff": max_diff,
        "within_bound": traced.total_elems <= predicted,
    }
    print(f"SPMDROW {arch:14s} mesh={row['mesh']:5s} "
          f"predicted={predicted:>12,} traced={traced.total_elems:>12,} "
          f"({'OK' if row['within_bound'] else 'OVER'}) "
          f"unfused={unfused:>12,} "
          f"overlap={row['overlap_frac']:.4f} "
          f"serial={'==' if bitwise_vs_serial else '!='} "
          f"gspmd={row['t_gspmd_ms']:8.2f}ms "
          f"shard_map={row['t_shard_map_ms']:8.2f}ms "
          f"diff={max_diff:.2e}", flush=True)
    if cal_pred_ms is not None:
        print(f"        calibrated comm price {cal_pred_ms:8.3f} ms "
              f"({100 * cal_pred_ms / row['t_shard_map_ms']:5.1f}% of "
              "shard_map wall-clock)", flush=True)
    for kind, cnt in sorted(traced.counts.items()):
        print(f"        {kind:14s} x{cnt:<3d} "
              f"{traced.bytes_by_kind[kind]:,} B", flush=True)
    for o in opaques:
        ok = "OK" if o["traced_elems"] <= o["bound_elems"] else "OVER"
        print(f"        opaque {o['name']:12s} rule={o['rule']:9s} "
              f"traced={o['traced_elems']:>10,} "
              f"bound={o['bound_elems']:>10,} {ok}", flush=True)
    if check:
        assert row["within_bound"], (
            f"{arch}: traced {traced.total_elems:,} elems exceed the §7 "
            f"plan_cost bound {predicted:,}")
        assert traced.total_elems <= unfused, (
            f"{arch}: fused schedule moves {traced.total_elems:,} elems, "
            f"more than the unfused lowering's {unfused:,} — "
            "plan_repart_best must pick the min")
        assert max_diff < 2e-3, f"{arch}: executors diverge ({max_diff})"
        assert bitwise_vs_serial, (
            f"{arch}: lookahead schedule is not bit-identical to its "
            "lookahead=0 serial twin — the hoist pass changed more than "
            "the issue order")
        assert row["overlap_frac"] > 0, (
            f"{arch}: no overlapped wire in the executed schedule — the "
            "graph-wide lookahead pass hoisted nothing")
        assert run_s0.collectives.total_elems == traced.total_elems, (
            f"{arch}: lookahead changed traced wire volume "
            f"({traced.total_elems:,} vs serial "
            f"{run_s0.collectives.total_elems:,})")
        for o in opaques:
            if o["rule"] in ("ring", "a2a", "local"):
                assert o["traced_elems"] <= o["bound_elems"], (
                    f"{arch}/{o['name']}: {o['rule']} rule moved "
                    f"{o['traced_elems']:,} elems, over its "
                    f"_opaque_comm_cost bound {o['bound_elems']:,} — the "
                    "realized schedule diverged from the priced one")
            if o["rule"] == "local" and o["name"].endswith("_scan"):
                # the scan-family property: a local scan per channel shard
                # moves nothing, where replication gathered full state
                assert o["traced_elems"] == 0, (
                    f"{arch}/{o['name']}: channel-parallel scan moved "
                    f"{o['traced_elems']:,} wire elems (expected 0 on "
                    "channel-only sharding)")
    return row


# ---------------------------------------------------------------------------
# collective-kind calibration (--emit-costs): measured ns per wire element
# ---------------------------------------------------------------------------


def calibrate_kinds(mesh, n_loc: int = 1 << 15, reps: int = 20) -> dict:
    """Time one collective of each kind on the live mesh and convert to
    ns-per-(ring-priced)-wire-element — the constants
    ``CostModel.with_measured`` scales the DP's collective prices with."""
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.core.spmd import _shard_map

    axes = tuple(mesh.axis_names)
    n_dev = int(mesh.devices.size)
    x = np.ones((n_dev * n_loc,), np.float32)

    def run(body):
        fn = jax.jit(_shard_map(body, mesh, (P(axes),), P(axes)))
        out = fn(x)
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            best = min(best, time.perf_counter() - t0)
        return best

    k = n_dev
    bodies = {
        "all_gather": (lambda b: lax.all_gather(b, axes, axis=0,
                                                tiled=True)[:n_loc],
                       n_dev * (k - 1) * n_loc),
        "all_to_all": (lambda b: lax.all_to_all(
            b.reshape(k, n_loc // k), axes, split_axis=0, concat_axis=0,
            tiled=True).reshape(n_loc), n_dev * (k - 1) * n_loc // k),
        "ppermute": (lambda b: lax.ppermute(
            b, axes, [(i, (i + 1) % k) for i in range(k)]), n_dev * n_loc),
        "psum": (lambda b: lax.psum(b, axes),
                 n_dev * 2 * (k - 1) * n_loc // k),
        "psum_scatter": (lambda b: lax.psum_scatter(
            b, axes, scatter_dimension=0, tiled=True),
            n_dev * (k - 1) * n_loc),
    }
    kinds = {}
    for kind, (body, wire) in bodies.items():
        t = run(body)
        kinds[kind] = {"wall_s": t, "wire_elems": wire,
                       "ns_per_elem": t * 1e9 / max(wire, 1)}
        print(f"CALROW  {kind:14s} {t * 1e3:8.3f} ms  "
              f"{kinds[kind]['ns_per_elem']:8.3f} ns/elem", flush=True)
    return kinds


def _bench_rows(rows: list[dict]) -> list[dict]:
    """{name, metric, value, unit} rows — the cross-PR perf trajectory."""
    out = []
    for r in rows:
        a = r["arch"]
        out += [
            {"name": f"spmd/{a}/shard_map", "metric": "wall_clock",
             "value": round(r["t_shard_map_ms"], 3), "unit": "ms"},
            {"name": f"spmd/{a}/gspmd", "metric": "wall_clock",
             "value": round(r["t_gspmd_ms"], 3), "unit": "ms"},
            {"name": f"spmd/{a}/traced", "metric": "wire_elems",
             "value": r["traced_elems"], "unit": "elems"},
            {"name": f"spmd/{a}/predicted", "metric": "wire_elems",
             "value": r["predicted_elems"], "unit": "elems"},
            {"name": f"spmd/{a}/unfused", "metric": "wire_elems",
             "value": r["unfused_elems"], "unit": "elems"},
        ]
        if r.get("t_calibrated_pred_ms") is not None:
            out.append({"name": f"spmd/{a}/calibrated_comm",
                        "metric": "wall_clock",
                        "value": round(r["t_calibrated_pred_ms"], 3),
                        "unit": "ms"})
        for o in r["opaques"]:
            if o["rule"] in ("ring", "a2a", "local"):
                out.append({"name": f"spmd/{a}/opaque/{o['name']}",
                            "metric": "wire_elems",
                            "value": o["traced_elems"], "unit": "elems"})
    return out


def _ratio_rows() -> list[dict]:
    """The deterministic predicted/traced ratio trajectory — paper-mode
    plan + static fused schedule, identical on every host, the numbers
    ``tests/test_spmd_fastpath.py`` pins against the committed JSON."""
    from repro.launch.trajectory import family_ratios

    out = []
    for r in family_ratios():
        print(f"RATIOROW {r['arch']:14s} predicted={r['predicted_elems']:>12,} "
              f"traced={r['traced_elems']:>12,} ratio={r['ratio']:.4f} "
              f"overlap_frac={r['overlap_frac']:.4f}", flush=True)
        out.append({"name": f"spmd/{r['arch']}/ratio",
                    "metric": "predicted_over_traced",
                    "value": r["ratio"], "unit": "ratio"})
        out.append({"name": f"spmd/{r['arch']}/overlap_frac",
                    "metric": "overlapped_over_traced",
                    "value": r["overlap_frac"], "unit": "ratio"})
        out.append({"name": f"spmd/{r['arch']}/exposed", "metric":
                    "wire_elems", "value": r["exposed_elems"],
                    "unit": "elems"})
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--arch", default=None, help="one family (default: all)")
    ap.add_argument("--check", action="store_true",
                    help="assert traced <= predicted (whole-program and "
                    "per ring/a2a opaque node) and output agreement")
    ap.add_argument("--emit-costs", default=None, metavar="OUT.JSON",
                    help="micro-benchmark each collective kind and write "
                    "measured ns/elem constants for "
                    "CostModel.with_measured")
    ap.add_argument("--calibrate", action="store_true",
                    help="micro-benchmark the collective kinds first, "
                    "build CostModel.with_measured from them, and price "
                    "each cell's traced schedule in measured time (the CI "
                    "default invocation); planning and the ratio "
                    "trajectory stay paper-mode/deterministic")
    ap.add_argument("--bench-out", default=str(REPO_ROOT / "BENCH_spmd.json"),
                    help="perf-trajectory JSON (default: repo root)")
    args = ap.parse_args()

    print(f"devices: {len(jax.devices())}")
    kinds = None
    if args.calibrate:
        from repro.core.cost import CostModel

        kinds = calibrate_kinds(make_host_mesh((2, 4)))
        cm = CostModel.with_measured({"kinds": kinds})
        print(f"calibrated cost model: {cm.describe()}", flush=True)
    fams = [args.arch] if args.arch else FAMILIES
    rows = [bench_cell(a, args.reps, args.check, kinds=kinds)
            for a in fams]
    ok = sum(r["within_bound"] for r in rows)
    print(f"\n{ok}/{len(rows)} cells within the plan-cost transfer bound")
    if args.bench_out:
        from _bench_io import write_bench_json

        write_bench_json(_bench_rows(rows) + _ratio_rows(),
                         Path(args.bench_out))
    if args.emit_costs:
        kinds = calibrate_kinds(make_host_mesh((2, 4)))
        payload = {"kinds": kinds,
                   "mesh": [int(s) for s in make_host_mesh((2, 4))
                            .devices.shape],
                   "rows": [{k: r[k] for k in
                             ("arch", "traced_elems", "traced_bytes",
                              "t_shard_map_ms")} for r in rows]}
        Path(args.emit_costs).write_text(json.dumps(payload, indent=1))
        print(f"wrote {args.emit_costs}", flush=True)


if __name__ == "__main__":
    main()
