"""Explicit-collective executor benchmark: predicted vs traced movement,
and wall-clock vs the GSPMD path, on a forced 8-device host mesh.

For every model-zoo family (plus a plain MLP):

  1. plan the cell once, compile it with both executors;
  2. compare the §7 ``plan_cost`` the DP optimized against the wire floats
     the shard_map executor's static collective schedule will actually move
     (ring-priced).  The plan cost is an upper bound — ``traced <=
     predicted`` is the property that makes the DP's prices trustworthy
     (Deinsum's argument: emit the schedule you costed);
  3. time both executors end-to-end (jit warm, best of N).

Rows print as ``SPMDROW <arch> ...`` so CI logs diff commit over commit.

Usage:
  PYTHONPATH=src python benchmarks/bench_spmd.py [--check] [--reps 5]
"""
import argparse
import time

from repro.launch.hostdev import force_host_devices

# 8 host devices so collectives are real (append-only, pre-jax-init)
force_host_devices(8)

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import engine
from repro.core.decomp import plan_cost
from repro.launch.mesh import make_host_mesh
from repro.models.eingraphs import program_for

FAMILIES = ["llama-7b", "mixtral-8x7b", "xlstm-125m", "hymba-1.5b"]


def _feeds(g, vocab, rng):
    out = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if str(np.dtype(n.dtype)) == "int32":
            out[n.name] = rng.integers(0, vocab, size=n.shape).astype(np.int32)
        else:
            out[n.name] = (rng.normal(size=n.shape) * 0.05).astype(np.float32)
    return out


def _time(run, feeds, reps):
    """(best wall-clock over reps, last outputs) — warm jit first."""
    outs = run(feeds)  # warm/compile
    jax.block_until_ready(list(outs.values()))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        outs = run(feeds)
        jax.block_until_ready(list(outs.values()))
        best = min(best, time.perf_counter() - t0)
    return best, outs


def bench_cell(arch: str, reps: int, check: bool) -> dict:
    from repro.core.plancache import PlanCache
    from repro.models.opaque_stubs import capacity_of, make_stub_opaques

    rng = np.random.default_rng(0)
    cfg = reduced(get_config(arch))
    shape = ShapeConfig("bench", "prefill", 32, 4)
    prog = program_for(cfg, shape)
    g = prog.graph
    for kind, fn in make_stub_opaques(capacity_of(g)).items():
        engine.register_opaque(kind, fn)
    mesh = make_host_mesh((2, 4))

    # one §8 DP per cell: the second compile is a plan-cache hit, and the
    # traced-vs-predicted comparison provably prices the *same* plan
    cache = PlanCache(capacity=4)
    run_g = prog.compile(mesh=mesh, cache=cache)
    run_s = prog.compile(mesh=mesh, cache=cache, executor="shard_map")
    assert run_s.plan.d_by_node == run_g.plan.d_by_node
    predicted = plan_cost(g, run_s.plan)
    traced = run_s.collectives

    feeds = _feeds(g, cfg.vocab, rng)
    t_g, outs_g = _time(run_g, feeds, reps)
    t_s, outs_s = _time(run_s, feeds, reps)
    max_diff = float(np.abs(np.asarray(outs_g["logits"])
                            - np.asarray(outs_s["logits"])).max())

    row = {
        "arch": arch,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "predicted_elems": int(predicted),
        "traced_elems": traced.total_elems,
        "traced_bytes": traced.total_bytes,
        "collectives": dict(traced.counts),
        "t_gspmd_ms": t_g * 1e3,
        "t_shard_map_ms": t_s * 1e3,
        "max_abs_diff": max_diff,
        "within_bound": traced.total_elems <= predicted,
    }
    print(f"SPMDROW {arch:14s} mesh={row['mesh']:5s} "
          f"predicted={predicted:>12,} traced={traced.total_elems:>12,} "
          f"({'OK' if row['within_bound'] else 'OVER'}) "
          f"gspmd={row['t_gspmd_ms']:8.2f}ms "
          f"shard_map={row['t_shard_map_ms']:8.2f}ms "
          f"diff={max_diff:.2e}", flush=True)
    for kind, cnt in sorted(traced.counts.items()):
        print(f"        {kind:14s} x{cnt:<3d} "
              f"{traced.bytes_by_kind[kind]:,} B", flush=True)
    if check:
        assert row["within_bound"], (
            f"{arch}: traced {traced.total_elems:,} elems exceed the §7 "
            f"plan_cost bound {predicted:,}")
        assert max_diff < 2e-3, f"{arch}: executors diverge ({max_diff})"
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--arch", default=None, help="one family (default: all)")
    ap.add_argument("--check", action="store_true",
                    help="assert traced <= predicted and output agreement")
    args = ap.parse_args()

    print(f"devices: {len(jax.devices())}")
    fams = [args.arch] if args.arch else FAMILIES
    rows = [bench_cell(a, args.reps, args.check) for a in fams]
    ok = sum(r["within_bound"] for r in rows)
    print(f"\n{ok}/{len(rows)} cells within the plan-cost transfer bound")


if __name__ == "__main__":
    main()
