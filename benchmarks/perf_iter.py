import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver.

Runs one (arch x shape) cell under a series of named policy/plan variants,
re-lowers, re-derives the three roofline terms, and prints a comparison —
the measurement half of the hypothesis -> change -> measure loop.  Each
variant writes a tagged JSON artifact so EXPERIMENTS.md can cite it.

  PYTHONPATH=src python -m benchmarks.perf_iter --arch llama-7b \
      --shape train_4k --variants baseline,collective,megatron_fsdp
"""
import argparse
import json

import jax


def variant_policy(name: str, cfg, shape, mesh):
    """Returns (policy or None, fsdp flag, description)."""
    from repro.launch.mesh import mesh_axes_dict
    from repro.models.eingraphs import plan_for
    from repro.models.policy import manual_policy, policy_from_plan

    axes = mesh_axes_dict(mesh)
    train = shape.kind == "train"
    fsdp_axes = tuple(a for a in ("pod", "data") if a in axes) if train else ()

    if name == "baseline":
        # paper-faithful EinDecomp plan (§7 objective) + fsdp storage
        return None, None, "EinDecomp (paper §7 cost) plan"
    if name == "paper_lin":
        g, plan, policy = plan_for(cfg, shape, axes, fsdp=train,
                                   offpath_repart=False)
        return policy, train, "paper-faithful §8.4 linearization"
    if name == "collective":
        from repro.core.decomp import eindecomp
        from repro.models.eingraphs import build_graph

        g = build_graph(cfg, shape)
        p = 1
        for v in axes.values():
            p *= v
        plan = eindecomp(g, p, mesh_axes=axes, offpath_repart=True,
                         cost_mode="collective")
        policy = policy_from_plan(plan, g, fsdp_axes=fsdp_axes)
        return policy, train, "EinDecomp with torus-collective cost mode"
    if name == "megatron_fsdp":
        pol = manual_policy(
            {"b": "data", "h": "model", "k": "model", "f": "model",
             "v": "model", "e": "model", "c": "data", "t": "model"},
            fsdp_axes=fsdp_axes)
        return pol, train, "manual Megatron TP x DP (+fsdp on train)"
    if name == "megatron_seq":
        pol = manual_policy(
            {"b": "data", "h": "model", "k": "model", "f": "model",
             "v": "model", "s": "model", "t": "model"},
            fsdp_axes=fsdp_axes)
        return pol, train, "Megatron TP + sequence parallelism"
    if name == "no_remat":
        from repro.models.eingraphs import plan_for as pf

        _, _, policy = pf(cfg, shape, axes, fsdp=train)
        policy.remat = False
        return policy, train, "EinDecomp plan, remat disabled"
    if name == "fsdp_both":
        from repro.models.eingraphs import plan_for as pf

        _, _, policy = pf(cfg, shape, axes, fsdp=train)
        policy.fsdp_axes = tuple(axes)  # ZeRO-3 over the whole mesh
        return policy, train, "EinDecomp plan, params+opt sharded over all axes"
    if name == "remat_dots":
        from repro.models.eingraphs import plan_for as pf

        _, _, policy = pf(cfg, shape, axes, fsdp=train)
        policy.remat = "dots"
        return policy, train, "EinDecomp plan, dots-saveable selective remat"
    if name == "fsdp_both_dots":
        from repro.models.eingraphs import plan_for as pf

        _, _, policy = pf(cfg, shape, axes, fsdp=train)
        policy.fsdp_axes = tuple(axes)
        policy.remat = "dots"
        return policy, train, "ZeRO-3 over mesh + dots-saveable remat"
    if name == "no_fsdp":
        from repro.models.eingraphs import plan_for as pf

        _, _, policy = pf(cfg, shape, axes, fsdp=False)
        return policy, False, "EinDecomp plan, params replicated over data"
    raise ValueError(name)


def run_variant(arch: str, shape_name: str, variant: str,
                out_dir: str = "artifacts/perf") -> dict:
    import dataclasses

    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    cfg_override = None
    if variant.startswith("moe_local"):
        cfg_override = dataclasses.replace(cfg, moe_groups=16)
        base = variant[len("moe_local"):].lstrip("_") or "baseline"
        variant_inner = base
    else:
        variant_inner = variant
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    policy, fsdp, desc = variant_policy(variant_inner,
                                        cfg_override or cfg, shape, mesh)
    if cfg_override is not None:
        desc = "group-local MoE dispatch (G=16) + " + desc
    rec = run_cell(arch, shape_name, fsdp=fsdp, policy_override=policy,
                   out_dir=out_dir, tag=variant, cfg_override=cfg_override)
    rec["variant"] = variant
    rec["description"] = desc
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    args = ap.parse_args()

    print(f"{'variant':16s} {'GB/dev':>8s} {'t_compute':>10s} {'t_memory':>10s}"
          f" {'t_coll':>10s} {'bound':>10s} {'frac':>5s}")
    for v in args.variants.split(","):
        try:
            rec = run_variant(args.arch, args.shape, v)
            r = rec["roofline"]
            print(f"{v:16s} {rec['memory']['per_device_gb']:8.2f} "
                  f"{r['t_compute_s']:10.3e} {r['t_memory_s']:10.3e} "
                  f"{r['t_collective_s']:10.3e} {rec['bottleneck']:>10s} "
                  f"{rec['roofline_fraction']:5.2f}", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{v:16s} FAILED: {type(e).__name__}: {e}", flush=True)
        finally:
            jax.clear_caches()


if __name__ == "__main__":
    main()
