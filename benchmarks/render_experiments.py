"""Inject the dry-run roofline tables into EXPERIMENTS.md placeholders."""
from __future__ import annotations

import glob
import json
import os


def table(mesh_filter: str, tag: str = "") -> str:
    lines = [
        "| arch | shape | GB/dev | fits 16GB | t_compute | t_memory(ub) |"
        " t_mem(lb) | t_coll | bound(ub) | bound(lb) | frac(ub) | frac(lb) |"
        " useful |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    recs = []
    for fn in sorted(glob.glob("artifacts/dryrun/*.json")):
        with open(fn) as f:
            r = json.load(f)
        if r.get("mesh") != mesh_filter or r.get("tag", "") != tag:
            continue
        recs.append(r)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in recs:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — |"
                         f" — | *skipped: full attention* | — | — | — | — |")
            continue
        if not r.get("ok"):
            continue
        rl = r["roofline"]
        mem = r.get("memory", {}).get("per_device_gb", float("nan"))
        fits = "yes" if r.get("fits_16gb") else "**no**"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mem:.2f} | {fits} |"
            f" {rl['t_compute_s']:.2e} | {rl['t_memory_s']:.2e} |"
            f" {rl.get('t_memory_lb_s', 0):.2e} |"
            f" {rl['t_collective_s']:.2e} | {r['bottleneck']} |"
            f" {r.get('bottleneck_lb', '—')} |"
            f" {r['roofline_fraction']:.2f} |"
            f" {r.get('roofline_fraction_lb', 0):.2f} |"
            f" {rl['useful_flops_ratio']:.2f} |")
    return "\n".join(lines)


def main() -> None:
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = text.replace("<!-- SINGLE_POD_TABLE -->", table("16x16"))
    text = text.replace("<!-- MULTI_POD_TABLE -->", table("2x16x16"))
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("tables injected")


if __name__ == "__main__":
    main()
