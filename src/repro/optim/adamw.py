"""AdamW with global-norm clipping, gradient accumulation and an optional
bf16 stochastic-rounding gradient-compression transform (the distributed-
optimization hook of DESIGN.md §7 — halves gradient all-reduce bytes).

Plain pytree implementation (no optax dependency): m/v moments are f32 and
inherit the parameter sharding, so ZeRO-style sharded optimizer state falls
out of the fsdp policy for free.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params, *, abstract: bool = False) -> AdamWState:
    def zero(p):
        if abstract or isinstance(p, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    step = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
            else jnp.zeros((), jnp.int32))
    return AdamWState(step, jax.tree.map(zero, params), jax.tree.map(zero, params))


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-6))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def compress_grads(grads, key: jax.Array):
    """bf16 stochastic rounding: the all-reduce then moves half the bytes.
    Off by default; enabled per-run (measured as a §Perf iteration)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for g, k in zip(leaves, keys):
        gf = g.astype(jnp.float32)
        noise = jax.random.uniform(k, gf.shape, jnp.float32, -0.5, 0.5)
        scale = jnp.float32(2.0 ** -8)  # bf16 mantissa step at unit scale
        out.append((gf + noise * scale * jnp.abs(gf)).astype(jnp.bfloat16))
    return jax.tree.unflatten(treedef, out)


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr: jnp.ndarray | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
):
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        update = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (update + weight_decay * pf)
        return pf.astype(p.dtype), m2, v2

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params2 = jax.tree.unflatten(td, [n[0] for n in new])
    m2 = jax.tree.unflatten(td, [n[1] for n in new])
    v2 = jax.tree.unflatten(td, [n[2] for n in new])
    return params2, AdamWState(step, m2, v2), gnorm
