"""LR schedules: cosine (llama-style) and WSD (warmup-stable-decay — the
MiniCPM schedule its config asks for)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def wsd_schedule(step, *, peak_lr: float, warmup: int, stable: int,
                 decay: int, floor_frac: float = 0.01):
    """Warmup -> stable plateau -> short exponential-ish decay (MiniCPM)."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
    dec = peak_lr * (floor_frac ** prog)
    out = jnp.where(step < warmup, warm,
                    jnp.where(step < warmup + stable, peak_lr, dec))
    return out
