"""Graph pass (RA0xx): label/bound/dtype consistency and OpDef conformance
of one EinGraph — independent of any plan, mesh, or backend.

Everything here re-derives what the builders *should* have enforced, so
hand-constructed graphs (``EinGraph.opaque`` performs no OpDef validation)
and graphs deserialized from caches get the same checks the frontend path
got at build time.
"""
from __future__ import annotations

import numpy as np

from repro.core import opdef
from repro.core.einsum import EinGraph

from repro.analysis.findings import Finding


def _f(code: str, msg: str, n=None) -> Finding:
    if n is None:
        return Finding(code, msg)
    return Finding(code, msg, nid=n.nid, node=n.name, srcloc=n.srcloc)


def _is_float(dtype) -> bool:
    try:
        return np.dtype(dtype).kind == "f"
    except TypeError:
        return False


def analyze_graph(g: EinGraph, out_ids=None) -> list[Finding]:
    findings: list[Finding] = []
    n_nodes = len(g.nodes)

    # RA007: duplicate input names -----------------------------------------
    seen: dict[str, int] = {}
    for n in g.nodes:
        if n.kind != "input":
            continue
        if n.name in seen:
            findings.append(_f(
                "RA007", f"input name {n.name!r} already used by node "
                         f"{seen[n.name]} — feeds are name-keyed", n))
        seen.setdefault(n.name, n.nid)

    # RA001: dead nodes (not reachable from the requested outputs) ---------
    outs = list(out_ids) if out_ids is not None else g.outputs()
    live: set[int] = set()
    stack = [o for o in outs if 0 <= o < n_nodes]
    while stack:
        nid = stack.pop()
        if nid in live:
            continue
        live.add(nid)
        stack.extend(g.nodes[nid].inputs)
    for n in g.nodes:
        if n.nid not in live:
            findings.append(_f(
                "RA001", f"{n.kind} node is unreachable from the requested "
                         f"outputs {sorted(outs)}", n))

    bounds: dict[str, int] = {}  # per-graph label universe is node-local;
    for n in g.nodes:            # bounds reset per node below
        # RA002: node labels vs shape rank ---------------------------------
        if len(n.labels) != len(n.shape):
            findings.append(_f(
                "RA002", f"{len(n.labels)} labels {n.labels} vs rank "
                         f"{len(n.shape)} shape {n.shape}", n))
            continue
        for a in n.inputs:
            if not (0 <= a < n_nodes) or a >= n.nid:
                findings.append(_f(
                    "RA002", f"input edge {a} is not an earlier node "
                             "(graphs are topological by construction)", n))

        if n.kind == "einsum":
            if n.spec is None:
                findings.append(_f("RA008", "einsum node without a spec", n))
                continue
            # RA008: spec arity vs inputs ----------------------------------
            if len(n.spec.in_labels) != len(n.inputs):
                findings.append(_f(
                    "RA008", f"spec {n.spec.pretty()!r} takes "
                             f"{len(n.spec.in_labels)} inputs, node has "
                             f"{len(n.inputs)}", n))
                continue
            if tuple(n.spec.out_labels) != tuple(n.labels):
                findings.append(_f(
                    "RA002", f"node labels {n.labels} differ from spec "
                             f"output labels {n.spec.out_labels}", n))
            # RA002/RA003: per-edge rank + bound consistency ---------------
            bounds = {}
            ok = True
            for i, (ls, a) in enumerate(zip(n.spec.in_labels, n.inputs)):
                an = g.nodes[a]
                if len(ls) != len(an.shape):
                    findings.append(_f(
                        "RA002", f"input {i} ({an.name}) rank "
                                 f"{len(an.shape)} vs edge labels {ls}", n))
                    ok = False
                    continue
                for l, b in zip(ls, an.shape):
                    if bounds.setdefault(l, b) != b:
                        findings.append(_f(
                            "RA003", f"label {l!r} bound {b} on input {i} "
                                     f"({an.name}) vs {bounds[l]} "
                                     "elsewhere", n))
                        ok = False
            if ok:
                want = tuple(bounds.get(l) for l in n.spec.out_labels)
                if want != n.shape:
                    findings.append(_f(
                        "RA003", f"output shape {n.shape} contradicts the "
                                 f"label bounds {want}", n))
            # RA006: float-width drift across einsum operands --------------
            dts = [g.nodes[a].dtype for a in n.inputs]
            fl = [np.dtype(d) for d in dts if _is_float(d)]
            if len(fl) == len(dts) and len({d.itemsize for d in fl}) > 1:
                findings.append(_f(
                    "RA006", f"operand dtypes {[str(d) for d in fl]} "
                             "differ; result silently takes the first", n))

        elif n.kind == "map":
            od = opdef.get(n.op)
            if od is None or od.category != "map":
                findings.append(_f(
                    "RA005", f"map kind {n.op!r} is not a registered map "
                             "op (opdef.list_ops('map'))", n))
            if len(n.inputs) != 1:
                findings.append(_f(
                    "RA008", f"map node takes 1 input, has "
                             f"{len(n.inputs)}", n))
            elif g.nodes[n.inputs[0]].shape != n.shape:
                findings.append(_f(
                    "RA003", "map output shape "
                             f"{n.shape} differs from its input's "
                             f"{g.nodes[n.inputs[0]].shape} (maps are "
                             "elementwise)", n))

        elif n.kind == "opaque":
            base = n.op.split(opdef.VJP_TAG)[0] if opdef.VJP_TAG in n.op \
                else n.op
            od = opdef.get(base)
            if od is None and opdef.executable_or_none(n.op) is None:
                findings.append(_f(
                    "RA005", f"opaque kind {n.op!r} is not registered "
                             "(ein.defop) and has no executable impl", n))
            # RA008: in_labels arity vs inputs -----------------------------
            if n.in_labels and len(n.in_labels) != len(n.inputs):
                findings.append(_f(
                    "RA008", f"{len(n.in_labels)} in_labels for "
                             f"{len(n.inputs)} inputs", n))
            elif n.in_labels:
                # RA002/RA003: edge labels vs input shapes + output -------
                bounds = {l: s for l, s in zip(n.labels, n.shape)}
                for i, (ls, a) in enumerate(zip(n.in_labels, n.inputs)):
                    an = g.nodes[a]
                    if len(ls) != len(an.shape):
                        findings.append(_f(
                            "RA002", f"input {i} ({an.name}) rank "
                                     f"{len(an.shape)} vs edge labels "
                                     f"{ls}", n))
                        continue
                    for l, b in zip(ls, an.shape):
                        if bounds.setdefault(l, b) != b:
                            findings.append(_f(
                                "RA003", f"label {l!r} bound {b} on input "
                                         f"{i} ({an.name}) vs {bounds[l]} "
                                         "elsewhere", n))
            # RA004: re-run the OpDef signature inference ------------------
            if od is not None and od.signature is not None and \
                    opdef.VJP_TAG not in n.op and \
                    len(n.inputs) == len(od.in_labels):
                try:
                    bound = opdef.bind_call(
                        od, [g.nodes[a].shape for a in n.inputs],
                        in_labels=n.in_labels,
                        out_labels=n.labels or None,
                        params=n.call_params)
                except opdef.OpDefError as e:
                    findings.append(_f("RA004", str(e), n))
                else:
                    if bound["out_shape"] != n.shape:
                        findings.append(_f(
                            "RA004", f"node shape {n.shape} contradicts "
                                     "the signature-inferred "
                                     f"{bound['out_shape']}", n))

    return findings
