"""repro.analysis — backend-free static verification of EinGraphs, plans,
and collective schedules.

Four passes, ruff-style ``RA`` codes (``findings.CODES`` is the index):

  graph    (RA0xx)  labels, bounds, dtypes, OpDef signature conformance
  plan     (RA1xx)  divisibility, mesh axes, shard rules, §7 cost honesty
  schedule (RA2xx)  ppermute bijectivity, donation aliasing, chain shapes,
                    double-buffer overlap, traced ≤ priced
  memory   (RA3xx)  peak per-device live bytes vs --max-hbm

Everything runs without initializing a jax backend — planning and schedule
lowering are pure Python over static shapes.  CLI::

    python -m repro.analysis --families all --mesh data=2,model=4
"""
from repro.analysis.findings import CODES, ERROR, Finding, Report, WARNING
from repro.analysis.graph_pass import analyze_graph
from repro.analysis.memory_pass import analyze_memory
from repro.analysis.plan_pass import analyze_plan
from repro.analysis.runner import (analyze, analyze_compiled,
                                   analyze_program, analyze_schedule_only)
from repro.analysis.schedule_pass import analyze_schedule

__all__ = [
    "CODES", "ERROR", "WARNING", "Finding", "Report",
    "analyze", "analyze_graph", "analyze_plan", "analyze_schedule",
    "analyze_memory", "analyze_program", "analyze_compiled",
    "analyze_schedule_only",
]
