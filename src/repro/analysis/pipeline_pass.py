"""Pipeline pass (RA4xx): the stage chain, handoff ordering, and
per-stage memory of a ``PipelineSchedule``.

What the pipeline tier promises statically, re-verified from the built
schedule rather than trusted from its builder:

  RA401  the stage graph is a *chain*: every handoff stub consumes a
         tensor produced by a strictly earlier stage (a back-edge means
         the cut was not dependency-closed — a cycle between stages);
  RA402  every handoff fires only after its producing (stage, microbatch)
         cell: in the combined trace, no intra-stage event of cell
         (s, mb) may appear after a rule="handoff" event tagged (s, mb)
         (the executor issues the ppermute when the cell's values exist —
         a premature handoff would ship garbage);
  RA403  per-stage peak live bytes: each stage schedule runs the memory
         pass on its own subgraph against ``--max-hbm`` — the pipeline's
         point is that *stages*, not the whole graph, must fit;
  RA404  stage imbalance: the realized max/mean compute ratio exceeds the
         partitioner's own ``balance`` cap (the DP doubled its cap to
         find a feasible cut — worth a warning, the bubble fraction the
         static tier prices assumes balanced stages);
  RA405  per-stage cost honesty: each stage schedule's traced intra-stage
         wire (one microbatch) stays within ``stage_priced_cost`` — the
         per-stage analogue of RA206.  The whole-graph RA206 convention
         does not transfer to a pipelined cell: the stitched plan is
         per-stage DP-optimal, not a whole-graph DP output, and the
         pipelined executor never runs the whole-graph schedule — the
         sound static bound is the per-stage price (plan_cost over the
         stage graph plus the input-edge and replicate-gather surcharges
         a single stage cannot amortize away).

Backend-free like every other pass: a ``PipelineSchedule`` is pure Python
over static shapes.
"""
from __future__ import annotations

from repro.core.einsum import EinGraph

from repro.analysis.findings import Finding
from repro.analysis.memory_pass import analyze_memory


def analyze_pipeline_schedule(g: EinGraph, psched,
                              max_hbm: int | None = None) -> list[Finding]:
    """All RA4xx checks over one built ``PipelineSchedule``."""
    findings: list[Finding] = []
    findings += _check_stage_chain(g, psched.stages)
    findings += _check_handoff_order(g, psched)
    findings += _check_stage_memory(psched, max_hbm)
    findings += _check_balance(g, psched)
    findings += _check_stage_wire(psched)
    return findings


def _check_stage_chain(g: EinGraph, stages) -> list[Finding]:
    out: list[Finding] = []
    stage_of = {gn: st.index for st in stages for gn in st.nids}
    for st in stages:
        for gn in st.recv:
            src = stage_of.get(gn)
            n = g.nodes[gn] if 0 <= gn < len(g.nodes) else None
            name = n.name if n is not None else f"<{gn}>"
            if src is None:
                out.append(Finding(
                    "RA401", f"stage {st.index} receives node {gn} "
                             f"({name}) that no stage produces",
                    nid=gn, node=name,
                    srcloc=n.srcloc if n is not None else ""))
            elif src >= st.index:
                out.append(Finding(
                    "RA401", f"stage {st.index} receives node {gn} "
                             f"({name}) produced by stage {src} — the "
                             "stage graph has a back-edge (not a chain)",
                    nid=gn, node=name,
                    srcloc=n.srcloc if n is not None else ""))
    return out


def _check_handoff_order(g: EinGraph, psched) -> list[Finding]:
    """A rule="handoff" event tagged (s, mb) must come after every
    intra-stage event of cell (s, mb) — the producing cell completes
    before its values ship."""
    out: list[Finding] = []
    handoff_seen: set[tuple[int, int]] = set()
    for e in psched.trace.events:
        cell = (e.stage, e.microbatch)
        if e.rule == "handoff":
            handoff_seen.add(cell)
        elif cell in handoff_seen:
            n = g.nodes[e.nid] if 0 <= e.nid < len(g.nodes) else None
            out.append(Finding(
                "RA402", f"cell (stage {e.stage}, microbatch "
                         f"{e.microbatch}) issues {e.kind} for node "
                         f"{e.nid} after its handoff already fired — "
                         "the ppermute ships values the cell has not "
                         "produced yet",
                nid=e.nid, node=n.name if n is not None else "",
                srcloc=n.srcloc if n is not None else ""))
    return out


def _check_stage_memory(psched, max_hbm: int | None) -> list[Finding]:
    out: list[Finding] = []
    if max_hbm is None:
        return out
    for st in psched.stages:
        if st.sched is None:
            continue
        local_outs = [st.lid_of[gn] for gn in st.out_gids]
        _, report = analyze_memory(st.graph, st.sched, local_outs, (), None)
        peak = report.get("peak_bytes", 0)
        if peak > max_hbm:
            out.append(Finding(
                "RA403", f"stage {st.index}: peak live bytes {peak:,} B "
                         f"per device exceed --max-hbm {int(max_hbm):,} B "
                         f"(the stage alone must fit)"))
    return out


def _check_stage_wire(psched) -> list[Finding]:
    """RA405: traced intra-stage wire of each stage (one microbatch — every
    microbatch replays the same stage schedule) within the sound per-stage
    §7 price (see module doc).  Skipped for hand-built schedules whose
    stages carry no plan/sched."""
    from repro.pipeline.plan import stage_priced_cost

    out: list[Finding] = []
    for st in psched.stages:
        if st.plan is None or st.sched is None:
            continue
        traced = psched.stage_trace_elems(st.index)
        priced = stage_priced_cost(st)
        if traced > priced:
            out.append(Finding(
                "RA405", f"stage {st.index} schedule moves {traced:,} wire "
                         f"elems (one microbatch), over its per-stage §7 "
                         f"price {priced:,} — the realized stage schedule "
                         "diverged from the priced one"))
    return out


def _check_balance(g: EinGraph, psched) -> list[Finding]:
    """Re-verify the partitioner's own contract: max stage weight (the
    partitioner's join-size metric, recomputed here) within ``balance x
    total / p``.  Fires exactly when the DP had to double its cap to find
    a feasible cut — an unbalanced chain whose real bubble exceeds the
    static (p-1)/(m+p-1)."""
    from repro.pipeline.partition import _node_weight

    stages = psched.stages
    p = len(stages)
    if p <= 1:
        return []
    ws = [sum(_node_weight(st.graph, st.lid_of[gn]) for gn in st.nids)
          for st in stages]
    total = sum(ws)
    if total == 0:
        return []
    cap = psched.spec.balance * total / p
    worst = max(ws)
    if worst > cap:
        s = ws.index(worst)
        return [Finding(
            "RA404", f"stage {s} carries {worst:,} of {total:,} weight vs "
                     f"the balance cap {cap:,.0f} (balance="
                     f"{psched.spec.balance}) — no balanced cut exists, "
                     f"so the static bubble fraction {psched.bubble:.3f} "
                     f"understates the realized one "
                     f"{psched.bubble_weighted:.3f}")]
    return []
