"""Memory pass (RA3xx): peak per-device live bytes, statically.

Deinsum (arxiv 2206.08301) derives distributed memory footprints from the
einsum spec alone; this pass does the same from (graph, plan, schedule):
every buffer's per-device block shape is ``local_shape(shape, layout,
sizes)``, liveness follows topo order (producer → last consumer; inputs
and outputs are program-lifetime, matching XLA's argument/output
accounting; donated inputs die after their last read), and repartition
chains add their largest replay copy as transient working space — at the
consumer for serial chains, at the hoisted issue point for lookahead
prefetches, whose landed shards additionally stay live until the consumer
reads them.  The result is the deliberate first brick of ROADMAP's
memory-aware planning: ``--max-hbm`` turns the report into a hard bound
(RA301/RA302).
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np

from repro.core.einsum import EinGraph
from repro.core.spmd import Schedule, local_shape

from repro.analysis.findings import Finding
from repro.analysis.schedule_pass import _replay_chain


def _itemsize(dtype) -> int:
    try:
        return np.dtype(dtype).itemsize
    except TypeError:
        return 4


def analyze_memory(g: EinGraph, sched: Schedule, out_ids=None,
                   donate: Sequence[str] = (), max_hbm: int | None = None
                   ) -> tuple[list[Finding], dict]:
    """Returns (findings, report).  The report dict carries the numbers the
    acceptance test compares against jax's ``compiled.memory_analysis()``:
    ``args_bytes`` / ``out_bytes`` / ``peak_bytes`` are all per-device."""
    findings: list[Finding] = []
    sizes = sched.sizes
    consumers = g.consumers()
    out_set = set(out_ids) if out_ids is not None else set(g.outputs())
    donated = {n.nid for n in g.nodes
               if n.kind == "input" and n.name in set(donate)}
    n_pos = len(g.nodes)

    def bytes_of(nid: int, shape=None) -> int:
        n = g.nodes[nid]
        try:
            loc = shape if shape is not None else \
                local_shape(n.shape, sched.layouts.get(nid, ()), sizes)
        except (ValueError, KeyError):
            loc = n.shape  # unrealizable layout: RA203 already flagged it
        return math.prod(loc) * _itemsize(n.dtype) if loc else \
            _itemsize(n.dtype)

    # lifetime [birth, death] in topo positions, inclusive ----------------
    buf_bytes: dict[int, int] = {}
    birth: dict[int, int] = {}
    death: dict[int, int] = {}
    for n in g.nodes:
        buf_bytes[n.nid] = bytes_of(n.nid)
        last = max(consumers.get(n.nid, []), default=n.nid)
        if n.kind == "input":
            # arguments are held for the whole program (XLA accounts the
            # full argument size) — unless donated, which frees/aliases
            # the buffer after its last read
            birth[n.nid] = 0
            death[n.nid] = last if n.nid in donated else n_pos - 1
        else:
            birth[n.nid] = n.nid
            death[n.nid] = n_pos - 1 if n.nid in out_set else last

    # transient repartition copies: while node t executes, each gathered /
    # re-bucketed argument occupies its largest replay shape next to the
    # resident buffers.  A *prefetched* argument (graph-wide lookahead)
    # widens that lifetime: the chain replays — and peaks — at its hoisted
    # issue position, and the landed shard stays live from there until the
    # consumer reads it, so its final bytes are charged over the whole
    # (issue, consumer] window.
    pf_issue = {(pf.consumer, pf.arg): pf.issue
                for pf in getattr(sched, "prefetches", ()) or ()}
    extra = [0] * n_pos
    prefetch_hold_bytes = 0
    for prog in sched.programs:
        n = g.nodes[prog.nid]
        for ai, (a, steps) in enumerate(zip(n.inputs, prog.arg_steps)):
            if not steps:
                continue
            try:
                shape = local_shape(g.nodes[a].shape,
                                    sched.layouts.get(a, ()), sizes)
            except (ValueError, KeyError):
                continue
            peak = math.prod(shape) if shape else 1
            s = list(shape)
            for st in steps:
                nxt, err = _replay_chain(tuple(s), [st], sizes)
                if err or nxt is None:
                    break
                s = list(nxt)
                peak = max(peak, math.prod(s) if s else 1)
            item = _itemsize(g.nodes[a].dtype)
            issue = pf_issue.get((prog.nid, ai), prog.nid)
            if not 0 <= issue < prog.nid:
                issue = prog.nid  # malformed lifetime: RA208's domain —
                #                   fall back to the serial charge
            extra[issue] += peak * item
            if issue < prog.nid:
                final = (math.prod(s) if s else 1) * item
                prefetch_hold_bytes += final
                for t in range(issue + 1, prog.nid + 1):
                    extra[t] += final

    # peak over topo positions --------------------------------------------
    peak_bytes = 0
    peak_pos = 0
    for t in range(n_pos):
        live = sum(b for nid, b in buf_bytes.items()
                   if birth[nid] <= t <= death[nid])
        live += extra[t]
        if live > peak_bytes:
            peak_bytes, peak_pos = live, t

    args_bytes = sum(buf_bytes[n.nid] for n in g.nodes if n.kind == "input")
    out_bytes = sum(buf_bytes[nid] for nid in out_set)
    top = sorted(buf_bytes.items(), key=lambda kv: -kv[1])[:8]
    report = {
        "peak_bytes": int(peak_bytes),
        "peak_pos": int(peak_pos),
        "args_bytes": int(args_bytes),
        "out_bytes": int(out_bytes),
        "n_buffers": len(buf_bytes),
        "n_prefetches": len(pf_issue),
        "prefetch_hold_bytes": int(prefetch_hold_bytes),
        "top_buffers": [{"nid": nid, "name": g.nodes[nid].name,
                         "bytes": int(b)} for nid, b in top],
    }

    if max_hbm is not None:
        for nid, b in top:
            if b > max_hbm:
                n = g.nodes[nid]
                findings.append(Finding(
                    "RA302", f"buffer {n.name!r} alone is {b:,} B per "
                             f"device, over --max-hbm {int(max_hbm):,} B",
                    nid=nid, node=n.name, srcloc=n.srcloc))
        if peak_bytes > max_hbm:
            n = g.nodes[peak_pos]
            findings.append(Finding(
                "RA301", f"peak live bytes {peak_bytes:,} B per device "
                         f"(at node {peak_pos}, {n.name}) exceed "
                         f"--max-hbm {int(max_hbm):,} B",
                nid=peak_pos, node=n.name, srcloc=n.srcloc))
    return findings, report
