"""Schedule pass (RA2xx): safety properties of the *static* collective
schedule (``spmd.build_schedule`` — pure Python, no backend).

The executor trusts this schedule: a non-bijective ppermute deadlocks or
silently drops shards at run time, a donated buffer read after its
aliasing step returns garbage, and a repartition chain whose shape
evolution breaks raises deep inside shard_map.  This pass verifies the
recorded schedule — including the exact ppermute (src, dst) pairs the
executor will issue (``CollectiveEvent.perm``) and the planner bounds the
benches assert dynamically (traced ≤ priced, per ruled opaque node and for
the whole program).
"""
from __future__ import annotations

import math
from typing import Sequence

from repro.core import opdef
from repro.core.decomp import Plan, opaque_node_bound
from repro.core.einsum import EinGraph
from repro.core.spmd import Schedule, _step_shape, _wire_elems, local_shape

from repro.analysis.findings import Finding, WARNING

#: rules whose per-node traced-vs-bound contract holds on every zoo cell.
#: ``local`` is deliberately absent: its zero-collective scan contract is
#: pinned dynamically (bench_spmd, prefill), but decode plans may pay a
#: producer-layout gather that cost_repart ring-prices below the
#: all_gather's wire accounting — a known pricing slack, not a schedule bug.
_BOUNDED_RULES = ("ring", "a2a")


def _f(code: str, msg: str, n, severity: str = "") -> Finding:
    return Finding(code, msg, severity=severity, nid=n.nid, node=n.name,
                   srcloc=n.srcloc)


def _group_size(axes, sizes: dict[str, int]) -> int:
    return math.prod(sizes.get(a, 1) for a in axes)


def _replay_chain(shape: tuple[int, ...], steps, sizes: dict[str, int]
                  ) -> tuple[tuple[int, ...] | None, str]:
    """Replay one repartition chain; ("", final shape) on success, else an
    error string naming the offending step."""
    s = list(shape)
    for st in steps:
        kind = st[0]
        try:
            if kind == "all_gather":
                s[st[2]] *= sizes[st[1]]
            elif kind == "all_to_all":
                _, ax, i, j = st
                k = sizes[ax]
                if s[j] % k:
                    return None, (f"all_to_all over {ax!r} (x{k}) does not "
                                  f"divide dim {j} of {tuple(s)}")
                s[i] *= k
                s[j] //= k
            elif kind in ("slice", "psum_scatter"):
                k = sizes[st[1]]
                if s[st[2]] % k:
                    return None, (f"{kind} over {st[1]!r} (x{k}) does not "
                                  f"divide dim {st[2]} of {tuple(s)}")
                s[st[2]] //= k
            elif kind == "psum_scatter_grouped":
                for ax, d in st[1]:
                    k = sizes[ax]
                    if s[d] % k:
                        return None, (f"grouped psum_scatter over {ax!r} "
                                      f"(x{k}) does not divide dim {d} of "
                                      f"{tuple(s)}")
                    s[d] //= k
            # ppermute / psum / pmax / pmin / gather_reduce keep the shape
        except (IndexError, KeyError) as e:
            return None, f"step {st!r} is malformed for shape {tuple(s)}: {e}"
    return tuple(s), ""


def analyze_schedule(g: EinGraph, plan: Plan | None, sched: Schedule,
                     out_ids=None, donate: Sequence[str] = ()
                     ) -> list[Finding]:
    findings: list[Finding] = []
    sizes = sched.sizes
    trace = sched.trace
    consumers = g.consumers()
    out_set = set(out_ids) if out_ids is not None else set(g.outputs())

    # RA201: ppermute permutation bijectivity ------------------------------
    for e in trace.events:
        if e.kind != "ppermute":
            continue
        n = g.nodes[e.nid]
        group = _group_size(e.axes, sizes)
        if not e.perm:
            findings.append(_f(
                "RA201", f"ppermute over {e.axes} carries no (src, dst) "
                         "pairs — bijectivity cannot be verified",
                n, severity=WARNING))
            continue
        srcs = [p[0] for p in e.perm]
        dsts = [p[1] for p in e.perm]
        bad = []
        if len(e.perm) != group:
            bad.append(f"{len(e.perm)} pairs for a {group}-device group")
        if len(set(srcs)) != len(srcs):
            bad.append("duplicate sources (a device sends twice: deadlock)")
        if len(set(dsts)) != len(dsts):
            bad.append("duplicate destinations (shards collide: data loss)")
        outside = [v for v in srcs + dsts if not 0 <= v < group]
        if outside:
            bad.append(f"indices {sorted(set(outside))} outside the "
                       f"{group}-device group {e.axes}")
        if bad:
            findings.append(_f(
                "RA201", f"ppermute over {e.axes}: " + "; ".join(bad), n))

    # RA202/RA207: donation-aliasing safety --------------------------------
    by_name = {n.name: n for n in g.nodes if n.kind == "input"}
    for name in donate:
        n = by_name.get(name)
        if n is None:
            continue  # unknown donate names are a compile-time KeyError
        cons = sorted(consumers.get(n.nid, []))
        if not cons and n.nid not in out_set:
            findings.append(_f(
                "RA207", f"donated input {name!r} is never read — the "
                         "donation frees nothing", n))
            continue
        # the aliasing step is the first consumer (topo order): once it
        # runs, the donated buffer may have been overwritten in place
        if len(cons) > 1:
            later = [f"{g.nodes[c].name} (node {c})" for c in cons[1:]]
            findings.append(_f(
                "RA202", f"donated input {name!r} is read again after its "
                         f"aliasing step (node {cons[0]}, "
                         f"{g.nodes[cons[0]].name}) by {', '.join(later)}",
                n))
        if cons and n.nid in out_set:
            findings.append(_f(
                "RA202", f"donated input {name!r} is consumed and also "
                         "returned as a program output — the returned "
                         "buffer may alias the overwritten donation", n))

    # RA203: repartition-chain shape evolution -----------------------------
    for prog in sched.programs:
        n = g.nodes[prog.nid]
        for i, (a, steps) in enumerate(zip(n.inputs, prog.arg_steps)):
            if not steps:
                continue
            try:
                start = local_shape(g.nodes[a].shape, sched.layouts[a],
                                    sizes)
            except (ValueError, KeyError) as e:
                findings.append(_f(
                    "RA203", f"arg {i} ({g.nodes[a].name}): producer "
                             f"layout is not realizable: {e}", n))
                continue
            _, err = _replay_chain(start, steps, sizes)
            if err:
                findings.append(_f(
                    "RA203", f"arg {i} ({g.nodes[a].name}): {err}", n))
        # post_steps are not replayed: they start from the node's *compute*
        # shape (pre-reduction for einsum, the rule's out_layout for
        # opaque), which the Schedule does not record — build_schedule
        # itself asserts their evolution at lowering time

    # RA204: double-buffer overlap hazards ---------------------------------
    # (graph-wide lookahead prefetches also ride the overlap mark but are
    # attributed via prefetch_for and audited by RA208 below — the ring
    # rule's per-hop accounting must not see them)
    overlap_by_node: dict[int, int] = {}
    for e in trace.events:
        if not e.overlap or e.prefetch_for >= 0:
            continue
        n = g.nodes[e.nid]
        if not e.rule:
            findings.append(_f(
                "RA204", f"overlapped {e.kind} emitted outside any shard "
                         "rule — there is no compute loop to overlap "
                         "with", n))
        if e.kind != "ppermute":
            findings.append(_f(
                "RA204", f"overlapped {e.kind}: only ring ppermute hops "
                         "are double-buffered", n, severity=WARNING))
        else:
            overlap_by_node[e.nid] = overlap_by_node.get(e.nid, 0) + 1
    for nid, count in sorted(overlap_by_node.items()):
        n = g.nodes[nid]
        ring_entries = [e for e in opdef.comm_for_node(n)
                        if e.get("kind") == "ring"]
        hops = [e for e in trace.events
                if e.nid == nid and e.kind == "ppermute" and e.overlap]
        r = _group_size(hops[0].axes, sizes) if hops else 1
        limit = max(len(ring_entries), 1) * max(r - 1, 0)
        if count > limit:
            findings.append(_f(
                "RA204", f"over-rotated ring: {count} overlapped hops for "
                         f"{len(ring_entries)} circulating tensors on a "
                         f"{r}-device ring (limit {limit}) — the last "
                         "rotation returns data already seen", n))

    # RA208: lookahead prefetch hazards ------------------------------------
    # A hoisted issue is only safe when the consumer's argument is already
    # producible at the issue point: its producer's compute (topo position
    # == nid) must precede the issue node's iteration.  Two lifetimes for
    # one (consumer, arg) would alias one prefetch buffer — the runner's
    # keyed dict holds exactly one value per slot.  And every
    # prefetch_for-marked event must be covered by a recorded lifetime,
    # else the memory pass cannot charge the buffer it implies.
    prefetches = list(getattr(sched, "prefetches", ()) or ())
    seen_slots: set[tuple[int, int]] = set()
    recorded_consumers: set[int] = set()
    for pf in prefetches:
        if not 0 <= pf.consumer < len(g.nodes):
            findings.append(Finding(
                "RA208", f"prefetch names consumer node {pf.consumer}, "
                         "which does not exist"))
            continue
        n = g.nodes[pf.consumer]
        recorded_consumers.add(pf.consumer)
        if not 0 <= pf.arg < len(n.inputs):
            findings.append(_f(
                "RA208", f"prefetch arg index {pf.arg} out of range for "
                         f"{len(n.inputs)} inputs", n))
            continue
        if (pf.consumer, pf.arg) in seen_slots:
            findings.append(_f(
                "RA208", f"two prefetches alias arg {pf.arg}'s buffer — "
                         "the second overwrites the first before its "
                         "consumer reads it", n))
        seen_slots.add((pf.consumer, pf.arg))
        if pf.issue >= pf.consumer:
            findings.append(_f(
                "RA208", f"prefetch of arg {pf.arg} issues at node "
                         f"{pf.issue}, not before its consumer "
                         f"{pf.consumer} — nothing is hoisted", n))
            continue
        if not 0 <= pf.issue < len(g.nodes):
            findings.append(_f(
                "RA208", f"prefetch of arg {pf.arg} issues at node "
                         f"{pf.issue}, which does not exist", n))
            continue
        if g.nodes[pf.issue].kind == "input":
            findings.append(_f(
                "RA208", f"prefetch of arg {pf.arg} issues at input node "
                         f"{pf.issue} ({g.nodes[pf.issue].name}) — input "
                         "nodes never execute an iteration, so the issue "
                         "never happens", n))
        a = n.inputs[pf.arg]
        if g.nodes[a].kind != "input" and pf.issue <= a:
            findings.append(_f(
                "RA208", f"prefetch of arg {pf.arg} issues at node "
                         f"{pf.issue}, before its producer "
                         f"{g.nodes[a].name} (node {a}) has computed — "
                         "the chain would read a stale or missing "
                         "buffer", n))
    for e in trace.events:
        if e.prefetch_for < 0 or e.prefetch_for in recorded_consumers:
            continue
        recorded_consumers.add(e.prefetch_for)  # one finding per consumer
        where = (g.nodes[e.prefetch_for] if 0 <= e.prefetch_for < len(g.nodes)
                 else None)
        msg = (f"{e.kind} is marked prefetch_for node {e.prefetch_for} but "
               "the schedule records no matching Prefetch lifetime — the "
               "memory pass cannot charge its buffer")
        findings.append(_f("RA208", msg, where) if where is not None
                        else Finding("RA208", msg))

    # RA205/RA206: traced wire elems vs the planner's §7 prices ------------
    # The §7 objective treats graph inputs as pre-placed (§8.2): the cost
    # of distributing an *input* to its consumer's layout is excluded from
    # plan_cost / opaque_node_bound, while the schedule records that wire.
    # Mirror the exclusion by replaying each input-edge chain with the same
    # accounting _record_steps used, so the comparison is like-for-like.
    if plan is not None:
        n_dev = _group_size(sizes.keys(), sizes)
        placement: dict[int, int] = {}
        for prog in sched.programs:
            n = g.nodes[prog.nid]
            moved = 0
            for a, steps in zip(n.inputs, prog.arg_steps):
                if g.nodes[a].kind != "input" or not steps:
                    continue
                try:
                    shape = local_shape(g.nodes[a].shape,
                                        sched.layouts[a], sizes)
                except (ValueError, KeyError):
                    continue
                for st in steps:
                    moved += _wire_elems(st, shape, sizes, n_dev)
                    shape = _step_shape(shape, st, sizes)
            if moved:
                placement[prog.nid] = moved

        elems_by_node = trace.elems_by_node
        for nid, rule in sorted(trace.rule_by_node.items()):
            if rule not in _BOUNDED_RULES:
                continue
            traced = elems_by_node.get(nid, 0) - placement.get(nid, 0)
            try:
                bound = opaque_node_bound(g, plan, nid)
            except Exception:
                continue  # unpriceable node: plan pass already flagged it
            if traced > bound:
                findings.append(_f(
                    "RA205", f"{rule} rule moves {traced:,} wire elems "
                             "(input placement excluded, §8.2), over its "
                             f"_opaque_comm_cost bound {bound:,} — the "
                             "realized schedule diverged from the priced "
                             "one", g.nodes[nid]))
        total = trace.total_elems - sum(placement.values())
        if plan.cost and total > plan.cost:
            findings.append(Finding(
                "RA206", f"schedule moves {total:,} wire elems (input "
                         "placement excluded, §8.2), over the §7 "
                         f"plan_cost {plan.cost:,} the DP optimized"))
    return findings
