"""Plan pass (RA1xx): does this Plan actually fit this graph + mesh?

Re-derives, as findings instead of exceptions, everything the planner
assumes and the executor will later assert — partitioning divisibility,
mesh-axis bookkeeping, shard-rule/comm resolvability, and the §7 pricing
invariant ``plan.cost == plan_cost(g, plan)`` (a plan edited or
deserialized after pricing is stale and the cost-honesty contract of the
benches silently breaks).
"""
from __future__ import annotations

import math

from repro.core import opaque_rules, opdef
from repro.core.decomp import Plan, node_bounds, node_label_universe, plan_cost
from repro.core.einsum import EinGraph

from repro.analysis.findings import Finding

#: comm kinds the DP prices — mirror of opdef.COMM_KINDS
_COMM_KINDS = set(opdef.COMM_KINDS)


def _f(code: str, msg: str, n) -> Finding:
    return Finding(code, msg, nid=n.nid, node=n.name, srcloc=n.srcloc)


def analyze_plan(g: EinGraph, plan: Plan,
                 mesh_axes: dict[str, int] | None = None) -> list[Finding]:
    findings: list[Finding] = []
    sizes = {a: int(s) for a, s in (mesh_axes or {}).items()}
    structurally_ok = True

    for n in g.nodes:
        d = plan.d_by_node.get(n.nid)
        if d is None:
            findings.append(_f(
                "RA101", "no partitioning entry in the plan", n))
            structurally_ok = False
            continue
        universe = node_label_universe(n)
        bounds = node_bounds(g, n.nid)

        # RA102: every partitioned label must divide its bound ------------
        for l, k in d.items():
            if l not in bounds:
                findings.append(_f(
                    "RA102", f"plan partitions label {l!r} which is not "
                             f"on the node (universe {universe})", n))
                structurally_ok = False
            elif k < 1 or bounds[l] % k:
                findings.append(_f(
                    "RA102", f"label {l!r}: {k} parts do not divide bound "
                             f"{bounds[l]}", n))
                structurally_ok = False

        # RA103: over-parallel (more shards than devices) -----------------
        total = math.prod(d.values()) if d else 1
        if total > plan.p:
            findings.append(_f(
                "RA103", f"product of parts {total} exceeds the plan's "
                         f"p={plan.p}", n))
            structurally_ok = False

        # RA108: sharding outside the declared shardable set --------------
        if n.kind == "opaque" and n.shardable is not None:
            for l, k in d.items():
                if k > 1 and l not in n.shardable:
                    findings.append(_f(
                        "RA108", f"label {l!r} is partitioned x{k} but is "
                                 "outside the node's shardable set "
                                 f"{sorted(n.shardable)}", n))

        # RA104: mesh-axis bookkeeping (mesh-mode plans only) -------------
        ax_n = plan.axes_by_node.get(n.nid, {})
        used: dict[str, str] = {}
        for l, axes in ax_n.items():
            for a in axes:
                if sizes and a not in sizes:
                    findings.append(_f(
                        "RA104", f"label {l!r} is sharded over unknown "
                                 f"mesh axis {a!r} (mesh has "
                                 f"{sorted(sizes)})", n))
                    structurally_ok = False
                prev = used.get(a)
                if prev is not None and prev != l:
                    findings.append(_f(
                        "RA104", f"mesh axis {a!r} shards both {prev!r} "
                                 f"and {l!r} on one node", n))
                    structurally_ok = False
                used[a] = l
            if sizes and all(a in sizes for a in axes):
                prod = math.prod(sizes[a] for a in axes) if axes else 1
                if prod != d.get(l, 1):
                    findings.append(_f(
                        "RA104", f"label {l!r}: mesh axes {tuple(axes)} "
                                 f"(x{prod}) disagree with d[{l!r}]="
                                 f"{d.get(l, 1)}", n))
                    structurally_ok = False
        # map nodes are exempt: the executor rides their input's layout
        # through untouched, so they legitimately carry parts but no axes.
        # input nodes too: they are pre-placed (§8.2) — an axis-less input
        # lands replicated and is repartitioned at its consumers, which is
        # always correct (and its placement cost is excluded anyway)
        if plan.mode == "mesh" and sizes and n.kind not in ("map", "input"):
            for l, k in d.items():
                if k > 1 and not ax_n.get(l):
                    findings.append(_f(
                        "RA104", f"label {l!r} is partitioned x{k} but "
                                 "carries no mesh axes — the executor "
                                 "would silently replicate it", n))
                    structurally_ok = False

        # RA105/RA106: opaque comm + shard-rule resolvability -------------
        if n.kind == "opaque":
            try:
                entries = opdef.comm_for_node(n)
            except Exception as e:  # malformed template renaming
                findings.append(_f("RA106", f"comm template does not "
                                            f"rename onto the node: {e}", n))
                entries = []
            for entry in entries:
                kind = entry.get("kind")
                if kind not in _COMM_KINDS:
                    findings.append(_f(
                        "RA105", f"comm kind {kind!r} unknown (priced "
                                 f"kinds: {sorted(_COMM_KINDS)})", n))
                label = entry.get("label")
                if label is not None and label not in universe:
                    findings.append(_f(
                        "RA106", f"comm entry names label {label!r}, not "
                                 f"on the node (universe {universe})", n))
                idx = entry.get("input", 0)
                if not (-1 <= int(idx) < len(n.inputs)):
                    findings.append(_f(
                        "RA106", f"comm entry input index {idx} out of "
                                 f"range for {len(n.inputs)} inputs "
                                 "(-1 = output)", n))
            try:
                rule_name = opaque_rules.resolve_rule_name(n)
            except ValueError as e:
                findings.append(_f("RA105", str(e), n))
            else:
                try:
                    opaque_rules.get_rule(rule_name)
                except KeyError:
                    findings.append(_f(
                        "RA105", f"shard rule {rule_name!r} is not "
                                 "registered "
                                 "(core.opaque_rules.register_rule)", n))

    # RA107: §7 pricing invariant — only meaningful on structurally sound
    # plans (a broken plan would crash or garbage the repricing)
    if structurally_ok and plan.cost:
        fresh = plan_cost(g, plan)
        if int(plan.cost) != int(fresh):
            findings.append(Finding(
                "RA107", f"plan.cost={plan.cost:,} but plan_cost(g, plan) "
                         f"reprices to {fresh:,} — the plan changed after "
                         "pricing"))
    return findings
