"""``python -m repro.analysis``: static verification of the model zoo.

Backend-free end to end: graph construction (frontend tracing), §8 DP
planning, schedule lowering, and all four passes are pure Python — the CI
``analysis`` job and the subprocess regression test both assert that no
jax backend is ever initialized.

Examples::

    python -m repro.analysis                      # all families, 3 modes
    python -m repro.analysis --families llama-7b --modes decode \
        --mesh data=2,model=4 --max-hbm 2000000000 --json report.json
    python -m repro.analysis --list-codes
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.findings import CODES
from repro.analysis.runner import analyze_program

#: the bench families (benchmarks/bench_spmd.py); paged decode is only
#: built for the serving families (benchmarks/bench_serve.py)
FAMILIES = ["llama-7b", "mixtral-8x7b", "xlstm-125m", "hymba-1.5b"]
PAGED_FAMILIES = ["llama-7b", "xlstm-125m", "hymba-1.5b"]
MODES = ["prefill", "decode", "paged"]

#: reduced-config cell shapes, mirroring the benches: prefill 32x4
#: (bench_spmd), decode/paged 40x4 with 8-row KV blocks (bench_serve)
PREFILL_SEQ, PREFILL_BATCH = 32, 4
DECODE_SEQ, DECODE_BATCH = 40, 4
KV_BLOCK = 8


def _parse_mesh(text: str) -> dict[str, int]:
    axes: dict[str, int] = {}
    for part in text.split(","):
        if not part.strip():
            continue
        name, _, size = part.partition("=")
        axes[name.strip()] = int(size)
    if not axes:
        raise argparse.ArgumentTypeError(f"empty mesh spec {text!r}")
    return axes


def _cell_program(family: str, mode: str):
    from repro.configs import ShapeConfig, get_config, reduced
    from repro.models.eingraphs import program_for

    cfg = reduced(get_config(family))
    if mode == "prefill":
        shape = ShapeConfig("analysis", "prefill", PREFILL_SEQ,
                            PREFILL_BATCH)
        return program_for(cfg, shape)
    shape = ShapeConfig("analysis", "decode", DECODE_SEQ, DECODE_BATCH)
    return program_for(cfg, shape,
                       kv_block=KV_BLOCK if mode == "paged" else 0)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Backend-free static verifier for the model zoo "
                    "(graph / plan / schedule / memory passes).")
    ap.add_argument("--families", default="all",
                    help=f"comma list or 'all' ({', '.join(FAMILIES)})")
    ap.add_argument("--modes", default="all",
                    help=f"comma list or 'all' ({', '.join(MODES)})")
    ap.add_argument("--mesh", type=_parse_mesh, default="data=2,model=4",
                    help="mesh shape, e.g. data=2,model=4 (device count is "
                         "the product)")
    ap.add_argument("--max-hbm", type=int, default=None,
                    help="per-device HBM bound in bytes (RA301/RA302)")
    ap.add_argument("--no-fuse", action="store_true",
                    help="analyze the unfused repartition lowering")
    ap.add_argument("--lookahead", type=int, default=1,
                    help="graph-wide overlap window (0 = serial issue "
                         "order; default 1, the executor default)")
    ap.add_argument("--pp", type=int, default=0,
                    help="pipeline stages: adds a pp=<n> mesh axis and "
                         "runs the RA4xx pipeline pass (0 = off)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="microbatches for the pipeline pass (clamped to "
                         "1 for graphs whose rows couple across the "
                         "batch, e.g. MoE capacity routing)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write the full report to this path")
    ap.add_argument("--list-codes", action="store_true",
                    help="print the RA error-code index and exit")
    args = ap.parse_args(argv)

    if isinstance(args.mesh, str):
        args.mesh = _parse_mesh(args.mesh)

    if args.list_codes:
        for code, (sev, desc) in sorted(CODES.items()):
            print(f"{code}  {sev:7s}  {desc}")
        return 0

    fams = FAMILIES if args.families == "all" else \
        [f.strip() for f in args.families.split(",") if f.strip()]
    modes = MODES if args.modes == "all" else \
        [m.strip() for m in args.modes.split(",") if m.strip()]
    for m in modes:
        if m not in MODES:
            ap.error(f"unknown mode {m!r} (choose from {MODES})")

    reports = []
    n_errors = n_warnings = 0
    for family in fams:
        for mode in modes:
            if mode == "paged" and family not in PAGED_FAMILIES:
                continue
            prog = _cell_program(family, mode)
            mesh = dict(args.mesh)
            pipeline = None
            if args.pp:
                from repro.pipeline import PipelineSpec

                pipeline = PipelineSpec(stages=args.pp,
                                        microbatches=args.microbatches)
                mesh = {pipeline.axis: args.pp, **mesh}
            report = analyze_program(
                prog, mesh, max_hbm=args.max_hbm,
                fuse=not args.no_fuse, lookahead=args.lookahead,
                pipeline=pipeline,
                meta={"family": family, "mode": mode,
                      "mesh": ",".join(f"{k}={v}"
                                       for k, v in mesh.items())})
            reports.append(report)
            n_errors += len(report.errors)
            n_warnings += len(report.warnings)
            mem = report.memory.get("peak_bytes")
            peak = f" peak={mem:,}B/dev" if mem is not None else ""
            status = "OK" if not report.findings else \
                ("FAIL" if report.has_errors else "WARN")
            print(f"ANALYZE {family:14s} {mode:8s} "
                  f"mesh={report.meta['mesh']:18s} "
                  f"{len(report.errors)}E/{len(report.warnings)}W "
                  f"{status}{peak}", flush=True)
            for f in report.findings:
                print(f"    {f.format()}", flush=True)

    print(f"analyzed {len(reports)} cell(s): {n_errors} error(s), "
          f"{n_warnings} warning(s)", flush=True)
    if args.json_path:
        payload = {"mesh": args.mesh, "n_errors": n_errors,
                   "n_warnings": n_warnings,
                   "cells": [r.to_json() for r in reports]}
        with open(args.json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"report written to {args.json_path}", flush=True)
    return 1 if n_errors else 0


if __name__ == "__main__":
    sys.exit(main())
