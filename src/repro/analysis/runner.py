"""Run the analysis passes over a graph / plan / schedule — backend-free.

The entry points never build a jax array or touch a device: planning
(``decomp.eindecomp``) and schedule lowering (``spmd.build_schedule``) are
pure Python over static shapes (the discipline the existing
"planning never initializes the jax backend" subprocess test pins), so the
full pipeline — graph → plan → schedule → memory — runs on any host.

``analyze_compiled`` is the post-compile convenience: it re-analyzes what
a ``CompiledProgram`` is actually going to execute (its plan, mesh, and
donation set) and is what the launch/serving hooks call.
"""
from __future__ import annotations

import math
from typing import Sequence

from repro.core.decomp import Plan, eindecomp
from repro.core.einsum import EinGraph

from repro.analysis.findings import Finding, Report
from repro.analysis.graph_pass import analyze_graph
from repro.analysis.memory_pass import analyze_memory
from repro.analysis.plan_pass import analyze_plan
from repro.analysis.schedule_pass import analyze_schedule


def analyze(g: EinGraph, plan: Plan | None = None,
            mesh_axes: dict[str, int] | None = None,
            out_ids: Sequence[int] | None = None,
            donate: Sequence[str] = (), max_hbm: int | None = None,
            fuse: bool = True, lookahead: int = 1,
            meta: dict | None = None) -> Report:
    """All applicable passes over one cell.

    Graph pass always runs; the plan pass needs ``plan``; the schedule and
    memory passes need ``plan`` + ``mesh_axes`` (they analyze the exact
    static schedule ``build_schedule`` lowers for that pair — including
    the graph-wide ``lookahead`` prefetch hoisting, so the memory pass
    charges prefetch buffers exactly where the executor holds them).
    """
    report = Report(meta=dict(meta or {}))
    outs = list(out_ids) if out_ids is not None else g.outputs()
    report.extend(analyze_graph(g, outs))

    if plan is not None:
        report.extend(analyze_plan(g, plan, mesh_axes))

    if plan is not None and mesh_axes is not None:
        from repro.core.spmd import build_schedule

        try:
            sched = build_schedule(g, plan, dict(mesh_axes), outs, fuse=fuse,
                                   lookahead=lookahead)
        except Exception as e:  # broken plans fail lowering, not the CLI
            report.add(Finding(
                "RA203", f"schedule lowering failed: "
                         f"{type(e).__name__}: {e}"))
            return report
        report.extend(analyze_schedule(g, plan, sched, outs, donate))
        mem_findings, mem_report = analyze_memory(g, sched, outs, donate,
                                                  max_hbm)
        report.extend(mem_findings)
        report.memory = mem_report
    return report


def analyze_schedule_only(g: EinGraph, sched, out_ids=None,
                          donate: Sequence[str] = (),
                          max_hbm: int | None = None,
                          meta: dict | None = None) -> Report:
    """Schedule + memory passes over an already-built (possibly
    hand-constructed) Schedule — the corpus fixtures' entry point."""
    report = Report(meta=dict(meta or {}))
    outs = list(out_ids) if out_ids is not None else g.outputs()
    report.extend(analyze_schedule(g, None, sched, outs, donate))
    mem_findings, mem_report = analyze_memory(g, sched, outs, donate,
                                              max_hbm)
    report.extend(mem_findings)
    report.memory = mem_report
    return report


def analyze_program(program, mesh_axes: dict[str, int],
                    plan: Plan | None = None, donate: Sequence[str] = (),
                    max_hbm: int | None = None, fuse: bool = True,
                    lookahead: int = 1, meta: dict | None = None,
                    pipeline=None) -> Report:
    """Analyze a frontend ``Program`` under a mesh shape, planning with the
    §7 DP when no plan is supplied (both steps are backend-free).

    ``pipeline`` is a ``repro.pipeline.PipelineSpec``: the pipeline pass
    (RA4xx) builds the static ``PipelineSchedule`` against ``mesh_axes``
    (which must carry the pipeline axis at size ``stages``) and verifies
    the stage chain, handoff ordering, per-stage memory, and balance on
    top of the ordinary four passes — still fully backend-free.  A spec
    whose microbatches the graph cannot support (rows coupled across the
    batch label, e.g. MoE capacity routing) is clamped to microbatches=1
    and noted in the report meta."""
    g = program.graph
    out_ids = [program._out[k] for k in program._out]
    if pipeline is not None:
        return _analyze_pipelined(program, g, out_ids, dict(mesh_axes),
                                  pipeline, donate, max_hbm, fuse,
                                  lookahead, meta)
    if plan is None:
        p = math.prod(int(s) for s in mesh_axes.values()) if mesh_axes else 1
        plan = eindecomp(g, p, mesh_axes=dict(mesh_axes))
    return analyze(g, plan, dict(mesh_axes), out_ids, donate, max_hbm,
                   fuse, lookahead, meta)


def _analyze_pipelined(program, g, out_ids, mesh_axes, pipeline, donate,
                       max_hbm, fuse, lookahead, meta) -> Report:
    import dataclasses

    from repro.pipeline import (batch_splittable, build_pipeline_schedule)

    from repro.analysis.pipeline_pass import analyze_pipeline_schedule

    meta = dict(meta or {})
    spec = pipeline
    if spec.microbatches > 1 and not batch_splittable(g, spec.batch_label):
        spec = dataclasses.replace(spec, microbatches=1)
        meta["microbatches_clamped"] = 1
    # offpath_repart=False mirrors the plain path's eindecomp default —
    # the stitched plan is the bit-identity baseline an unpipelined
    # compile of the same cell would run
    psched = build_pipeline_schedule(g, spec, mesh_axes, out_ids,
                                     offpath_repart=False,
                                     fuse=fuse, lookahead=lookahead)
    # graph + plan passes analyze the stitched full-graph plan (the
    # bit-identity baseline the pipeline realizes); the schedule- and
    # memory-level checks run PER STAGE inside the pipeline pass (RA402 /
    # RA403 / RA405) — the pipelined executor never runs the whole-graph
    # schedule, and RA206's whole-graph convention is a statement about
    # DP-produced plans that a per-stage-optimal stitched plan does not
    # satisfy (the sound bound is the per-stage price, RA405)
    report = analyze(g, psched.stitched, None, out_ids, donate, max_hbm,
                     fuse, lookahead, meta)
    report.extend(analyze_pipeline_schedule(g, psched, max_hbm))
    # memory meta: the worst stage's per-device peak — each stage must fit
    peaks = []
    for st in psched.stages:
        if st.sched is None:
            continue
        louts = [st.lid_of[gn] for gn in st.out_gids]
        _, mem = analyze_memory(st.graph, st.sched, louts, (), None)
        peaks.append(mem)
    if peaks:
        report.memory = max(peaks, key=lambda m: m.get("peak_bytes", 0))
    report.meta.setdefault("pipeline", f"p={spec.stages},m="
                                       f"{spec.microbatches}")
    report.meta["bubble"] = round(psched.bubble, 4)
    return report


def analyze_compiled(compiled, max_hbm: int | None = None,
                     meta: dict | None = None,
                     mesh_axes: dict[str, int] | None = None) -> Report:
    """Re-verify what a ``CompiledProgram`` will execute: its own plan,
    mesh, and donation set (the launch / serving hooks' surface).

    ``mesh_axes`` is only needed for programs compiled with
    ``mesh_axes=`` but no jax ``Mesh`` (the gspmd executor): the plan is
    mesh-mode but the compiled object has no mesh to read sizes from."""
    from repro.core.engine import mesh_axes_dict

    program = compiled.program
    if mesh_axes is None and compiled.mesh is not None:
        mesh_axes = mesh_axes_dict(compiled.mesh)
    donate = tuple(compiled._in_names[i] for i in compiled.donate_argnums)
    g = program.graph
    out_ids = [program._out[k] for k in program._out]
    return analyze(g, compiled.plan, mesh_axes, out_ids, donate, max_hbm,
                   fuse=True, lookahead=getattr(compiled, "lookahead", 1),
                   meta=meta)
