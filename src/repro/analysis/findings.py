"""Finding/Report data model + the RA error-code index.

Ruff-style codes, one namespace per pass:

  RA0xx  graph     (labels, bounds, dtypes, OpDef conformance)
  RA1xx  plan      (divisibility, mesh axes, shard rules, §7 cost)
  RA2xx  schedule  (ppermute bijectivity, donation aliasing, chains)
  RA3xx  memory    (per-device peak live bytes vs --max-hbm)
  RA4xx  pipeline  (stage chain, handoff ordering, per-stage memory)

Every finding carries the node id/name and — for frontend-traced graphs —
the ``file.py:line`` that built the node (``Node.srcloc``), so reports are
clickable back to the model source.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Iterable

ERROR = "error"
WARNING = "warning"

#: code -> (default severity, one-line description) — the `--list-codes`
#: table and the docs' error-code index are generated from this.
CODES: dict[str, tuple[str, str]] = {
    # graph pass ----------------------------------------------------------
    "RA001": (WARNING, "dead node: not reachable from any requested output"),
    "RA002": (ERROR, "label/rank arity mismatch between a node and its "
                     "labels or an edge's labels"),
    "RA003": (ERROR, "label bound mismatch across edges (same label, "
                     "different sizes)"),
    "RA004": (ERROR, "opaque node contradicts its registered OpDef "
                     "signature (bind_call fails or infers another shape)"),
    "RA005": (ERROR, "unregistered map/opaque kind: execution has no impl "
                     "to dispatch"),
    "RA006": (WARNING, "dtype drift: einsum combines floats of different "
                       "widths (result silently takes the first input's)"),
    "RA007": (ERROR, "duplicate input name: feeds are name-keyed and "
                     "would be ambiguous"),
    "RA008": (ERROR, "spec arity mismatch: node input count differs from "
                     "its spec/in_labels"),
    # plan pass -----------------------------------------------------------
    "RA101": (ERROR, "node missing from the plan (no partitioning entry)"),
    "RA102": (ERROR, "partitioning does not divide the label bound"),
    "RA103": (ERROR, "over-parallel: product of parts exceeds the plan's "
                     "device count p"),
    "RA104": (ERROR, "mesh-axis inconsistency: unknown axis, axis-size "
                     "product != parts, or one axis on two labels"),
    "RA105": (ERROR, "unresolvable shard rule or unknown comm kind on an "
                     "opaque node's OpDef"),
    "RA106": (ERROR, "comm template inconsistent with the node (label not "
                     "on the node, input index out of range)"),
    "RA107": (ERROR, "stale plan cost: plan.cost != plan_cost(g, plan) — "
                     "the plan was edited after pricing"),
    "RA108": (ERROR, "non-shardable label is partitioned (outside the "
                     "opaque node's declared shardable set)"),
    # schedule pass -------------------------------------------------------
    "RA201": (ERROR, "non-bijective ppermute: deadlock (missing source) "
                     "or data loss (duplicate destination)"),
    "RA202": (ERROR, "donated buffer read after its aliasing step (or "
                     "returned as a program output)"),
    "RA203": (ERROR, "repartition chain breaks shape evolution (a step "
                     "does not divide / lowering failed)"),
    "RA204": (ERROR, "overlap hazard: overlapped collective outside any "
                     "rule's compute loop, or an over-rotated ring"),
    "RA205": (ERROR, "opaque node's traced wire elems exceed its "
                     "_opaque_comm_cost planner bound"),
    "RA206": (ERROR, "program's traced wire elems exceed the §7 "
                     "plan_cost the DP optimized"),
    "RA207": (WARNING, "dead donation: donated input is never read"),
    "RA208": (ERROR, "prefetch hazard: hoisted issue precedes a producer's "
                     "compute, aliases another prefetch's buffer, or is "
                     "unrecorded in the schedule's lifetimes"),
    # memory pass ---------------------------------------------------------
    "RA301": (ERROR, "peak per-device live bytes exceed --max-hbm"),
    "RA302": (ERROR, "a single buffer alone exceeds --max-hbm"),
    # pipeline pass -------------------------------------------------------
    "RA401": (ERROR, "stage-graph back-edge: a stage receives a tensor "
                     "produced by the same or a later stage (not a chain)"),
    "RA402": (ERROR, "premature handoff: a cell's ppermute fires before "
                     "the producing (stage, microbatch) cell completes"),
    "RA403": (ERROR, "a single stage's peak live bytes exceed --max-hbm"),
    "RA404": (WARNING, "stage compute imbalance beyond the partitioner's "
                       "balance cap (bubble fraction understated)"),
    "RA405": (ERROR, "a stage schedule's traced wire exceeds the sound "
                     "per-stage §7 price (per-stage analogue of RA206)"),
}


@dataclass(frozen=True)
class Finding:
    """One diagnostic: code + where + why."""

    code: str
    message: str
    severity: str = ""        # "" = the code's default severity
    nid: int | None = None
    node: str = ""            # node name, when node-scoped
    srcloc: str = ""          # "file.py:line" from the frontend trace

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unknown finding code {self.code!r}")
        if not self.severity:
            object.__setattr__(self, "severity", CODES[self.code][0])

    def format(self) -> str:
        where = self.srcloc or (f"node {self.nid}" if self.nid is not None
                                else "")
        name = f" ({self.node})" if self.node else ""
        loc = f"{where}{name}: " if (where or name) else ""
        return f"{loc}{self.code} [{self.severity}] {self.message}"

    def to_json(self) -> dict:
        return {"code": self.code, "severity": self.severity,
                "message": self.message, "nid": self.nid,
                "node": self.node, "srcloc": self.srcloc}


@dataclass
class Report:
    """All findings for one analyzed cell (graph [+ plan [+ schedule +
    memory]]), plus the memory pass's per-device accounting."""

    findings: list[Finding] = field(default_factory=list)
    meta: dict = field(default_factory=dict)      # family/mode/mesh/...
    memory: dict = field(default_factory=dict)    # memory_pass report

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == ERROR]

    @property
    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == WARNING]

    @property
    def has_errors(self) -> bool:
        return bool(self.errors)

    def codes(self) -> set[str]:
        return {f.code for f in self.findings}

    def format(self) -> str:
        head = " ".join(f"{k}={v}" for k, v in self.meta.items())
        lines = [head] if head else []
        lines += [f.format() for f in self.findings]
        lines.append(f"{len(self.errors)} error(s), "
                     f"{len(self.warnings)} warning(s)")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {"meta": self.meta,
                "findings": [f.to_json() for f in self.findings],
                "memory": self.memory,
                "n_errors": len(self.errors),
                "n_warnings": len(self.warnings)}

    def dump_json(self) -> str:
        return json.dumps(self.to_json(), indent=2, sort_keys=True)
