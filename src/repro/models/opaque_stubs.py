"""Deterministic, shape-correct stand-ins for opaque kinds that are
*declared* (core/opdefs_builtin.py: signature, comm, shard rule) but ship
no production engine implementation (MoE dispatch/combine, recurrent
scans).

Shared by the executor-equivalence tests and ``benchmarks/bench_spmd.py``:
those suites pin that two execution paths realize the *same dataflow*, not
the fused ops' numerics (which live with the real model stack in
``tests/test_models_smoke.py``).  One definition, so the test suite and the
benchmark cannot silently validate different semantics.

The MoE pair implements real (deterministic, top-1, capacity-dropped)
token routing through ``core.opaque_rules.moe_route`` — the *same* helper
the expert-parallel ``a2a`` shard rule builds its all_to_all program from.
Dispatch places each kept token's raw activation at its global ``(expert,
slot)``; combine gathers it back gate-weighted (dropped tokens contribute
0).  That shared routing is what makes the dense replicated path and the
sharded a2a path agree to fp tolerance.

``make_stub_opaques`` registers through the unified OpDef path
(``opdef.provide_impl``), which cross-validates each impl's output shape
against the declared signature at registration time; the returned dict
additionally supports the historical ``monkeypatch.setitem`` idiom.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.core.opaque_rules import moe_route


def capacity_of(g) -> int:
    """Expert capacity of the graph's MoE dispatch node (0 if none)."""
    disp = [n for n in g.nodes if n.op == "moe_dispatch"]
    return disp[0].shape[1] if disp else 0


def make_stub_opaques(capacity: int = 0, *,
                      register: bool = True) -> dict[str, Callable]:
    """{opaque kind: deterministic stand-in} (``capacity`` from
    ``capacity_of`` is the default when a dispatch node carries no
    ``capacity`` param of its own — OpDef-built graphs always do).

    With ``register`` (default) the impls are attached to their declared
    OpDefs via ``opdef.provide_impl`` — signature-checked, visible to every
    execution surface at once.  The returned dict remains usable with the
    historical monkeypatch-an-impl test idiom.
    """

    def cumnorm(h):
        h = jnp.asarray(h)
        t = jnp.arange(1, h.shape[1] + 1, dtype=h.dtype)[None, :, None]
        return jnp.cumsum(h, axis=1) / t

    def dispatch(x, route, capacity=capacity):
        x = jnp.asarray(x)
        b, s, d = x.shape
        n_e = route.shape[-1]
        expert, pos, _gate, _cnt = moe_route(route)
        keep = pos < capacity
        xt = jnp.swapaxes(x, 0, 1).reshape(s * b, d)
        e_idx = jnp.where(keep, expert, 0)
        c_idx = jnp.where(keep, pos, 0)
        out = jnp.zeros((n_e, capacity, d), x.dtype)
        return out.at[e_idx, c_idx].add(xt * keep[:, None].astype(x.dtype))

    def combine(y, route):
        y = jnp.asarray(y)
        _, cap, d = y.shape
        b, s, _ = route.shape
        expert, pos, gate, _cnt = moe_route(route)
        keep = pos < cap
        vals = y[jnp.where(keep, expert, 0), jnp.where(keep, pos, 0)]
        vals = vals * (gate * keep).astype(y.dtype)[:, None]
        return jnp.swapaxes(vals.reshape(s, b, d), 0, 1)

    fns = {"ssm_scan": cumnorm, "mlstm_scan": cumnorm, "slstm_scan": cumnorm,
           "moe_dispatch": dispatch, "moe_combine": combine}
    if register:
        from repro.core import opdef

        for kind, fn in fns.items():
            opdef.provide_impl(kind, fn)
    return fns
