"""Deterministic, shape-correct stand-ins for opaque kinds that have no
production engine implementation (MoE dispatch/combine, recurrent scans).

Shared by the executor-equivalence tests and ``benchmarks/bench_spmd.py``:
those suites pin that two execution paths realize the *same dataflow*, not
the fused ops' numerics (which live with the real model stack in
``tests/test_models_smoke.py``).  One definition, so the test suite and the
benchmark cannot silently validate different semantics.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def capacity_of(g) -> int:
    """Expert capacity of the graph's MoE dispatch node (0 if none)."""
    disp = [n for n in g.nodes if n.op == "moe_dispatch"]
    return disp[0].shape[1] if disp else 0


def make_stub_opaques(capacity: int = 0) -> dict[str, Callable]:
    """{opaque kind: deterministic stand-in} for one graph (``capacity``
    from ``capacity_of``).  Register via ``engine.register_opaque`` or
    ``monkeypatch.setitem(engine.OPAQUE_FNS, ...)``."""

    def cumnorm(h):
        h = jnp.asarray(h)
        t = jnp.arange(1, h.shape[1] + 1, dtype=h.dtype)[None, :, None]
        return jnp.cumsum(h, axis=1) / t

    def dispatch(x, route):
        w = jax.nn.softmax(jnp.asarray(route), axis=-1)        # (b, s, e)
        pooled = jnp.einsum("bsa,bse->ea", jnp.asarray(x), w)  # (e, a)
        e = route.shape[-1]
        return jnp.broadcast_to(pooled[:, None, :],
                                (e, capacity, x.shape[-1])) / capacity

    def combine(y, route):
        w = jax.nn.softmax(jnp.asarray(route), axis=-1)
        return jnp.einsum("eca,bse->bsa", jnp.asarray(y), w) / y.shape[1]

    return {"ssm_scan": cumnorm, "mlstm_scan": cumnorm, "slstm_scan": cumnorm,
            "moe_dispatch": dispatch, "moe_combine": combine}
