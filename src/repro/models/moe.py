"""Mixture-of-Experts FFN: top-k routing, capacity-bounded sort dispatch,
grouped expert matmuls, optional always-on shared experts.

Two formulations exist in the codebase (DESIGN.md §8, deviation 4):
  * the *relational* one — dense one-hot dispatch einsums — lives in the
    EinGraph builders (models/eingraphs.py) and the TRA tests, because it is
    the faithful paper-style declarative spec;
  * this module is the production lowering: tokens are sorted by expert,
    scattered into capacity buffers (GShard layout), experts run as one
    grouped matmul (Pallas kernel on TPU), results gathered back.

Dispatch modes (EXPERIMENTS.md §Perf, mixtral cell):
  * global (moe_groups<=1): one capacity region per expert.  The scatter's
    destination device depends on runtime indices, which GSPMD cannot
    prove local -> it materializes replicated buffers (measured: ~20x
    compute + ~100x collective blowup at 1M tokens).
  * group-local (moe_groups=G): tokens are split into G structural groups
    (a leading vmapped dim aligned with the data axis) with per-(group,
    expert) capacity.  Scatters are batched per group, buffers carry the
    group dim sharded like batch, and all dispatch movement is local.

The expert label e is a first-class EinSum label: EinDecomp assigns a mesh
axis to it and the gmm's expert dim shards — that *is* expert parallelism.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.common import ParamFactory, activation
from repro.models import ffn as ffn_mod


def init_moe(pf: ParamFactory, cfg) -> dict:
    D, E, F = cfg.d_model, cfg.n_e, cfg.d_ff
    p = {
        "router": pf.dense(D, E),
        "w1": pf.dense(E, D, F),
        "w2": pf.dense(E, F, D),
    }
    if cfg.gated_ffn:
        p["w3"] = pf.dense(E, D, F)
    if cfg.shared_expert_ff:
        p["shared"] = ffn_mod.init_ffn(pf, cfg, d_ff=cfg.shared_expert_ff)
    return p


def _capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_e * cfg.capacity_factor)
    return max(128, -(-c // 128) * 128)  # round up to kernel block


def _route(p, xt, cfg):
    """xt (..., T, D) -> (top weights, top experts, aux loss)."""
    E = cfg.n_e
    logits = jnp.einsum("...td,de->...te", xt, p["router"]).astype(jnp.float32)
    if cfg.n_experts < E:  # padded dispatch slots never win routing
        logits = logits + jnp.where(jnp.arange(E) < cfg.n_experts, 0.0, -1e30)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(gates, cfg.top_k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(gates.reshape(-1, E), axis=0)
    ce = jnp.mean(jax.nn.one_hot(tope.reshape(-1), E, dtype=jnp.float32),
                  axis=0)
    aux = E * jnp.sum(me * ce)
    return topw, tope, aux


def _dispatch_compute_combine(p, xt, topw, tope, C, cfg):
    """One dispatch group: xt (T, D) -> (T, D).  Used directly (global) or
    under vmap (group-local)."""
    T, D = xt.shape
    E, K = cfg.n_e, cfg.top_k
    e_flat = tope.reshape(-1)                                    # (T*K,)
    t_flat = jnp.repeat(jnp.arange(T), K)
    w_flat = topw.reshape(-1).astype(xt.dtype)

    order = jnp.argsort(e_flat)                                  # stable
    e_sorted = e_flat[order]
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(T * K) - starts[e_sorted]
    rank = jnp.zeros((T * K,), jnp.int32).at[order].set(rank_sorted)

    keep = rank < C
    slot = jnp.where(keep, e_flat * C + rank, E * C)             # overflow slot
    buf = jnp.zeros((E * C + 1, D), xt.dtype).at[slot].set(xt[t_flat])
    buf = buf[: E * C].reshape(E, C, D)

    act = activation(cfg.act)
    h = ops.gmm(buf, p["w1"])                                    # (E, C, F)
    if cfg.gated_ffn:
        h = act(h) * ops.gmm(buf, p["w3"])
    else:
        h = act(h)
    y = ops.gmm(h, p["w2"])                                      # (E, C, D)

    y_flat = y.reshape(E * C, D)
    gathered = jnp.where(keep[:, None],
                         y_flat[jnp.minimum(slot, E * C - 1)], 0)
    return jnp.zeros((T, D), xt.dtype).at[t_flat].add(
        gathered * w_flat[:, None])


def moe_ffn(p: dict, x: jnp.ndarray, cfg, *, policy=None, mesh=None
            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, s, d) -> (out, aux_loss)."""
    b, s, D = x.shape
    T = b * s

    def cst(t, labels):
        if policy is None or mesh is None:
            return t
        return jax.lax.with_sharding_constraint(
            t, policy.sharding(mesh, labels, t.shape))

    G = max(1, cfg.moe_groups)
    if G > 1 and b % G == 0:
        # ---- group-local: G is a structural batch dim, kept sharded like b
        # through every stage via explicit constraints (GSPMD replicates
        # batched scatters otherwise — measured 16x compute blowup).
        E, K = cfg.n_e, cfg.top_k
        Tg = T // G
        xg = cst(x.reshape(G, Tg, D), "b s a")
        topw, tope, aux = _route(p, xg, cfg)
        C = _capacity(Tg, cfg)

        e_flat = tope.reshape(G, Tg * K)
        t_flat = jnp.repeat(jnp.arange(Tg), K)                  # shared
        w_flat = topw.reshape(G, Tg * K).astype(x.dtype)
        gix = jnp.arange(G)[:, None]

        order = jnp.argsort(e_flat, axis=-1)
        e_sorted = jnp.take_along_axis(e_flat, order, axis=-1)
        counts = jnp.sum(jax.nn.one_hot(e_flat, E, dtype=jnp.int32), axis=1)
        starts = jnp.cumsum(counts, axis=-1) - counts           # (G, E)
        rank_sorted = (jnp.arange(Tg * K)[None]
                       - jnp.take_along_axis(starts, e_sorted, axis=-1))
        rank = jnp.zeros((G, Tg * K), jnp.int32).at[gix, order].set(rank_sorted)

        keep = rank < C
        slot = jnp.where(keep, e_flat * C + rank, E * C)
        buf = jnp.zeros((G, E * C + 1, D), x.dtype).at[gix, slot].set(
            xg[gix, t_flat[None]])
        buf = cst(buf[:, : E * C], "b c a").reshape(G, E, C, D)

        act = activation(cfg.act)
        h = jnp.einsum("geca,eaf->gecf", buf, p["w1"])
        if cfg.gated_ffn:
            h = act(h) * jnp.einsum("geca,eaf->gecf", buf, p["w3"])
        else:
            h = act(h)
        h = cst(h, "b e c f")
        y = cst(jnp.einsum("gecf,efa->geca", h, p["w2"]), "b e c a")

        y_flat = y.reshape(G, E * C, D)
        gathered = jnp.where(keep[..., None],
                             y_flat[gix, jnp.minimum(slot, E * C - 1)], 0)
        out = jnp.zeros((G, Tg, D), x.dtype).at[gix, t_flat[None]].add(
            gathered * w_flat[..., None])
        out = cst(out, "b s a").reshape(b, s, D)
    else:
        xt = x.reshape(T, D)
        topw, tope, aux = _route(p, xt, cfg)
        C = _capacity(T, cfg)
        out = _dispatch_compute_combine(p, xt, topw, tope, C, cfg)
        out = out.reshape(b, s, D)
        out = cst(out, "b s a")

    if cfg.shared_expert_ff:
        out = out + ffn_mod.ffn(p["shared"], x, cfg)
    return out, aux
