"""Declarative model builders for every architecture family + ``program_for``.

This is where the paper's technique becomes a first-class feature of the
framework: each model family's layer (plus embedding and LM head) is
declared with the symbolic frontend (``repro.frontend``) as extended-einsum
expressions over canonical labels

    b batch  s sequence  t cache-time  a d_model  h q-heads  k kv-heads
    d head_dim  f ffn-hidden  g 2x-expansion  v vocab  e experts  c capacity

``program_for`` wraps one (arch x shape) cell as a ``Program`` with named
inputs and a named ``logits`` output; ``Program.compile`` runs EinDecomp
(through the plan cache) and ``CompiledProgram.policy()`` collapses the plan
to the ShardingPolicy the production model stack applies via GSPMD.  Fused
ops (flash attention, MoE dispatch, recurrent scans) are opaque
expressions whose whole declaration — label signature, shardable set, the
internal-communication (``comm``) template the DP prices as ring /
all-to-all traffic, the bound shard rule — lives on their registered
OpDef (core/opdefs_builtin.py); the builders below only pass arguments
and, where a signature label is renamed per instance, ``in_labels``
(DESIGN.md §2 adaptation 3, §4 arch-applicability).

``build_graph`` / ``plan_for`` remain as thin shims over the Program
surface for callers written against the original imperative API.
"""
from __future__ import annotations

import functools

from repro import frontend as ein
from repro.core.decomp import Plan
from repro.core.einsum import EinGraph
from repro.frontend import Program
from repro.models.policy import ShardingPolicy, policy_from_plan


# ---------------------------------------------------------------------------
# Fragments (symbolic expressions; x is the running "b s a" activation)
# ---------------------------------------------------------------------------


def _attention_nodes(x: ein.Expr, cfg, B: int, S: int, *,
                     decode: bool = False, kv_len: int = 0,
                     kv_block: int = 0) -> ein.Expr:
    """q/k/v are declared in the kernel's (batch, heads, seq, head_dim)
    layout, so the opaque node's sequence label *is* the kernel's sequence
    axis — what the ring shard rule rotates K/V blocks over.  Everything
    else (output shape/labels, shardable set, the ring comm declaration the
    DP prices, the bound shard rule) comes from the ``flash_attention``
    OpDef; the per-call ``in_labels`` only rename its ring label ``l`` to
    this instance's label — ``s`` in prefill (shared with q), the
    kv-cache-time ``t`` in decode."""
    H, K, hd, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
    wq = ein.tensor("wq", "a h d", (D, H, hd))
    q = ein.einsum("b s a, a h d -> b h s d", x, wq, name="q_proj")
    if decode:
        if kv_block:
            # paged serving-tier decode: the cache arrives as a block pool
            # plus per-sequence block tables, and the time-ordered (b k t d)
            # view is a kv_block_gather node — an OpDef like any other, so
            # the DP prices the lookup and the shard_map executor lowers it
            # through the ``paged`` rule (zero-collective local gather).
            W = -(-kv_len // kv_block)
            t_len = W * kv_block
            kp = ein.tensor("kv_pool_k", "n p k d",
                            (B * W + 1, kv_block, K, hd))
            vp = ein.tensor("kv_pool_v", "n p k d",
                            (B * W + 1, kv_block, K, hd))
            tb = ein.tensor("block_tables", "b w", (B, W), dtype="int32")
            kc = ein.opaque("kv_block_gather", [kp, tb], name="kv_gather_k",
                            kv_len=t_len)
            vc = ein.opaque("kv_block_gather", [vp, tb], name="kv_gather_v",
                            kv_len=t_len)
        else:
            kc = ein.tensor("k_cache", "b k t d", (B, K, kv_len, hd))
            vc = ein.tensor("v_cache", "b k t d", (B, K, kv_len, hd))
        att = ein.opaque(
            "flash_attention", [q, kc, vc],
            in_labels=[("b", "h", "s", "d"), ("b", "k", "t", "d"),
                       ("b", "k", "t", "d")],
            name="attn")
    else:
        wk = ein.tensor("wk", "a k d", (D, K, hd))
        wv = ein.tensor("wv", "a k d", (D, K, hd))
        kk = ein.einsum("b s a, a k d -> b k s d", x, wk, name="k_proj")
        vv = ein.einsum("b s a, a k d -> b k s d", x, wv, name="v_proj")
        att = ein.opaque(
            "flash_attention", [q, kk, vv],
            in_labels=[("b", "h", "s", "d"), ("b", "k", "s", "d"),
                       ("b", "k", "s", "d")],
            name="attn")
    wo = ein.tensor("wo", "h d a", (H, hd, D))
    return ein.einsum("b h s d, h d a -> b s a", att, wo, name="o_proj")


def _ffn_nodes(x: ein.Expr, cfg, B: int, S: int,
               d_ff: int | None = None) -> ein.Expr:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    w1 = ein.tensor("w1", "a f", (D, F))
    h = ein.einsum("b s a, a f -> b s f", x, w1, name="ffn_up")
    h = h.map(cfg.act if cfg.act in ("silu", "gelu", "relu2") else "silu")
    if cfg.gated_ffn:
        w3 = ein.tensor("w3", "a f", (D, F))
        hg = ein.einsum("b s a, a f -> b s f", x, w3, name="ffn_gate")
        h = ein.einsum("b s f, b s f -> b s f", h, hg, combine="mul", agg="",
                       name="ffn_mul")
    w2 = ein.tensor("w2", "f a", (F, D))
    return ein.einsum("b s f, f a -> b s a", h, w2, name="ffn_down")


def _moe_nodes(x: ein.Expr, cfg, B: int, S: int) -> ein.Expr:
    D, E, F = cfg.d_model, cfg.n_e, cfg.d_ff
    T = B * S
    C = max(128, -(-int(T * cfg.top_k / E * cfg.capacity_factor) // 128) * 128)
    wr = ein.tensor("router_w", "a e", (D, E))
    route = ein.einsum("b s a, a e -> b s e", x, wr, name="router")
    # the capacity param binds the output-only label c (OpDef param_bounds);
    # shardable set + a2a comm declaration + shard rule come from the OpDef
    disp = ein.opaque("moe_dispatch", [x, route], name="dispatch",
                      capacity=C)
    we1 = ein.tensor("we1", "e a f", (E, D, F))
    h = ein.einsum("e c a, e a f -> e c f", disp, we1, name="expert_up")
    h = h.map(cfg.act if cfg.act in ("silu", "gelu", "relu2") else "silu")
    if cfg.gated_ffn:
        we3 = ein.tensor("we3", "e a f", (E, D, F))
        hg = ein.einsum("e c a, e a f -> e c f", disp, we3, name="expert_gate")
        h = ein.einsum("e c f, e c f -> e c f", h, hg, combine="mul", agg="",
                       name="expert_mul")
    we2 = ein.tensor("we2", "e f a", (E, F, D))
    y = ein.einsum("e c f, e f a -> e c a", h, we2, name="expert_down")
    comb = ein.opaque("moe_combine", [y, route], name="combine")
    if cfg.shared_expert_ff:
        sh = _ffn_nodes(x, cfg, B, S, d_ff=cfg.shared_expert_ff)
        comb = ein.einsum("b s a, b s a -> b s a", comb, sh, combine="add",
                          agg="", name="moe_add_shared")
    return comb


def _recurrent_nodes(x: ein.Expr, cfg, B: int, S: int, kind: str) -> ein.Expr:
    """mLSTM / sLSTM / SSM path as proj -> opaque scan -> proj.

    The scan's sequence label is non-partitionable (the scan OpDefs'
    shardable sets exclude s) — the brief's arch-applicability caveat for
    recurrence.  mLSTM/SSM channel labels stay shardable (chunkwise forms
    are channel-local) and the OpDefs bind the ``local`` shard rule, so the
    shard_map executor runs a local scan per channel shard with zero
    collectives; sLSTM's dense recurrent matrix couples the whole width,
    so only b shards.
    """
    D = cfg.d_model
    F = 2 * D
    win = ein.tensor(f"{kind}_in", "a f", (D, F))
    h = ein.einsum("b s a, a f -> b s f", x, win, name=f"{kind}_up")
    scan = ein.opaque(f"{kind}_scan", [h], name=f"{kind}_scan")
    wdn = ein.tensor(f"{kind}_down", "f a", (F, D))
    return ein.einsum("b s f, f a -> b s a", scan, wdn, name=f"{kind}_down_proj")


# ---------------------------------------------------------------------------
# Whole-model declaration
# ---------------------------------------------------------------------------


def build_expr(cfg, shape, *, mode: str | None = None,
               kv_block: int = 0) -> ein.Expr:
    """Embedding -> one block period -> LM head, at the cell's (B, S),
    declared as one symbolic expression (the logits).

    One period is enough: scan reuses the same plan for every unit (the
    per-layer graphs are isomorphic), which is also why the DP stays fast.

    ``kv_block`` > 0 declares the decode KV cache as a *paged* block pool +
    block tables feeding ``kv_block_gather`` nodes (the serving tier's
    cache; block size ``kv_block``) instead of dense (b k t d) inputs.
    """
    mode = mode or ("decode" if shape.kind == "decode" else shape.kind)
    B = shape.batch
    S = 1 if mode == "decode" else shape.seq
    D, V = cfg.d_model, cfg.vocab_padded
    kv_len = 0
    if mode == "decode":
        # paged caches are time-ordered (window masking happens at the
        # attend), so their span is the full sequence, not the ring window
        kv_len = shape.seq if kv_block else cfg.kv_len(shape)

    ids = ein.tensor("ids", "b s", (B, S), dtype="int32")
    table = ein.tensor("embed", "v a", (V, D))
    x = ein.opaque("gather_rows", [table, ids], name="embed_lookup")

    for blk in cfg.block_pattern:
        if blk == "attn":
            a = _attention_nodes(x, cfg, B, S, decode=(mode == "decode"),
                                 kv_len=kv_len, kv_block=kv_block)
            x = ein.einsum("b s a, b s a -> b s a", x, a, combine="add",
                           agg="", name="resid_attn")
            m = (_moe_nodes(x, cfg, B, S) if cfg.moe
                 else _ffn_nodes(x, cfg, B, S))
            x = ein.einsum("b s a, b s a -> b s a", x, m, combine="add",
                           agg="", name="resid_ffn")
        elif blk == "hymba":
            a = _attention_nodes(x, cfg, B, S, decode=(mode == "decode"),
                                 kv_len=kv_len, kv_block=kv_block)
            sm = _recurrent_nodes(x, cfg, B, S, "ssm")
            mix = ein.einsum("b s a, b s a -> b s a", a, sm, combine="add",
                             agg="", name="hymba_mix")
            x = ein.einsum("b s a, b s a -> b s a", x, mix, combine="add",
                           agg="", name="resid_mix")
            f = _ffn_nodes(x, cfg, B, S)
            x = ein.einsum("b s a, b s a -> b s a", x, f, combine="add",
                           agg="", name="resid_ffn")
        elif blk in ("mlstm", "slstm"):
            r = _recurrent_nodes(x, cfg, B, S, blk)
            x = ein.einsum("b s a, b s a -> b s a", x, r, combine="add",
                           agg="", name=f"resid_{blk}")
        else:
            raise ValueError(blk)

    head = ein.tensor("head", "a v", (D, V))
    return ein.einsum("b s a, a v -> b s v", x, head, name="lm_head")


def _build_program(cfg, shape, *, mode: str | None = None,
                   kv_block: int = 0) -> Program:
    mode_str = mode or ("decode" if shape.kind == "decode" else shape.kind)
    logits = build_expr(cfg, shape, mode=mode, kv_block=kv_block)
    paged = f":paged{kv_block}" if kv_block else ""
    return Program({"logits": logits},
                   name=f"{cfg.name}:{shape.name}:{mode_str}{paged}")


@functools.lru_cache(maxsize=None)
def _program_cached(cfg, shape, kv_block: int = 0) -> Program:
    return _build_program(cfg, shape, kv_block=kv_block)


def program_for(cfg, shape, *, mode: str | None = None,
                kv_block: int = 0) -> Program:
    """The declarative surface for one (arch x shape) cell: a ``Program``
    with name-keyed inputs and a ``logits`` output.  Memoized per (cfg,
    shape, kv_block) for the default mode — programs (and their traced
    graphs) are immutable after construction.  ``kv_block`` > 0 declares
    the decode KV cache as a paged block pool (see ``build_expr``)."""
    if mode is None:
        return _program_cached(cfg, shape, kv_block)
    return _build_program(cfg, shape, mode=mode, kv_block=kv_block)


def fsdp_axes_for(mesh_axes: dict[str, int]) -> tuple[str, ...]:
    """The data-parallel mesh axes ZeRO-style parameter sharding lands on
    (train shapes; beyond-paper §Perf lever)."""
    return tuple(a for a in ("pod", "data") if a in mesh_axes)


# ---------------------------------------------------------------------------
# Deprecation shims: the original imperative entry points
# ---------------------------------------------------------------------------


def build_graph(cfg, shape, *, mode: str | None = None) -> EinGraph:
    """Deprecated shim: the traced ``EinGraph`` of ``program_for(cfg,
    shape)`` — identical node-for-node to what the original imperative
    builder produced (tests/test_program_equivalence.py pins this)."""
    return program_for(cfg, shape, mode=mode).graph


@functools.lru_cache(maxsize=None)
def _plan_cached(cfg, shape, mesh_key: tuple, offpath_repart: bool):
    prog = _program_cached(cfg, shape)
    compiled = prog.compile(mesh_axes=dict(mesh_key),
                            offpath_repart=offpath_repart)
    return prog.graph, compiled.plan


def plan_for(cfg, shape, mesh_axes: dict[str, int], *,
             fsdp: bool = False, offpath_repart: bool = True,
             cache=None) -> tuple[EinGraph, Plan, ShardingPolicy]:
    """Deprecated shim over ``program_for(cfg, shape).compile(...)``: run
    EinDecomp for one (arch x shape x mesh) cell and derive the production
    ShardingPolicy.  ``fsdp`` additionally ZeRO-shards params over the data
    axes (train shapes; beyond-paper §Perf lever).

    ``cache`` is an optional ``core.plancache.PlanCache``; when given it
    replaces the process-local lru memo, which means plans survive process
    restarts (disk-backed caches) and transfer across isomorphic graphs —
    e.g. two archs whose block graphs coincide structurally plan once.
    New code should hold the ``CompiledProgram`` instead:

        compiled = program_for(cfg, shape).compile(mesh_axes=axes, cache=...)
        plan, policy = compiled.plan, compiled.policy(fsdp_axes=...)
    """
    if cache is not None:
        # program construction is memoized in-process; the canonical hash is
        # memoized on the graph object, so repeated replanning through the
        # persistent cache stays O(lookup) after the first call.
        prog = program_for(cfg, shape)
        compiled = prog.compile(mesh_axes=dict(mesh_axes),
                                offpath_repart=offpath_repart, cache=cache)
        g, plan = prog.graph, compiled.plan
    else:
        g, plan = _plan_cached(cfg, shape,
                               tuple(sorted(mesh_axes.items())), offpath_repart)
    policy = policy_from_plan(plan, g,
                              fsdp_axes=fsdp_axes_for(mesh_axes) if fsdp else ())
    return g, plan, policy
