"""The model stack: embedding -> scanned blocks -> norm -> LM head.

One implementation serves all ten assigned architectures; ``cfg.blocks()``
cycles the block pattern (attn | hymba | mlstm | slstm) over layers.  Layers
are grouped into *units* of one pattern period and scanned with
``jax.lax.scan`` (stacked params, leading L axis), with optional remat.

Sharding: a ``ShardingPolicy`` (usually derived from an EinDecomp plan)
supplies PartitionSpecs; activations get ``with_sharding_constraint`` at the
canonical cut points (embed out, block out, ffn hidden, logits), parameters
get in_shardings via ``param_shardings``.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (ParamFactory, dtype_of, embed, lm_logits,
                                 rmsnorm, softmax_xent)
from repro.models.policy import ShardingPolicy

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(pf: ParamFactory, cfg, blk: str) -> dict:
    p: dict[str, Any] = {"norm1": pf.ones(cfg.d_model)}
    if blk == "attn":
        p["attn"] = attn_mod.init_attention(pf, cfg)
        p["norm2"] = pf.ones(cfg.d_model)
        if cfg.moe:
            p["moe"] = moe_mod.init_moe(pf, cfg)
        else:
            p["ffn"] = ffn_mod.init_ffn(pf, cfg)
    elif blk == "hymba":
        p["attn"] = attn_mod.init_attention(pf, cfg)
        p["ssm"] = ssm_mod.init_ssm(pf, cfg)
        p["norm_a"] = pf.ones(cfg.d_model)
        p["norm_s"] = pf.ones(cfg.d_model)
        p["norm2"] = pf.ones(cfg.d_model)
        p["ffn"] = ffn_mod.init_ffn(pf, cfg)
    elif blk == "mlstm":
        p["mlstm"] = xlstm_mod.init_mlstm(pf, cfg)
    elif blk == "slstm":
        p["slstm"] = xlstm_mod.init_slstm(pf, cfg)
    else:
        raise ValueError(blk)
    return p


def _stack(trees: list):
    def leaf(*xs):
        if isinstance(xs[0], jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct((len(xs),) + xs[0].shape, xs[0].dtype)
        return jnp.stack(xs)

    return jax.tree.map(leaf, *trees)


def init_params(cfg, key: jax.Array | None = None, *, abstract: bool = False) -> dict:
    dt = dtype_of(cfg)
    pf = ParamFactory(key, dt, abstract)
    pattern = cfg.block_pattern
    units = cfg.n_layers // len(pattern)
    assert units * len(pattern) == cfg.n_layers

    layers = []
    for pos, blk in enumerate(pattern):
        layers.append(_stack([_init_block(pf, cfg, blk) for _ in range(units)]))

    params = {
        # d**-0.5 keeps tied-head logits unit-variance (x RMS=1 post-norm)
        "embed": pf.dense(cfg.vocab_padded, cfg.d_model,
                          scale=cfg.d_model ** -0.5),
        "layers": layers,
        "final_norm": pf.ones(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = pf.dense(cfg.d_model, cfg.vocab_padded)
    return params


# label strings mirroring init_params structure (for param_shardings)


def _block_labels(cfg, blk: str) -> dict:
    p: dict[str, Any] = {"norm1": "L a"}
    if blk in ("attn", "hymba"):
        at = {"wq": "L a h d", "wk": "L a k d", "wv": "L a k d", "wo": "L h d a"}
        if cfg.qkv_bias:
            at.update({"bq": "L h d", "bk": "L k d", "bv": "L k d"})
        p["attn"] = at
        p["norm2"] = "L a"
        ffl = {"w1": "L a f", "w2": "L f a"}
        if cfg.gated_ffn:
            ffl["w3"] = "L a f"
        if blk == "attn" and cfg.moe:
            ml = {"router": "L a e", "w1": "L e a f", "w2": "L e f a"}
            if cfg.gated_ffn:
                ml["w3"] = "L e a f"
            if cfg.shared_expert_ff:
                ml["shared"] = dict(ffl)
            p["moe"] = ml
        else:
            p["ffn"] = dict(ffl)
    if blk == "hymba":
        p["ssm"] = {"in_proj": "L a f", "conv_w": "L z a", "x_proj": "L a z",
                    "a_log": "L a n", "d_skip": "L a", "out_proj": "L f a"}
        p["norm_a"] = "L a"
        p["norm_s"] = "L a"
    if blk == "mlstm":
        p["mlstm"] = {"w_up": "L a f", "wq": "L a f", "wk": "L a f",
                      "wv": "L a f", "w_if": "L a z", "w_down": "L f a",
                      "norm": "L a"}
    if blk == "slstm":
        p["slstm"] = {"w_in": "L a f", "r": "L a f", "w_down": "L f a",
                      "norm": "L a"}
    return p


def param_labels(cfg) -> dict:
    labels = {
        "embed": "v a",
        "layers": [_block_labels(cfg, blk) for blk in cfg.block_pattern],
        "final_norm": "a",
    }
    if not cfg.tie_embeddings:
        labels["head"] = "a v"
    return labels


def param_shardings(cfg, policy: ShardingPolicy, mesh) -> dict:
    """Pytree of NamedShardings matching init_params(abstract=True)."""
    abstract = init_params(cfg, abstract=True)
    labels = param_labels(cfg)

    def make(sds, lab):
        return policy.sharding(mesh, lab, sds.shape, param=True)

    return jax.tree.map(make, abstract, labels)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _cst(x, labels: str, policy, mesh):
    if policy is None or mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, policy.sharding(mesh, labels, x.shape))


def _block_forward(blk: str, p: dict, x, cfg, policy, mesh):
    """Full-sequence block.  Returns (x, cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if blk == "attn":
        a_out, kv = attn_mod.attention_full(p["attn"], h, cfg)
        kv = (_cst(kv[0], "b s k d", policy, mesh),
              _cst(kv[1], "b s k d", policy, mesh))
        x = x + _cst(a_out, "b s a", policy, mesh)
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if cfg.moe:
            m_out, aux = moe_mod.moe_ffn(p["moe"], h2, cfg, policy=policy,
                                         mesh=mesh)
        else:
            m_out = ffn_mod.ffn(p["ffn"], h2, cfg)
        x = x + _cst(m_out, "b s a", policy, mesh)
        cache = kv
    elif blk == "hymba":
        a_out, kv = attn_mod.attention_full(p["attn"], h, cfg)
        kv = (_cst(kv[0], "b s k d", policy, mesh),
              _cst(kv[1], "b s k d", policy, mesh))
        s_out, st = ssm_mod.ssm_forward(p["ssm"], h, cfg)
        mixed = 0.5 * (rmsnorm(a_out, p["norm_a"], cfg.norm_eps)
                       + rmsnorm(s_out, p["norm_s"], cfg.norm_eps))
        x = x + _cst(mixed, "b s a", policy, mesh)
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + _cst(ffn_mod.ffn(p["ffn"], h2, cfg), "b s a", policy, mesh)
        cache = (kv, st)
    elif blk == "mlstm":
        out, st = xlstm_mod.mlstm_forward(p["mlstm"], h, cfg)
        x = x + _cst(out, "b s a", policy, mesh)
        cache = st
    elif blk == "slstm":
        out, st = xlstm_mod.slstm_forward(p["slstm"], h, cfg)
        x = x + _cst(out, "b s a", policy, mesh)
        cache = st
    else:
        raise ValueError(blk)
    return x, cache, aux


def _embed_tokens(params, tokens, prefix_embeds, cfg, policy, mesh):
    x = embed(params["embed"], tokens).astype(dtype_of(cfg))
    if cfg.prefix_len and prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return _cst(x, "b s a", policy, mesh)


def forward(params, tokens, cfg, *, prefix_embeds=None, policy=None,
            mesh=None, collect_cache: bool = False, remat: bool | None = None,
            unroll: bool = False, last_logit_only: bool = False,
            logit_index=None):
    """Full-sequence forward.  Returns (logits, caches, aux_loss).
    ``last_logit_only`` computes the LM head for the final position only
    (prefill serving: (b,s,v) logits are never needed — §Perf).
    ``logit_index`` (a scalar, may be traced) generalizes it to *any*
    single position — bucketed serving prefill pads the prompt to the
    bucket length and takes the logit at the last real token."""
    x = _embed_tokens(params, tokens, prefix_embeds, cfg, policy, mesh)
    pattern = cfg.block_pattern
    remat = (policy.remat if policy is not None else True) if remat is None else remat

    def unit(carry, unit_params):
        x, aux = carry
        caches = []
        for pos, blk in enumerate(pattern):
            x, cache, a = _block_forward(blk, unit_params[pos], x, cfg,
                                         policy, mesh)
            caches.append(cache)
            aux = aux + a
        return (x, aux), (tuple(caches) if collect_cache else 0)

    if remat == "dots":
        # selective remat: keep matmul outputs, recompute elementwise only
        body = jax.checkpoint(
            unit, policy=jax.checkpoint_policies.dots_saveable)
    elif remat:
        body = jax.checkpoint(unit)
    else:
        body = unit
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        tuple(params["layers"]), unroll=True if unroll else 1)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if last_logit_only:
        x = x[:, -1:]
    elif logit_index is not None:
        x = jax.lax.dynamic_slice_in_dim(x, logit_index, 1, axis=1)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = lm_logits(x, head)
    logits = _cst(logits, "b s v", policy, mesh)
    return logits, caches, aux


def loss_fn(params, batch, cfg, *, policy=None, mesh=None, unroll: bool = False):
    logits, _, aux = forward(
        params, batch["tokens"], cfg,
        prefix_embeds=batch.get("prefix_embeds"), policy=policy, mesh=mesh,
        unroll=unroll)
    # loss over token positions only (prefix positions predict nothing)
    if cfg.prefix_len:
        logits = logits[:, cfg.prefix_len:]
    ce = softmax_xent(logits[:, :-1], batch["labels"][:, 1:], cfg.vocab)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_caches(cfg, batch: int, kv_len: int, *, abstract: bool = False):
    """Per-pattern-position stacked (units, ...) decode caches."""
    dt = dtype_of(cfg)
    units = cfg.n_layers // len(cfg.block_pattern)

    def one(blk):
        if blk == "attn":
            return attn_mod.init_kv_cache(cfg, batch, kv_len, dt)
        if blk == "hymba":
            return (attn_mod.init_kv_cache(cfg, batch, kv_len, dt),
                    ssm_mod.init_ssm_state(cfg, batch, dt))
        if blk == "mlstm":
            return xlstm_mod.init_mlstm_state(cfg, batch)
        if blk == "slstm":
            return xlstm_mod.init_slstm_state(cfg, batch)
        raise ValueError(blk)

    def build():
        return [_stack([one(blk) for _ in range(units)])
                for blk in cfg.block_pattern]

    if abstract:
        return jax.eval_shape(build)  # no allocation (77GB+ at 32k decode)
    return build()


def _block_decode(blk: str, p: dict, x, cache, pos, cfg, policy, mesh):
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if blk == "attn":
        a_out, cache2 = attn_mod.attention_decode(p["attn"], h, cache, pos, cfg)
        cache2 = attn_mod.KVCache(_cst(cache2.k, "b t k d", policy, mesh),
                                  _cst(cache2.v, "b t k d", policy, mesh))
        x = x + a_out
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if cfg.moe:
            m_out, _ = moe_mod.moe_ffn(p["moe"], h2, cfg, policy=policy,
                                       mesh=mesh)
        else:
            m_out = ffn_mod.ffn(p["ffn"], h2, cfg)
        x = x + m_out
    elif blk == "hymba":
        kv, st = cache
        a_out, kv2 = attn_mod.attention_decode(p["attn"], h, kv, pos, cfg)
        kv2 = attn_mod.KVCache(_cst(kv2.k, "b t k d", policy, mesh),
                               _cst(kv2.v, "b t k d", policy, mesh))
        s_out, st2 = ssm_mod.ssm_decode(p["ssm"], h, st, cfg)
        mixed = 0.5 * (rmsnorm(a_out, p["norm_a"], cfg.norm_eps)
                       + rmsnorm(s_out, p["norm_s"], cfg.norm_eps))
        x = x + mixed
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + ffn_mod.ffn(p["ffn"], h2, cfg)
        cache2 = (kv2, st2)
    elif blk == "mlstm":
        out, cache2 = xlstm_mod.mlstm_decode(p["mlstm"], h, cache, cfg)
        x = x + out
    elif blk == "slstm":
        out, cache2 = xlstm_mod.slstm_decode(p["slstm"], h, cache, cfg)
        x = x + out
    else:
        raise ValueError(blk)
    return x, cache2


def decode_step(params, tokens, caches, pos, cfg, *, policy=None, mesh=None,
                unroll: bool = False):
    """One token for the whole batch.  tokens (b, 1); pos scalar int32.
    Returns (logits (b, 1, v), new caches)."""
    x = embed(params["embed"], tokens).astype(dtype_of(cfg))
    x = _cst(x, "b s a", policy, mesh)
    pattern = cfg.block_pattern

    def unit(x, scanned):
        unit_params, unit_caches = scanned
        new_caches = []
        for ppos, blk in enumerate(pattern):
            x, c2 = _block_decode(blk, unit_params[ppos], x, unit_caches[ppos],
                                  pos, cfg, policy, mesh)
            new_caches.append(c2)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(
        unit, x, (tuple(params["layers"]), tuple(caches)),
        unroll=True if unroll else 1)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = lm_logits(x, head)
    logits = _cst(logits, "b s v", policy, mesh)
    return logits, list(new_caches)


# ---------------------------------------------------------------------------
# Paged decode (the serving tier): block-pool KV caches + per-slot positions
# ---------------------------------------------------------------------------


def init_paged_caches(cfg, batch: int, n_blocks: int, block: int, *,
                      abstract: bool = False):
    """Per-pattern-position stacked (units, ...) paged decode caches.

    Attention blocks hold a ``PagedKVCache`` pool of ``n_blocks`` blocks x
    ``block`` rows (shared by all batch slots through block tables) instead
    of the dense per-slot (b, S, k, d) buffer; recurrent states are
    unchanged (per-slot already, so ``batch`` sizes only those)."""
    dt = dtype_of(cfg)
    units = cfg.n_layers // len(cfg.block_pattern)

    def one(blk):
        if blk == "attn":
            return attn_mod.init_paged_kv_cache(cfg, n_blocks, block, dt)
        if blk == "hymba":
            return (attn_mod.init_paged_kv_cache(cfg, n_blocks, block, dt),
                    ssm_mod.init_ssm_state(cfg, batch, dt))
        if blk == "mlstm":
            return xlstm_mod.init_mlstm_state(cfg, batch)
        if blk == "slstm":
            return xlstm_mod.init_slstm_state(cfg, batch)
        raise ValueError(blk)

    def build():
        return [_stack([one(blk) for _ in range(units)])
                for blk in cfg.block_pattern]

    if abstract:
        return jax.eval_shape(build)
    return build()


def _block_decode_paged(blk: str, p: dict, x, cache, tables, pos, cfg,
                        policy, mesh):
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if blk == "attn":
        a_out, cache2 = attn_mod.attention_decode_paged(
            p["attn"], h, cache, tables, pos, cfg)
        x = x + a_out
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if cfg.moe:
            m_out, _ = moe_mod.moe_ffn(p["moe"], h2, cfg, policy=policy,
                                       mesh=mesh)
        else:
            m_out = ffn_mod.ffn(p["ffn"], h2, cfg)
        x = x + m_out
    elif blk == "hymba":
        kv, st = cache
        a_out, kv2 = attn_mod.attention_decode_paged(
            p["attn"], h, kv, tables, pos, cfg)
        s_out, st2 = ssm_mod.ssm_decode(p["ssm"], h, st, cfg)
        mixed = 0.5 * (rmsnorm(a_out, p["norm_a"], cfg.norm_eps)
                       + rmsnorm(s_out, p["norm_s"], cfg.norm_eps))
        x = x + mixed
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + ffn_mod.ffn(p["ffn"], h2, cfg)
        cache2 = (kv2, st2)
    elif blk == "mlstm":
        out, cache2 = xlstm_mod.mlstm_decode(p["mlstm"], h, cache, cfg)
        x = x + out
    elif blk == "slstm":
        out, cache2 = xlstm_mod.slstm_decode(p["slstm"], h, cache, cfg)
        x = x + out
    else:
        raise ValueError(blk)
    return x, cache2


def decode_step_paged(params, tokens, caches, tables, pos, cfg, *,
                      policy=None, mesh=None, unroll: bool = False):
    """One continuous-batching decode step.  tokens (b, 1); tables (b, W)
    int32 block tables; pos (b,) int32 per-slot positions.  Returns
    (logits (b, 1, v), new caches).  Idle slots point their table rows at
    the scratch block 0 and carry pos such that their writes land there."""
    x = embed(params["embed"], tokens).astype(dtype_of(cfg))
    x = _cst(x, "b s a", policy, mesh)
    pattern = cfg.block_pattern

    def unit(x, scanned):
        unit_params, unit_caches = scanned
        new_caches = []
        for ppos, blk in enumerate(pattern):
            x, c2 = _block_decode_paged(
                blk, unit_params[ppos], x, unit_caches[ppos], tables, pos,
                cfg, policy, mesh)
            new_caches.append(c2)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(
        unit, x, (tuple(params["layers"]), tuple(caches)),
        unroll=True if unroll else 1)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = lm_logits(x, head)
    logits = _cst(logits, "b s v", policy, mesh)
    return logits, list(new_caches)


def cache_labels(cfg):
    """Label strings mirroring init_caches structure (for shardings)."""
    from repro.models.attention import KVCache
    from repro.models.ssm import SSMState
    from repro.models.xlstm import MLSTMState, SLSTMState

    def one(blk):
        kv = KVCache("L b t k d", "L b t k d")
        if blk == "attn":
            return kv
        if blk == "hymba":
            return (kv, SSMState("L b a n", "L b z a"))
        if blk == "mlstm":
            return MLSTMState("L b h d d", "L b h d", "L b h")
        if blk == "slstm":
            return SLSTMState("L b a", "L b a", "L b a", "L b a")
        raise ValueError(blk)

    return [one(blk) for blk in cfg.block_pattern]


def cache_shardings(cfg, batch: int, kv_len: int, policy, mesh):
    abstract = init_caches(cfg, batch, kv_len, abstract=True)
    labels = cache_labels(cfg)

    def make(sds, lab):
        return policy.sharding(mesh, lab, sds.shape)

    return jax.tree.map(make, abstract, labels)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs for the dry-run; real arrays for smoke)
# ---------------------------------------------------------------------------


def input_specs(cfg, shape, *, policy=None, mesh=None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""

    def sds(shp, dtype, labels):
        if policy is not None and mesh is not None:
            return jax.ShapeDtypeStruct(
                shp, dtype, sharding=policy.sharding(mesh, labels, shp))
        return jax.ShapeDtypeStruct(shp, dtype)

    B, S = shape.batch, shape.seq
    dt = dtype_of(cfg)
    if shape.kind in ("train", "prefill"):
        toks = S - (cfg.prefix_len or 0)
        out = {"tokens": sds((B, toks), jnp.int32, "b s"),
               "labels": sds((B, toks), jnp.int32, "b s")}
        if cfg.prefix_len:
            out["prefix_embeds"] = sds((B, cfg.prefix_len, cfg.d_model), dt,
                                       "b s a")
        if shape.kind == "prefill":
            out.pop("labels")
        return out
    # decode: one token + caches + position
    out = {"tokens": sds((B, 1), jnp.int32, "b s"),
           "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    return out
