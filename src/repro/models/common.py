"""Shared model components: norms, RoPE, embeddings, losses, init.

All parameters are plain dict pytrees; layer stacks carry a leading L axis
for ``jax.lax.scan``.  ``abstract=True`` init returns ShapeDtypeStructs so
the dry-run builds the full 100B+ parameter trees without allocating.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------


class ParamFactory:
    """Makes either real (seeded, fan-in scaled) params or abstract ones."""

    def __init__(self, key: jax.Array | None, dtype, abstract: bool):
        self.key = key
        self.dtype = dtype
        self.abstract = abstract

    def dense(self, *shape: int, scale: float | None = None):
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, self.dtype)
        self.key, sub = jax.random.split(self.key)
        fan_in = shape[0] if len(shape) >= 2 else 1
        s = scale if scale is not None else fan_in ** -0.5
        return (jax.random.normal(sub, shape, jnp.float32) * s).astype(self.dtype)

    def zeros(self, *shape: int):
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, self.dtype)
        return jnp.zeros(shape, self.dtype)

    def ones(self, *shape: int):
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, self.dtype)
        return jnp.ones(shape, self.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * r).astype(x.dtype) * g


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., s, h, hd); positions: (s,) or broadcastable to x[..., :, 0, 0]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., s, hd/2)
    cos = jnp.cos(ang)[..., None, :]                     # (..., s, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------


def embed(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, ids, axis=0)


def lm_logits(x: jnp.ndarray, head: jnp.ndarray) -> jnp.ndarray:
    """x (b, s, d) @ head (d, v) -> (b, s, v)."""
    return jnp.einsum("bsd,dv->bsv", x, head)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 vocab_real: int | None = None) -> jnp.ndarray:
    """Mean next-token cross-entropy, f32 logsumexp, padded ids masked."""
    lf = logits.astype(jnp.float32)
    if vocab_real is not None and vocab_real < lf.shape[-1]:
        pad = lf.shape[-1] - vocab_real
        mask = jnp.concatenate(
            [jnp.zeros((vocab_real,), jnp.float32),
             jnp.full((pad,), -1e30, jnp.float32)])
        lf = lf + mask
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu2": lambda x: jnp.square(jnp.maximum(x, 0)),
        "relu": lambda x: jnp.maximum(x, 0),
    }[name]
