"""Selective SSM (Mamba-style) used by the Hymba hybrid blocks.

TPU adaptation (DESIGN.md §2): the recurrence h_t = a_t ⊙ h_{t-1} + b_t is
computed *chunkwise* — ``lax.scan`` over chunks (sequential, carries the
(b, di, n) state) with ``lax.associative_scan`` inside each chunk (parallel
on the VPU).  This bounds live memory to one chunk's expanded state instead
of the full (b, s, di, n) tensor, and gives O(state) 500k-token decode.

Simplifications vs. Mamba (noted per DESIGN.md §4): dt is a scalar per
position (x_proj emits 2n+1 features: B, C, dt) and the inner width equals
d_model.  The decomposition-relevant structure — a recurrent scan whose
sequence label cannot be partitioned, with batch/state labels free — is
exactly preserved, which is what EinDecomp reasons about.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamFactory


class SSMState(NamedTuple):
    h: jnp.ndarray      # (b, di, n)
    conv: jnp.ndarray   # (b, k-1, di) — causal-conv tail


def init_ssm(pf: ParamFactory, cfg) -> dict:
    D = cfg.d_model
    di = D
    n = cfg.ssm_state
    kc = cfg.ssm_conv
    return {
        "in_proj": pf.dense(D, 2 * di),
        "conv_w": pf.dense(kc, di, scale=kc ** -0.5),
        "x_proj": pf.dense(di, 2 * n + 1),
        "a_log": pf.ones(di, n),
        "d_skip": pf.ones(di),
        "out_proj": pf.dense(di, D),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, tail: jnp.ndarray):
    """Depthwise causal conv along s.  x (b, s, di); w (k, di); tail
    (b, k-1, di) = the last k-1 inputs from the previous call."""
    k = w.shape[0]
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    return out, xp[:, -(k - 1):]


def _ssm_features(p: dict, xin: jnp.ndarray, n: int):
    feats = jnp.einsum("bsd,df->bsf", xin, p["x_proj"]).astype(jnp.float32)
    B, C, dt = feats[..., :n], feats[..., n : 2 * n], feats[..., 2 * n]
    dt = jax.nn.softplus(dt)[..., None]                     # (b, s, 1)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # (di, n)
    decay = jnp.exp(dt[..., None] * a)                      # (b, s, di, n)
    drive = (dt * B)[..., None, :] * xin.astype(jnp.float32)[..., None]
    return decay, drive, C


def ssm_forward(p: dict, x: jnp.ndarray, cfg, *, chunk: int = 256
                ) -> tuple[jnp.ndarray, SSMState]:
    """Full-sequence path.  x: (b, s, D) -> (y, final state)."""
    b, s, D = x.shape
    n = cfg.ssm_state
    di = D
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    tail0 = jnp.zeros((b, cfg.ssm_conv - 1, di), x.dtype)
    xin, _tail = _causal_conv(xin, p["conv_w"], tail0)
    xin = jax.nn.silu(xin)

    chunk = min(chunk, s)
    assert s % chunk == 0
    nchunks = s // chunk
    decay, drive, C = _ssm_features(p, xin, n)
    # reshape to (nchunks, b, chunk, ...)
    def split(t):
        return t.reshape(b, nchunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    decay_c, drive_c, C_c = split(decay), split(drive), split(C)

    def chunk_step(h, inputs):
        dc, dr, cc = inputs                                  # (b, chunk, di, n)…
        # intra-chunk parallel scan of h_t = dc_t*h_{t-1} + dr_t
        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2
        A, Bd = jax.lax.associative_scan(comb, (dc, dr), axis=1)
        hs = A * h[:, None] + Bd                              # (b, chunk, di, n)
        y = jnp.einsum("bcdn,bcn->bcd", hs, cc)              # contract state
        return hs[:, -1], y

    h0 = jnp.zeros((b, di, n), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_step, h0, (decay_c, drive_c, C_c))
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    y = y + xin.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    return out, SSMState(h_last, _tail)


def init_ssm_state(cfg, batch: int, dtype) -> SSMState:
    di = cfg.d_model
    return SSMState(
        jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
        jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype))


def ssm_decode(p: dict, x: jnp.ndarray, state: SSMState, cfg
               ) -> tuple[jnp.ndarray, SSMState]:
    """One-token step.  x: (b, 1, D)."""
    b, _, D = x.shape
    n = cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xin, tail = _causal_conv(xin, p["conv_w"], state.conv)
    xin = jax.nn.silu(xin)
    decay, drive, C = _ssm_features(p, xin, n)
    h = decay[:, 0] * state.h + drive[:, 0]                  # (b, di, n)
    y = jnp.einsum("bdn,bn->bd", h, C[:, 0])[:, None]
    y = y + xin.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    return out, SSMState(h, tail)
