"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-
parallel) and sLSTM (scalar memory, inherently sequential scan).

TPU adaptation (DESIGN.md §2): the mLSTM runs in *chunkwise* form — the
inter-chunk recurrence over the (b, h, d, d) matrix memory is a short
``lax.scan``; within a chunk the quadratic (L x L) gate-decay matrix is
formed in VMEM-sized tiles (L=256 default), giving O(s·d²) total work
instead of the O(s²) fully-parallel form.  The sLSTM keeps its sequential
``lax.scan`` over time — its sequence label is non-partitionable and its
EinGraph node says so (shardable excludes s), which is precisely what
EinDecomp needs to know (DESIGN.md §4 Arch-applicability).

Gating follows the paper's stabilized exponential form: i and f are kept in
log space, a per-step running max m_t is subtracted before exponentiation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamFactory, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


class MLSTMState(NamedTuple):
    c: jnp.ndarray   # (b, h, d, d) matrix memory
    n: jnp.ndarray   # (b, h, d)    normalizer
    m: jnp.ndarray   # (b, h)       running log-max (stabilizer)


def init_mlstm(pf: ParamFactory, cfg) -> dict:
    D = cfg.d_model
    H = cfg.n_heads
    return {
        "w_up": pf.dense(D, 2 * D),      # -> (mlstm input, output gate z)
        "wq": pf.dense(D, D),
        "wk": pf.dense(D, D),
        "wv": pf.dense(D, D),
        "w_if": pf.dense(D, 2 * H),      # input & forget gate preacts per head
        "w_down": pf.dense(D, D),
        "norm": pf.ones(D),
    }


def _heads(x: jnp.ndarray, h: int) -> jnp.ndarray:
    b, s, d = x.shape
    return x.reshape(b, s, h, d // h).transpose(0, 2, 1, 3)  # (b, h, s, dh)


def mlstm_forward(p: dict, x: jnp.ndarray, cfg, *, chunk: int = 256
                  ) -> tuple[jnp.ndarray, MLSTMState]:
    b, s, D = x.shape
    H = cfg.n_heads
    dh = D // H
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    xm, z = jnp.split(up, 2, axis=-1)
    q = _heads(jnp.einsum("bsd,de->bse", xm, p["wq"]), H).astype(jnp.float32)
    k = _heads(jnp.einsum("bsd,de->bse", xm, p["wk"]), H).astype(jnp.float32) * dh ** -0.5
    v = _heads(jnp.einsum("bsd,de->bse", xm, p["wv"]), H).astype(jnp.float32)
    gates = jnp.einsum("bsd,dg->bsg", xm, p["w_if"]).astype(jnp.float32)
    i_pre = gates[..., :H].transpose(0, 2, 1)                 # (b, h, s)
    f_pre = gates[..., H:].transpose(0, 2, 1)
    logf = -jax.nn.softplus(-f_pre)                           # log sigmoid(f)

    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def split(t, axis=2):
        shp = list(t.shape)
        shp[axis:axis + 1] = [nc, chunk]
        return jnp.moveaxis(t.reshape(shp), axis, 0)

    qc, kc, vc = split(q), split(k), split(v)
    ic, fc = split(i_pre), split(logf)

    def chunk_step(carry, inp):
        C, N, M = carry                                       # (b,h,d,d),(b,h,d),(b,h)
        qq, kk, vv, ii, ff = inp                              # (b,h,L,dh), gates (b,h,L)
        L = qq.shape[2]
        Fc = jnp.cumsum(ff, axis=-1)                          # (b,h,L) cumulative log f
        # stabilizer: m_t = max(Fc_t + M, max_{j<=t}(Fc_t - Fc_j + i_j))
        a = Fc + M[..., None]                                 # inter contribution
        blog = Fc[..., :, None] - Fc[..., None, :] + ii[..., None, :]  # (b,h,L,L)
        tri = jnp.tril(jnp.ones((L, L), bool))
        blog = jnp.where(tri, blog, -jnp.inf)
        m_t = jnp.maximum(a, jnp.max(blog, axis=-1))          # (b,h,L)
        Ddec = jnp.exp(blog - m_t[..., None])                 # intra decay matrix
        inter_w = jnp.exp(a - m_t)                            # (b,h,L)
        s_qk = jnp.einsum("bhld,bhjd->bhlj", qq, kk)
        h_intra = jnp.einsum("bhlj,bhjd->bhld", s_qk * Ddec, vv)
        h_inter = jnp.einsum("bhld,bhde->bhle", qq, C) * inter_w[..., None]
        # normalizer: n_t = sum_j decay * k_j  (intra)  +  inter_w * N
        n_intra = jnp.einsum("bhlj,bhjd->bhld", Ddec, kk)
        n_t = n_intra + inter_w[..., None] * N[:, :, None, :]
        h_num = h_intra + h_inter
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhld,bhld->bhl", qq, n_t)),
                            jnp.exp(-m_t))[..., None]
        h_out = h_num / denom                                 # (b,h,L,dh)
        # carry update to end of chunk
        m_new = jnp.maximum(Fc[..., -1] + M,
                            jnp.max(Fc[..., -1:] - Fc + ii, axis=-1))
        wgt = jnp.exp(Fc[..., -1:] - Fc + ii - m_new[..., None])  # (b,h,L)
        C_new = (jnp.exp(Fc[..., -1] + M - m_new)[..., None, None] * C
                 + jnp.einsum("bhl,bhld,bhle->bhde", wgt, kk, vv))
        N_new = (jnp.exp(Fc[..., -1] + M - m_new)[..., None] * N
                 + jnp.einsum("bhl,bhld->bhd", wgt, kk))
        return (C_new, N_new, m_new), h_out

    C0 = jnp.zeros((b, H, dh, dh), jnp.float32)
    N0 = jnp.zeros((b, H, dh), jnp.float32)
    M0 = jnp.full((b, H), -jnp.inf)
    (C, N, M), hs = jax.lax.scan(chunk_step, (C0, N0, M0), (qc, kc, vc, ic, fc))
    h = jnp.moveaxis(hs, 0, 2).reshape(b, H, s, dh)           # (b,h,s,dh)
    h = h.transpose(0, 2, 1, 3).reshape(b, s, D).astype(x.dtype)
    h = rmsnorm(h, p["norm"])
    out = jnp.einsum("bsd,de->bse", h * jax.nn.silu(z), p["w_down"])
    return out, MLSTMState(C, N, M)


def init_mlstm_state(cfg, batch: int) -> MLSTMState:
    H, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    return MLSTMState(
        jnp.zeros((batch, H, dh, dh), jnp.float32),
        jnp.zeros((batch, H, dh), jnp.float32),
        jnp.full((batch, H), -jnp.inf))


def mlstm_decode(p: dict, x: jnp.ndarray, state: MLSTMState, cfg
                 ) -> tuple[jnp.ndarray, MLSTMState]:
    """One-token recurrent step (exact xLSTM eqs. 19-27)."""
    b, _, D = x.shape
    H = cfg.n_heads
    dh = D // H
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    xm, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bsd,de->bse", xm, p["wq"])[:, 0].reshape(b, H, dh).astype(jnp.float32)
    k = jnp.einsum("bsd,de->bse", xm, p["wk"])[:, 0].reshape(b, H, dh).astype(jnp.float32) * dh ** -0.5
    v = jnp.einsum("bsd,de->bse", xm, p["wv"])[:, 0].reshape(b, H, dh).astype(jnp.float32)
    gates = jnp.einsum("bsd,dg->bsg", xm, p["w_if"])[:, 0].astype(jnp.float32)
    i_pre, f_pre = gates[..., :H], gates[..., H:]
    logf = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(logf + state.m, i_pre)
    fw = jnp.exp(logf + state.m - m_new)
    iw = jnp.exp(i_pre - m_new)
    C = fw[..., None, None] * state.c + iw[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k, v)
    N = fw[..., None] * state.n + iw[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, N)),
                        jnp.exp(-m_new))[..., None]
    h = jnp.einsum("bhd,bhde->bhe", q, C) / denom
    h = h.reshape(b, 1, D).astype(x.dtype)
    h = rmsnorm(h, p["norm"])
    out = jnp.einsum("bsd,de->bse", h * jax.nn.silu(z), p["w_down"])
    return out, MLSTMState(C, N, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


class SLSTMState(NamedTuple):
    c: jnp.ndarray   # (b, d)
    n: jnp.ndarray   # (b, d)
    h: jnp.ndarray   # (b, d)
    m: jnp.ndarray   # (b, d)


def init_slstm(pf: ParamFactory, cfg) -> dict:
    D = cfg.d_model
    return {
        "w_in": pf.dense(D, 4 * D),     # z, i, f, o preacts from x
        "r": pf.dense(D, 4 * D, scale=D ** -0.5),  # recurrent (block approx)
        "w_down": pf.dense(D, D),
        "norm": pf.ones(D),
    }


def _slstm_cell(p, x_t, st: SLSTMState) -> SLSTMState:
    pre = (x_t @ p["w_in"].astype(jnp.float32)
           + st.h @ p["r"].astype(jnp.float32))
    D = st.c.shape[-1]
    z, i_pre, f_pre, o = jnp.split(pre, 4, axis=-1)
    logf = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(logf + st.m, i_pre)
    fw = jnp.exp(logf + st.m - m_new)
    iw = jnp.exp(i_pre - m_new)
    c = fw * st.c + iw * jnp.tanh(z)
    n = fw * st.n + iw
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
    return SLSTMState(c, n, h, m_new)


def init_slstm_state(cfg, batch: int) -> SLSTMState:
    D = cfg.d_model
    z = jnp.zeros((batch, D), jnp.float32)
    return SLSTMState(z, z, z, jnp.full((batch, D), -jnp.inf))


def slstm_forward(p: dict, x: jnp.ndarray, cfg
                  ) -> tuple[jnp.ndarray, SLSTMState]:
    b, s, D = x.shape

    def step(st, x_t):
        st = _slstm_cell(p, x_t.astype(jnp.float32), st)
        return st, st.h

    st, hs = jax.lax.scan(step, init_slstm_state(cfg, b), x.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)
    h = rmsnorm(h, p["norm"])
    return jnp.einsum("bsd,de->bse", h, p["w_down"]), st


def slstm_decode(p: dict, x: jnp.ndarray, state: SLSTMState, cfg
                 ) -> tuple[jnp.ndarray, SLSTMState]:
    st = _slstm_cell(p, x[:, 0].astype(jnp.float32), state)
    h = st.h[:, None].astype(x.dtype)
    h = rmsnorm(h, p["norm"])
    return jnp.einsum("bsd,de->bse", h, p["w_down"]), st
