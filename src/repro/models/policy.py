"""ShardingPolicy: the bridge from an EinDecomp plan to GSPMD shardings.

The model stack is written against *canonical labels*:

    b batch   s sequence   t kv-cache time   a d_model   h q-heads
    k kv-heads   d head_dim   f ffn hidden   v vocab   e experts
    c expert capacity   n ssm state   L layer stack (scan axis)

EinDecomp (mesh mode) assigns whole mesh axes to labels per node; a policy
collapses that to one label->axes map (majority vote across nodes — the
per-node plan is exact in the engine path, the policy is the production
projection of it; see DESIGN.md §3 plan.py entry).

``fsdp=True`` additionally shards *parameters only* along their d_model (a)
or vocab dim over the data axis (ZeRO-3 style storage sharding, all-gathered
at use).  This is beyond the paper's cost model and is one of the §Perf
levers.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass
class ShardingPolicy:
    label_axes: dict[str, tuple[str, ...]] = field(default_factory=dict)
    fsdp_axes: tuple[str, ...] = ()     # extra param-only axes (on label 'a'/'v')
    remat: bool = True

    # -- spec construction ---------------------------------------------------

    def _axes(self, label: str) -> tuple[str, ...]:
        if label == "t":  # cache time inherits sequence sharding
            return self.label_axes.get("t", self.label_axes.get("s", ()))
        return self.label_axes.get(label, ())

    def act_spec(self, labels: str) -> P:
        """PartitionSpec for an activation with the given label string."""
        entries = []
        used: set[str] = set()
        for l in labels.split():
            ax = tuple(a for a in self._axes(l) if a not in used)
            used.update(ax)
            entries.append(_entry(ax))
        return P(*entries)

    def param_spec(self, labels: str) -> P:
        """PartitionSpec for a parameter; fsdp axes land on the first
        otherwise-unsharded 'a' (or 'v') dim."""
        entries = []
        used: set[str] = set()
        lab = labels.split()
        for l in lab:
            ax = tuple(a for a in self._axes(l) if a not in used)
            used.update(ax)
            entries.append(list(ax))
        if self.fsdp_axes:
            free = [a for a in self.fsdp_axes if a not in used]
            if free:
                # prefer OUTPUT/feature dims (f, h, v, ...) over the
                # contraction dim 'a': sharding 'a' makes GSPMD reshard the
                # (huge) activation to produce the weight gradient, where
                # feature-dim sharding only all-gathers the (small) weight
                # (ZeRO-3 style).  Measured in EXPERIMENTS.md §Perf iter 1.
                for pick in ("f", "h", "v", "k", "d", "e", "c", "a"):
                    if pick in lab and not entries[lab.index(pick)]:
                        entries[lab.index(pick)].extend(free)
                        break
        return P(*[_entry(tuple(e)) for e in entries])

    def sharding(self, mesh: Mesh, labels: str, shape=None, *,
                 param: bool = False) -> NamedSharding:
        spec = self.param_spec(labels) if param else self.act_spec(labels)
        if shape is not None:
            spec = safe_spec(spec, shape, mesh)
        return NamedSharding(mesh, spec)


def _entry(ax: tuple[str, ...]):
    if not ax:
        return None
    return ax[0] if len(ax) == 1 else tuple(ax)


def safe_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim (divisibility
    guard: e.g. 25 heads on a 16-way axis)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        keep = []
        d = int(dim)
        for a in axes:
            if d % sizes[a] == 0:
                keep.append(a)
                d //= sizes[a]
        out.append(_entry(tuple(keep)))
    return P(*out)


# ---------------------------------------------------------------------------
# Plan -> policy
# ---------------------------------------------------------------------------


def policy_from_plan(plan, graph=None, *, fsdp_axes: tuple[str, ...] = (),
                     remat: bool = True) -> ShardingPolicy:
    """Collapse a mesh-mode plan's per-node label->axes maps to one policy.

    Votes are weighted by node output size (big tensors should keep their
    plan-chosen sharding), then resolved *per mesh axis* so one axis serves
    exactly one label globally — the per-node plan is exact in the engine
    path; the policy is its consistent production projection.
    """
    sizes: dict[int, float] = {}
    if graph is not None:
        for n in graph.nodes:
            numel = 1
            for s in n.shape:
                numel *= int(s)
            sizes[n.nid] = float(numel)
    votes: dict[str, Counter] = {}
    for nid, ax_map in plan.axes_by_node.items():
        w = sizes.get(nid, 1.0)
        for label, axes in ax_map.items():
            votes.setdefault(label, Counter())[tuple(sorted(axes))] += w
    label_axes: dict[str, tuple[str, ...]] = {}
    for label, ctr in votes.items():
        best = max(ctr.items(), key=lambda kv: (kv[1], len(kv[0])))[0]
        if best:
            label_axes[label] = best
    # two labels may share an axis only if they never co-occur in a tensor;
    # act_spec/param_spec dedupe per-tensor (first label keeps the axis).
    return ShardingPolicy(label_axes=label_axes, fsdp_axes=fsdp_axes,
                          remat=remat)


def manual_policy(assignments: dict[str, str | tuple[str, ...]], *,
                  fsdp_axes: tuple[str, ...] = (), remat: bool = True
                  ) -> ShardingPolicy:
    """Hand-written policy (the paper's §9 baselines: megatron = {'h': model,
    'f': model, 'v': model, 'b': data}; sequence = {'s': model, ...})."""
    la = {}
    for l, ax in assignments.items():
        la[l] = (ax,) if isinstance(ax, str) else tuple(ax)
    return ShardingPolicy(label_axes=la, fsdp_axes=fsdp_axes, remat=remat)
