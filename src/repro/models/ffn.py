"""Feed-forward blocks: gated (SwiGLU / GeGLU) and plain (incl. squared-ReLU).

The hidden width f is the canonical "model parallel" EinSum label — the
EinGraph fragment is  h1[bsf] <- x[bsa] W1[af];  act;  y[bsa] <- h[bsf] W2[fa]
and EinDecomp discovers Megatron-style f-sharding on it (paper Exp 3).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import ParamFactory, activation


def init_ffn(pf: ParamFactory, cfg, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    p = {"w1": pf.dense(D, F), "w2": pf.dense(F, D)}
    if cfg.gated_ffn:
        p["w3"] = pf.dense(D, F)
    return p


def ffn(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    act = activation(cfg.act)
    h = jnp.einsum("bsa,af->bsf", x, p["w1"])
    if cfg.gated_ffn:
        h = act(h) * jnp.einsum("bsa,af->bsf", x, p["w3"])
    else:
        h = act(h)
    return jnp.einsum("bsf,fa->bsa", h, p["w2"])
