"""GQA / MQA / sliding-window attention with KV caching.

Three call modes:
  * full-sequence (train / prefill): fused flash attention (Pallas on TPU,
    jnp oracle elsewhere) over the whole (possibly windowed, causal) span.
  * decode: one query token against a KV cache buffer; sliding-window archs
    keep a ring buffer of size `window` so 500k-token decode is O(window).

Parameter layout keeps heads (h) and head_dim (d) as separate tensor dims —
these are exactly the EinSum labels EinDecomp assigns mesh axes to (the
multi-head-attention EinGraph of paper §3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.common import ParamFactory, apply_rope


def init_attention(pf: ParamFactory, cfg) -> dict:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": pf.dense(D, H, hd),
        "wk": pf.dense(D, K, hd),
        "wv": pf.dense(D, K, hd),
        "wo": pf.dense(H, hd, D, scale=(H * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = pf.zeros(H, hd)
        p["bk"] = pf.zeros(K, hd)
        p["bv"] = pf.zeros(K, hd)
    return p


def _project_qkv(p: dict, x: jnp.ndarray, cfg, positions: jnp.ndarray):
    q = jnp.einsum("bsa,ahd->bshd", x, p["wq"])
    k = jnp.einsum("bsa,akd->bskd", x, p["wk"])
    v = jnp.einsum("bsa,akd->bskd", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_full(p: dict, x: jnp.ndarray, cfg, *,
                   prefix_len: int = 0) -> tuple[jnp.ndarray, tuple]:
    """Train / prefill path.  Returns (out, (k_cache, v_cache)).

    ``prefix_len`` > 0 marks a non-causal prefix (PaliGemma patch tokens):
    implemented as full attention within the prefix via window exemption —
    we keep plain causal for the whole span and note the simplification in
    DESIGN.md (the decomposition structure is identical).
    """
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(p, x, cfg, positions)
    # (b, s, h, d) -> (b, h, s, d) for the kernel
    o = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, window=cfg.window)
    o = o.transpose(0, 2, 1, 3)  # (b, s, h, d)
    out = jnp.einsum("bshd,hda->bsa", o, p["wo"])
    return out, (k, v)


class KVCache(NamedTuple):
    k: jnp.ndarray  # (b, S, kv_heads, hd)
    v: jnp.ndarray


def init_kv_cache(cfg, batch: int, length: int, dtype) -> KVCache:
    K, hd = cfg.n_kv_heads, cfg.hd
    shape = (batch, length, K, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def attention_decode(p: dict, x: jnp.ndarray, cache: KVCache, pos: jnp.ndarray,
                     cfg) -> tuple[jnp.ndarray, KVCache]:
    """One decode step.  x: (b, 1, d_model); pos: scalar absolute position.

    Sliding-window archs use the cache as a ring buffer (slot = pos % W) and
    attend with window masking on absolute positions reconstructed from the
    ring; full-attention archs write at slot = pos.
    """
    b = x.shape[0]
    S = cache.k.shape[1]
    positions = jnp.full((1,), pos)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)

    slot = (pos % S) if cfg.window else pos
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))

    qh = q.transpose(0, 2, 1, 3)          # (b, h, 1, hd)
    kh = k.transpose(0, 2, 1, 3)          # (b, kv, S, hd)
    vh = v.transpose(0, 2, 1, 3)

    if cfg.window:
        # ring buffer: absolute position of slot i given current pos
        idx = jnp.arange(S)
        abs_pos = pos - ((pos % S) - idx) % S   # in (pos-S, pos]
        valid = (abs_pos >= 0) & (abs_pos <= pos) & (abs_pos > pos - cfg.window)
    else:
        idx = jnp.arange(S)
        valid = idx <= pos

    o = _decode_attend(qh, kh, vh, valid, cfg)
    o = o.transpose(0, 2, 1, 3)
    out = jnp.einsum("bshd,hda->bsa", o, p["wo"])
    return out, KVCache(k, v)


class PagedKVCache(NamedTuple):
    """Block-pool KV cache (the serving tier): ``n_blocks`` blocks of
    ``block`` cache rows each; sequences own disjoint block sets through
    per-slot block tables.  Block 0 is reserved as scratch (inactive slots
    write there; nothing valid ever reads it)."""

    k: jnp.ndarray  # (n_blocks, block, kv_heads, hd)
    v: jnp.ndarray


def init_paged_kv_cache(cfg, n_blocks: int, block: int, dtype) -> PagedKVCache:
    K, hd = cfg.n_kv_heads, cfg.hd
    shape = (n_blocks, block, K, hd)
    return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def attention_decode_paged(p: dict, x: jnp.ndarray, pool: PagedKVCache,
                           tables: jnp.ndarray, pos: jnp.ndarray,
                           cfg) -> tuple[jnp.ndarray, PagedKVCache]:
    """One decode step against a paged block pool.

    x: (b, 1, d_model); tables: (b, W) int32 block tables; pos: (b,) int32
    per-slot absolute positions — unlike ``attention_decode``, every batch
    slot sits at its *own* position (continuous batching).  This step's
    K/V are scattered into block ``tables[b, pos//block]`` at row offset
    ``pos % block``; the time-ordered cache view is gathered through the
    same block-table lookup the planner prices (``ops.kv_block_gather``)
    and attended with per-row validity masks (``idx <= pos``, plus the
    sliding window on absolute positions for windowed archs — the pool is
    time-ordered, so no ring reconstruction is needed).
    """
    blk = pool.k.shape[1]
    W = tables.shape[1]
    q, k_new, v_new = _project_qkv(p, x, cfg, pos[:, None])
    blk_ids = jnp.take_along_axis(tables, (pos // blk)[:, None], axis=1)[:, 0]
    off = pos % blk
    # slots own disjoint blocks (block 0 = shared scratch for idle slots)
    k_pool = pool.k.at[blk_ids, off].set(k_new[:, 0])
    v_pool = pool.v.at[blk_ids, off].set(v_new[:, 0])

    kh = ops.kv_block_gather(k_pool, tables, W * blk)   # (b, kv, t, d)
    vh = ops.kv_block_gather(v_pool, tables, W * blk)
    qh = q.transpose(0, 2, 1, 3)                        # (b, h, 1, hd)

    idx = jnp.arange(W * blk)
    valid = idx[None, :] <= pos[:, None]
    if cfg.window:
        valid &= idx[None, :] > (pos[:, None] - cfg.window)

    o = _decode_attend(qh, kh, vh, valid, cfg)
    o = o.transpose(0, 2, 1, 3)
    out = jnp.einsum("bshd,hda->bsa", o, p["wo"])
    return out, PagedKVCache(k_pool, v_pool)


def _decode_attend(q, k, v, valid, cfg):
    """Masked attention for a single query against the whole cache buffer.
    ``valid`` is (S,) shared across the batch, or (b, S) per-row (the paged
    decode path, where every slot sits at its own position)."""
    hq, hkv = q.shape[1], k.shape[1]
    g = hq // hkv
    b, _, S, d = k.shape
    qs = q.reshape(b, hkv, g, 1, d).astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qs, k.astype(jnp.float32))
    mask = (valid[:, None, None, None, :] if valid.ndim == 2
            else valid[None, None, None, None, :])
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p / l, v.astype(jnp.float32))
    return o.reshape(b, hq, 1, d).astype(q.dtype)
