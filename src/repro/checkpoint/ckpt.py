"""Sharded checkpointing with async save and elastic (resharding) restore.

Format: one directory per step with
  manifest.json   — step, flattened tree structure, per-leaf shape/dtype,
                    the mesh shape + plan the run used
  <leaf_id>.npy   — one file per pytree leaf (addressable data gathered per
                    host; single-process here, so the full array)

Restore accepts a *different* mesh/policy than the one saved: arrays are
re-placed with jax.device_put under the new shardings (elastic restart —
EinDecomp then replans for the new p; DESIGN.md §7).

Async: ``CheckpointManager.save`` snapshots the arrays to host memory
synchronously (cheap) and writes files on a background thread, so the train
step is never blocked on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    paths = [f"leaf{idx:05d}" for idx in range(len(leaves))]
    return leaves, paths, treedef


def save_checkpoint(path: str, step: int, tree: Any, *, extra: dict | None = None
                    ) -> None:
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, names, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for leaf, name in zip(leaves, names):
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if logical not in ("float64", "float32", "float16", "int64", "int32",
                           "int16", "int8", "uint8", "uint32", "uint64",
                           "bool"):
            arr = arr.astype(np.float32)  # bf16 etc: widen losslessly
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": logical})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_checkpoint(path: str, like: Any, *, shardings: Any = None
                    ) -> tuple[int, Any, dict]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, names, treedef = _flatten_with_paths(like)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    dtypes = {l["name"]: l["dtype"] for l in manifest["leaves"]}
    out = []
    for name, shd in zip(names, shard_leaves):
        arr = np.load(os.path.join(path, name + ".npy"))
        arr = jax.numpy.asarray(arr, dtype=dtypes.get(name, arr.dtype))
        if shd is not None:
            out.append(jax.device_put(arr, shd))  # reshard for the new mesh
        else:
            out.append(arr)
    return manifest["step"], jax.tree.unflatten(treedef, out), manifest["extra"]


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints under ``root``; async writes."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(root, exist_ok=True)

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def latest(self) -> str | None:
        steps = self.all_steps()
        return self._dir(steps[-1]) if steps else None

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, *, extra: dict | None = None,
             blocking: bool = False) -> None:
        self.wait()
        # snapshot to host memory now; write on a background thread
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_checkpoint(self._dir(step), step, host, extra=extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def restore_latest(self, like: Any, *, shardings: Any = None):
        path = self.latest()
        if path is None:
            return None
        return load_checkpoint(path, like, shardings=shardings)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
