"""Per-stage §8 planning and the stitched full-graph plan.

Each stage subgraph is planned by the *existing* EinDecomp DP against the
intra-stage mesh (the combined mesh minus the ``pp`` axis), resolving
through the canonical plan cache — stage graphs hash on structure alone
(canon.graph_key), so repeated transformer layers plan once and every
repetition hits warm.

The per-stage plans are then **stitched** into one full-graph plan: every
stage node's entry transfers to its global node verbatim (extraction
preserves labels), cut stubs drop out (the producer's own stage owns its
entry), and graph inputs take the entry of their first consuming stage —
the same first-consumer-wins rule ``decomp._finalize_inputs`` applies.
The stitched plan is a complete, valid mesh-mode plan for the *unpipelined*
graph: compiling it through the ordinary shard_map executor is the
bit-identity baseline the pipeline executor is tested against.

Finally, every stage's input-stub entries are **overridden** to the
stitched layout of the tensor that actually arrives there (the producer's
planned layout for handoffs, the stitched entry for graph inputs).  Stage
schedules built from these exec plans therefore emit exactly the
repartition chains the full-graph schedule emits for the same edges, which
is what makes the pipelined and unpipelined executions realize the same
collectives on the same values.
"""
from __future__ import annotations

from math import prod

from repro.core.decomp import (Plan, _consumer_sites, _in_labels_of,
                               cost_repart, eindecomp, plan_cost)
from repro.core.einsum import EinGraph

from repro.pipeline.partition import PipelineSpec, Stage


def _copy_plan(plan: Plan) -> Plan:
    out = Plan(p=plan.p, mode=plan.mode, cost=plan.cost)
    out.d_by_node = {k: dict(v) for k, v in plan.d_by_node.items()}
    out.axes_by_node = {k: {l: tuple(a) for l, a in v.items()}
                        for k, v in plan.axes_by_node.items()}
    return out


def plan_pipeline(
    g: EinGraph,
    stages: list[Stage],
    spec: PipelineSpec,
    *,
    intra_axes: dict[str, int],
    cache=None,
    offpath_repart: bool = True,
    cost_mode="paper",
) -> tuple[Plan, dict]:
    """Plan every stage (warm through ``cache``), stitch the full-graph
    plan, and override stub entries (see module doc).  Returns the
    stitched plan plus the plan-cache hit/miss delta this pipeline caused
    (how many stage plans resolved warm — the transformer-layer dedup the
    tests pin)."""
    p_intra = prod(intra_axes.values()) if intra_axes else 1
    before = dict(cache.stats) if cache is not None else {}
    for st in stages:
        st.plan = _copy_plan(eindecomp(
            st.graph, p_intra, mesh_axes=intra_axes,
            offpath_repart=offpath_repart, cost_mode=cost_mode, cache=cache))
    stats = {}
    if cache is not None:
        after = cache.stats
        stats = {k: after.get(k, 0) - before.get(k, 0)
                 for k in ("hits", "misses", "path_hits", "path_misses")}

    stitched = _stitch(g, stages, p_intra, spec)
    _override_stub_entries(stages, stitched)
    return stitched, stats


def _stitch(g: EinGraph, stages: list[Stage], p_intra: int,
            spec: PipelineSpec) -> Plan:
    """Per-stage plans -> one full-graph plan (see module doc).  ``g`` may
    be the unscaled graph: plan entries are {label: parts} maps, and any
    parts choice made at the b/m microbatch extent divides the full batch
    too, so the stitched plan is valid at both extents."""
    plan = Plan(p=p_intra, mode="mesh")
    for st in stages:
        for gn in st.nids:
            ln = st.lid_of[gn]
            plan.d_by_node[gn] = dict(st.plan.d_by_node[ln])
            if ln in st.plan.axes_by_node:
                plan.axes_by_node[gn] = {
                    l: tuple(a) for l, a in st.plan.axes_by_node[ln].items()}
    # graph inputs: first consuming stage's stub entry wins (stages are in
    # chain order and stub entries are the first local consumer's need, so
    # this agrees with decomp._finalize_inputs on the full graph)
    for st in stages:
        for gn, ln in sorted(st.lid_of.items()):
            if g.nodes[gn].kind != "input" or gn in plan.d_by_node:
                continue
            plan.d_by_node[gn] = dict(st.plan.d_by_node[ln])
            if ln in st.plan.axes_by_node:
                plan.axes_by_node[gn] = {
                    l: tuple(a) for l, a in st.plan.axes_by_node[ln].items()}
    plan.cost = plan_cost(g, plan)
    return plan


def _override_stub_entries(stages: list[Stage], stitched: Plan) -> None:
    """Point every stage-graph input entry at the layout the tensor
    actually arrives in: handoff stubs take the producer's stitched entry,
    graph-input stubs the stitched global input entry.  Labels transfer
    verbatim — extraction copies them unchanged."""
    for st in stages:
        for gn, ln in st.lid_of.items():
            if st.graph.nodes[ln].kind != "input":
                continue
            st.plan.d_by_node[ln] = dict(stitched.d_by_node[gn])
            if gn in stitched.axes_by_node:
                st.plan.axes_by_node[ln] = {
                    l: tuple(a) for l, a in stitched.axes_by_node[gn].items()}
            else:
                st.plan.axes_by_node.pop(ln, None)


def stage_priced_cost(stage: Stage) -> int:
    """The §7 price of one stage's schedule: ``plan_cost`` over the stage
    graph *plus* two terms the whole-graph bound amortizes away but a
    single stage cannot:

      * input-edge repartitions — stage inputs are not pre-placed the way
        §8.2 graph inputs are (a handoff arrives in the producer's layout,
        a shared graph input in its stitched layout), so the edges the
        stage schedule traces must be priced too;
      * replicate-ruled opaques — the fallback shard rule gathers every
        input to full replication, wire ``plan_cost`` never sees (the §7
        edge price targets the plan's layout, not the realized gather).
        Each such edge is priced as a gather to a replicated consumer at
        every one of the ``p`` sites: traced is n*(k-1), the surcharge
        alone is (p-1)*n >= it, so the bound is static and sound.

    This is the per-stage bound bench_pipeline --check holds traced wire
    under (the per-stage analogue of bench_spmd's whole-program
    ``traced <= plan_cost``)."""
    g, plan = stage.graph, stage.plan
    total = plan_cost(g, plan)
    rules = stage.sched.trace.rule_by_node if stage.sched is not None else {}
    for n in g.nodes:
        if n.kind not in ("einsum", "opaque"):
            continue
        d = plan.d_by_node[n.nid]
        replicated = n.kind == "opaque" and rules.get(n.nid) == "replicate"
        for ls, a in zip(_in_labels_of(n), n.inputs):
            na = g.nodes[a]
            da = tuple(plan.d_by_node[a].get(l, 1) for l in na.labels)
            if replicated:
                ones = tuple(1 for _ in na.labels)
                total += cost_repart(da, ones, na.shape, plan.p)
            elif na.kind == "input":
                target = tuple(d.get(l, 1) for l in ls)
                total += cost_repart(da, target, na.shape,
                                     _consumer_sites(n.kind, target, plan.p))
    return total
