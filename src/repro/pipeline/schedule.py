"""The static GPipe microbatch schedule and its combined collective trace.

``build_pipeline_schedule`` is the pipeline tier's ``build_schedule``: a
pure function of (graph, PipelineSpec, combined mesh shape) — no jax, no
devices — that partitions, plans, and lowers the whole pipeline:

  * stages come from the partitioner (repro.pipeline.partition), planned
    and stitched by repro.pipeline.plan;
  * each stage lowers through the ordinary ``spmd.build_schedule`` against
    the intra-stage mesh axes, at the per-microbatch batch extent;
  * the **cells** list is the GPipe fill/drain issue order — tick t runs
    cell (stage s, microbatch t - s) for every valid s, so the first p - 1
    and last p - 1 ticks are partially idle: the static bubble fraction
    (p-1)/(m+p-1) that ``core.cost.bubble_fraction`` prices;
  * stage handoffs lower to one cyclic ``ppermute`` per live tensor per
    boundary per microbatch over the ``pp`` mesh axis, appended to the
    combined trace (rule="handoff") *between* the producing and consuming
    cells — exactly where the executor issues them.  A tensor consumed k
    stages downstream is relayed through every intermediate boundary, so
    the trace prices the same wire the partitioner's objective minimized.

Every stage-trace event is re-emitted per microbatch with (stage,
microbatch) attribution and local node ids translated back to global ids,
so the combined trace slices cleanly by stage, by microbatch, or by rule.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core import spmd
from repro.core.cost import bubble_fraction, bubble_fraction_weighted
from repro.core.decomp import Plan
from repro.core.einsum import EinGraph

from repro.pipeline.partition import (PipelineSpec, Stage, _node_weight,
                                      partition_stages)
from repro.pipeline.plan import plan_pipeline, stage_priced_cost


@dataclass
class PipelineSchedule:
    """Everything static about one pipelined compile (see module doc)."""

    spec: PipelineSpec
    stages: list[Stage]
    stitched: Plan                      # full-graph plan = bit-id baseline
    cells: list[tuple[int, int]]        # GPipe (stage, microbatch) order
    boundaries: list[list[int]]         # per boundary: global nids handed off
    trace: spmd.CollectiveTrace         # combined, (stage, mb)-tagged
    sizes: dict[str, int]               # combined mesh sizes (pp included)
    out_ids: list[int]                  # global program outputs
    cut_elems: list[int] = field(default_factory=list)   # per boundary / mb
    stage_compute: list[int] = field(default_factory=list)  # §7 proxy / mb
    bubble: float = 0.0                 # static (p-1)/(m+p-1)
    bubble_weighted: float = 0.0        # compute-weighted fill/drain bubble
    cache_stats: dict = field(default_factory=dict)

    @property
    def handoff_elems(self) -> int:
        return sum(e.elems for e in self.trace.events if e.rule == "handoff")

    def stage_trace_elems(self, s: int) -> int:
        """Intra-stage traced wire of stage ``s`` for ONE microbatch (every
        microbatch replays the same stage schedule)."""
        return sum(e.elems for e in self.trace.events
                   if e.stage == s and e.microbatch == 0
                   and e.rule != "handoff")

    def stage_priced(self, s: int) -> int:
        return stage_priced_cost(self.stages[s])


def build_pipeline_schedule(
    g: EinGraph,
    spec: PipelineSpec,
    mesh_axes: dict[str, int],
    out_ids=None,
    *,
    cache=None,
    offpath_repart: bool = True,
    cost_mode="paper",
    fuse: bool = True,
    lookahead: int = 1,
) -> PipelineSchedule:
    """Partition + plan + lower one pipelined compile (see module doc).
    ``mesh_axes`` is the combined mesh including the ``spec.axis`` entry
    (which may be absent or size 1 when ``spec.stages == 1``)."""
    sizes = {a: int(s) for a, s in mesh_axes.items()}
    pp = sizes.get(spec.axis, 1)
    if pp != spec.stages:
        raise ValueError(
            f"pipeline: spec.stages={spec.stages} but mesh axis "
            f"{spec.axis!r} has size {pp} — they must agree")
    intra = {a: s for a, s in sizes.items() if a != spec.axis}
    p, m = spec.stages, spec.microbatches
    out_ids = list(out_ids) if out_ids is not None else g.outputs()

    stages = partition_stages(g, spec)
    stitched, cache_stats = plan_pipeline(
        g, stages, spec, intra_axes=intra, cache=cache,
        offpath_repart=offpath_repart, cost_mode=cost_mode)

    # per-stage lowering: stage outs = cut producers + global outs, so the
    # reduce-scatter fusion never rewrites a boundary tensor's layout
    stage_of = {gn: st.index for st in stages for gn in st.nids}
    cons = g.consumers()
    last_stage = {u: max((stage_of[v] for v in cons[u] if v in stage_of),
                         default=-1) for u in stage_of}
    out_set = set(out_ids)
    for st in stages:
        st.out_gids = [gn for gn in st.nids
                       if gn in out_set or last_stage[gn] > st.index]
        local_outs = [st.lid_of[gn] for gn in st.out_gids]
        st.sched = spmd.build_schedule(st.graph, st.plan, intra, local_outs,
                                       fuse=fuse, lookahead=lookahead)

    boundaries = [sorted(u for u in stage_of
                         if stage_of[u] <= k < last_stage[u])
                  for k in range(p - 1)]
    cells = [(s, t - s) for t in range(m + p - 1)
             for s in range(p) if 0 <= t - s < m]

    n_dev = math.prod(sizes.values()) if sizes else 1
    perm = tuple((i, (i + 1) % pp) for i in range(pp))

    def handoff_layout(u: int):
        st = stages[stage_of[u]]
        return st.sched.layouts[st.lid_of[u]]

    trace = spmd.CollectiveTrace()
    for (s, mb) in cells:
        st = stages[s]
        trace.extend_tagged(st.sched.trace, stage=s, microbatch=mb,
                            nid_map=st.gid_of)
        if s < p - 1 and pp > 1:
            for u in boundaries[s]:
                st_p = stages[stage_of[u]]
                node = st_p.graph.nodes[st_p.lid_of[u]]
                loc = spmd.local_shape(node.shape, handoff_layout(u), intra)
                n_loc = int(np.prod(loc, dtype=np.int64)) if loc else 1
                elems = n_dev * n_loc
                trace.add("ppermute", (spec.axis,), u, elems,
                          elems * spmd._itemsize(node.dtype),
                          rule="handoff", perm=perm, stage=s, microbatch=mb)

    cut_elems = []
    for bset in boundaries:
        tot = 0
        for u in bset:
            st_p = stages[stage_of[u]]
            tot += int(np.prod(st_p.graph.nodes[st_p.lid_of[u]].shape,
                               dtype=np.int64))
        cut_elems.append(tot)

    # per-stage compute weight for the measured bubble: the partitioner's
    # own §7 join-size proxy (all decompositions of a node share its FLOP
    # count, and every stage runs on the same intra mesh, so the proxy is
    # placement-invariant — Schedule.compute_elems would weigh stages by
    # local *output* numel, a memory proxy that over-counts cheap wide maps)
    stage_compute = [sum(_node_weight(st.graph, st.lid_of[gn])
                         for gn in st.nids) for st in stages]
    return PipelineSchedule(
        spec=spec, stages=stages, stitched=stitched, cells=cells,
        boundaries=boundaries, trace=trace, sizes=sizes, out_ids=out_ids,
        cut_elems=cut_elems, stage_compute=stage_compute,
        bubble=bubble_fraction(p, m),
        bubble_weighted=bubble_fraction_weighted(stage_compute, m),
        cache_stats=cache_stats)
