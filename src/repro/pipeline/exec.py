"""Pipeline executor: ONE shard_map over the combined (pp, intra) mesh.

Realization (documented in docs/architecture.md "Pipeline tier"): compute
is **replicated over the pp axis** — every pp slice executes every
(stage, microbatch) cell of the GPipe schedule as straight-line traced
code, and each stage handoff is a *cyclic rotation* ``ppermute`` over pp.
Because the graph inputs enter replicated over pp and every intra-stage
collective acts within a pp slice, all slices hold identical values at
every point; the rotation therefore preserves values exactly (slice i
receives from slice i-1 what it already holds) while putting the handoff
bytes on the pp wire precisely where a stage-resident pipeline would.
The static tier (PipelineSchedule: cells, per-stage traces, bubble) is
the honest cost model of the stage-resident schedule; this executor is
its bit-exact value realization — and what makes ``pipeline=`` outputs
bit-identical to the unpipelined stitched-plan compile, which the tests
and bench assert across the zoo.

Microbatches are split from (and re-concatenated onto) the batch
dimension OUTSIDE the shard_map but inside the jitted wrapper: each
microbatch's output chunk is assembled to its global rows first, so the
concatenation restores exact row order (concatenating *local* blocks
inside the body would interleave rows after out-spec assembly).

With a size-1 (or absent) pp axis no handoffs are emitted at all and the
single stage's schedule is the serial ``build_schedule`` verbatim — the
zero-collectives invariant the tests pin.
"""
from __future__ import annotations

from typing import Any, Callable

from repro.core import spmd
from repro.core.einsum import EinGraph

from repro.pipeline.schedule import PipelineSchedule


def make_pipeline_runner(g: EinGraph, psched: PipelineSchedule,
                         mesh) -> Callable:
    """Build ``f(*input_arrays) -> tuple(outputs)`` executing the GPipe
    cell schedule inside one shard_map over ``mesh`` (which must carry the
    combined axes ``psched.sizes``).  Jit-able like the other runners."""
    import jax.numpy as jnp
    from jax import lax

    spec = psched.spec
    p, m = spec.stages, spec.microbatches
    pp = psched.sizes.get(spec.axis, 1)
    intra = {a: s for a, s in psched.sizes.items() if a != spec.axis}
    stages = psched.stages
    stitched = psched.stitched
    out_ids = psched.out_ids
    perm = [(i, (i + 1) % pp) for i in range(pp)]

    in_ids = g.input_ids()
    in_layout = {i: spmd._plan_layout(g.nodes[i],
                                      stitched.axes_by_node.get(i, {}),
                                      intra)
                 for i in in_ids}
    batched_in = {i: (m > 1 and spec.batch_label in g.nodes[i].labels)
                  for i in in_ids}
    stage_of = {gn: st.index for st in stages for gn in st.nids}

    def out_layout(o: int):
        st = stages[stage_of[o]]
        return st.sched.layouts[st.lid_of[o]]

    batched_out = {o: (m > 1 and spec.batch_label in g.nodes[o].labels)
                   for o in out_ids}

    # flattened shard_map signature: one slot per (input, microbatch) for
    # batch-carrying inputs, one shared slot otherwise; same for outputs
    flat_in: list[tuple[int, int | None]] = []
    for i in in_ids:
        flat_in.extend((i, mb) for mb in range(m)) if batched_in[i] \
            else flat_in.append((i, None))
    flat_out: list[tuple[int, int | None]] = []
    for o in out_ids:
        flat_out.extend((o, mb) for mb in range(m)) if batched_out[o] \
            else flat_out.append((o, None))

    in_specs = tuple(spmd._pspec(in_layout[i]) for i, _ in flat_in)
    out_specs = tuple(spmd._pspec(out_layout(o)) for o, _ in flat_out)

    def body(*local_chunks):
        gvals: list[dict[int, Any]] = [{} for _ in range(m)]
        for (gid, mb), arr in zip(flat_in, local_chunks):
            v = jnp.asarray(arr)
            if mb is None:
                for d in gvals:
                    d[gid] = v
            else:
                gvals[mb][gid] = v
        for (s, mb) in psched.cells:
            st = stages[s]
            vals: dict[int, Any] = {
                ln: gvals[mb][gn] for gn, ln in st.lid_of.items()
                if st.graph.nodes[ln].kind == "input"}
            spmd.run_schedule_body(st.graph, st.sched, vals)
            for gn in st.out_gids:
                gvals[mb][gn] = vals[st.lid_of[gn]]
            if s < p - 1 and pp > 1:
                for u in psched.boundaries[s]:
                    gvals[mb][u] = lax.ppermute(gvals[mb][u], spec.axis,
                                                perm)
        return tuple(gvals[mb if mb is not None else 0][gid]
                     for gid, mb in flat_out)

    mapped = spmd._shard_map(body, mesh, in_specs, out_specs)

    def runner(*arrays):
        flat = []
        for i, arr in zip(in_ids, arrays):
            if batched_in[i]:
                dim = g.nodes[i].labels.index(spec.batch_label)
                flat.extend(jnp.split(jnp.asarray(arr), m, axis=dim))
            else:
                flat.append(arr)
        res = mapped(*flat)
        outs, k = [], 0
        for o in out_ids:
            if batched_out[o]:
                dim = g.nodes[o].labels.index(spec.batch_label)
                outs.append(jnp.concatenate(res[k:k + m], axis=dim))
                k += m
            else:
                outs.append(res[k])
                k += 1
        return tuple(outs)

    return runner
