"""Stage partitioner: cut an EinGraph into a chain of pipeline stages.

The third parallelism axis (after the §6 data/model decomposition the DP
already searches): a contiguous cut of the topological node sequence into
``p`` stages, minimizing the bytes that cross stage boundaries subject to a
per-stage compute-balance cap.  Contiguity is sound because this IR's
``topo_order()`` *is* construction order — any prefix of it is a valid
dependency-closed unit — and it is what makes the cut a chain (stage s only
ever feeds stages > s), which the RA401 analysis pass re-verifies.

A tensor produced in stage s and consumed in stage s+k is *live* across k
boundaries and is charged at every one of them: the executor's handoff
lowering (repro.pipeline.exec) relays it hop by hop over the ``pp`` mesh
axis, so the partitioner's objective prices exactly the wire the schedule
emits.

Stage subgraphs are materialized as standalone ``EinGraph``s: graph inputs
are copied verbatim (name preserved — canonical hashing never sees names),
cut tensors become fresh input stubs named ``handoff_<gnid>``.  Stub
creation is lazy, on first reference in global topo order, which preserves
the construction-order == topo-order invariant the rest of the stack
relies on.  ``canon.subgraph_key`` over the stage's global nids is the
stage identity: repeated transformer layers hash equal, which is what lets
their §8 plans resolve warm through the canonical plan cache.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

from repro.core import canon
from repro.core.decomp import node_bounds
from repro.core.einsum import EinGraph


@dataclass(frozen=True)
class PipelineSpec:
    """How to pipeline a compile: ``stages`` cuts over the ``axis`` mesh
    axis, ``microbatches`` splits the ``batch_label`` dimension.  ``balance``
    caps each stage's compute weight at balance * total / stages (doubled
    until a feasible cut exists, so a pathological graph degrades to an
    unbalanced cut instead of failing)."""

    stages: int = 1
    microbatches: int = 1
    axis: str = "pp"
    batch_label: str = "b"
    balance: float = 1.25

    def __post_init__(self):
        if self.stages < 1 or self.microbatches < 1:
            raise ValueError(f"PipelineSpec: stages={self.stages}, "
                             f"microbatches={self.microbatches} must be >= 1")


@dataclass
class Stage:
    """One pipeline stage: a dependency-closed slice of the global graph,
    extracted as a standalone EinGraph the §8 DP can plan directly."""

    index: int
    nids: list[int]              # global non-input nids, topo order
    graph: EinGraph              # extracted stage subgraph
    gid_of: dict[int, int]       # local nid -> global nid (stubs included)
    lid_of: dict[int, int]       # global nid -> local nid
    recv: list[int]              # global nids consumed via handoff stubs
    key: str = ""                # canon.subgraph_key(g, nids)
    # filled by repro.pipeline.plan / schedule:
    plan: object = None          # per-stage §8 plan (stub entries overridden)
    sched: object = None         # per-stage spmd.Schedule (microbatch-sized)
    out_gids: list[int] = field(default_factory=list)  # cut + global outs


def _in_label_sets(n):
    if n.kind == "einsum":
        return n.spec.in_labels
    if n.kind == "map":
        return (n.labels,)
    return n.in_labels or tuple((n.labels,) * len(n.inputs))


def batch_splittable(g: EinGraph, batch_label: str = "b") -> bool:
    """Whether splitting ``batch_label`` into microbatches is sound at the
    label level: every node consuming a batch-carrying input must carry the
    batch label on its own output (no reduction or rearrangement over the
    batch).  The MoE dispatch/combine pair fails this — capacity-dropped
    routing couples tokens across the whole batch — which is exactly why
    mixtral pipelines at m=1 only."""
    for n in g.nodes:
        if n.kind == "input":
            continue
        in_has = any(batch_label in ls for ls in _in_label_sets(n))
        if in_has and batch_label not in n.labels:
            return False
    return True


def _node_weight(g: EinGraph, nid: int) -> int:
    """Per-node compute proxy: join size (product of the node's label
    universe bounds) for einsum/opaque, output numel for map, 0 for
    inputs.  All decompositions of a node share its FLOP count (§7), so a
    partitioning-independent proxy is the right balance weight."""
    n = g.nodes[nid]
    if n.kind == "input":
        return 0
    if n.kind == "map":
        return int(np.prod(n.shape, dtype=np.int64))
    out = 1
    for b in node_bounds(g, nid).values():
        out *= int(b)
    return out


def _itemsize(dtype) -> int:
    try:
        return int(np.dtype(dtype).itemsize)
    except TypeError:
        return 4


def cut_tensors(g: EinGraph, boundaries: list[int]) -> list[list[int]]:
    """Per boundary, the global nids *live* across it: produced at or
    before, consumed after.  ``boundaries[k]`` is the position (in the
    non-input topo sequence) where stage k+1 starts.  Graph inputs are
    never cut — they are pre-placed (§8.2) and fed to every stage
    directly."""
    seq = [nid for nid in g.topo_order() if g.nodes[nid].kind != "input"]
    pos = {nid: i for i, nid in enumerate(seq)}
    cons = g.consumers()
    last = {u: max((pos[v] for v in cs), default=-1)
            for u, cs in cons.items() if u in pos}
    return [[u for u in seq[:b] if last.get(u, -1) >= b] for b in boundaries]


def partition_stages(g: EinGraph, spec: PipelineSpec) -> list[Stage]:
    """Cut ``g`` into ``spec.stages`` stages minimizing cut-edge bytes under
    the balance cap, and extract each as a standalone EinGraph.

    ``spec.stages == 1`` with ``microbatches == 1`` is the identity fast
    path: one Stage whose ``graph`` IS ``g`` (object identity), so the
    downstream schedule is build_schedule(g, ...) verbatim — the serial
    schedule.  With m > 1 every stage graph (including the single-stage
    one) is batch-scaled to the per-microbatch extent b/m, which is the
    compute one (stage, microbatch) cell runs.
    """
    if spec.microbatches > 1 and not batch_splittable(g, spec.batch_label):
        raise ValueError(
            "pipeline: graph couples rows across the batch label "
            f"{spec.batch_label!r} (e.g. MoE capacity routing) — "
            "microbatches must be 1")
    gm = scale_graph_batch(g, spec.microbatches, spec.batch_label)
    seq = [nid for nid in gm.topo_order() if gm.nodes[nid].kind != "input"]
    p = spec.stages
    if p == 1:
        lid = {nid: nid for nid in gm.topo_order()}
        return [Stage(index=0, nids=list(seq), graph=gm, gid_of=dict(lid),
                      lid_of=dict(lid), recv=[],
                      key=canon.subgraph_key(gm, seq))]
    if p > len(seq):
        raise ValueError(
            f"pipeline: {p} stages over {len(seq)} non-input nodes")

    n = len(seq)
    pos = {nid: i for i, nid in enumerate(seq)}
    cons = gm.consumers()
    last = {u: max((pos[v] for v in cons[u]), default=-1) for u in pos}
    nbytes = {u: int(np.prod(gm.nodes[u].shape, dtype=np.int64))
              * _itemsize(gm.nodes[u].dtype) for u in pos}
    cut_cost = [0] * (n + 1)
    for b in range(1, n):
        cut_cost[b] = sum(nbytes[u] for u in seq[:b] if last[u] >= b)
    w = [_node_weight(gm, nid) for nid in seq]
    pref = [0]
    for x in w:
        pref.append(pref[-1] + x)

    def solve(cap: float) -> list[int] | None:
        inf = float("inf")
        f = [[inf] * (n + 1) for _ in range(p + 1)]
        back: dict[tuple[int, int], int] = {}
        f[0][0] = 0.0
        for k in range(1, p + 1):
            for j in range(k, n + 1):
                for i in range(k - 1, j):
                    if pref[j] - pref[i] > cap:
                        continue
                    c = f[k - 1][i] + (cut_cost[i] if i else 0)
                    if c < f[k][j]:
                        f[k][j] = c
                        back[(k, j)] = i
        if f[p][n] == inf:
            return None
        bounds, j = [], n
        for k in range(p, 0, -1):
            i = back[(k, j)]
            if i:
                bounds.append(i)
            j = i
        return sorted(bounds)

    cap = spec.balance * pref[-1] / p
    boundaries = solve(cap)
    while boundaries is None:
        cap *= 2
        boundaries = solve(cap)

    edges = [0] + boundaries + [n]
    stages = []
    for k in range(p):
        nids = seq[edges[k]:edges[k + 1]]
        stages.append(_extract_stage(gm, k, nids))
    return stages


def _extract_stage(g: EinGraph, index: int, nids: list[int]) -> Stage:
    """Materialize one stage as a standalone EinGraph (see module doc).
    ``g`` is the (already microbatch-scaled) global graph."""
    sg = EinGraph(f"{g.name}.stage{index}")
    lid_of: dict[int, int] = {}
    recv: list[int] = []

    def ensure(a: int) -> int:
        if a in lid_of:
            return lid_of[a]
        na = g.nodes[a]
        name = na.name if na.kind == "input" else f"handoff_{a}"
        if na.kind != "input":
            recv.append(a)
        lid_of[a] = sg.input(name, na.labels, na.shape, na.dtype)
        return lid_of[a]

    for gn in nids:
        node = g.nodes[gn]
        ins = tuple(ensure(a) for a in node.inputs)
        lid_of[gn] = len(sg.nodes)
        sg.nodes.append(dataclasses.replace(
            node, nid=len(sg.nodes), inputs=ins))

    gid_of = {l: gn for gn, l in lid_of.items()}
    return Stage(index=index, nids=list(nids), graph=sg, gid_of=gid_of,
                 lid_of=dict(lid_of), recv=recv,
                 key=canon.subgraph_key(g, nids))


def scale_graph_batch(g: EinGraph, m: int, batch_label: str = "b") -> EinGraph:
    """A copy of ``g`` with every batch-labeled extent divided by ``m`` —
    the per-microbatch global graph (identity when m == 1)."""
    if m == 1:
        return g
    for node in g.nodes:
        if batch_label in node.labels:
            b = node.shape[node.labels.index(batch_label)]
            if b % m:
                raise ValueError(
                    f"pipeline: batch bound {b} not divisible by "
                    f"microbatches={m} (node {node.name})")
    out = EinGraph(g.name)
    for node in g.nodes:
        out.nodes.append(dataclasses.replace(
            node,
            shape=tuple(s // m if l == batch_label else s
                        for l, s in zip(node.labels, node.shape))))
    return out
