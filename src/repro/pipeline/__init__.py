"""Pipeline parallelism as a tensor-relational rewrite (see ISSUE/docs).

Three layers, mirroring the core stack:

  * partition — cut the EinGraph into a chain of stage subgraphs
    (min cut-edge bytes under a compute-balance cap);
  * plan — per-stage §8 DP through the canonical plan cache, stitched
    back into one full-graph plan (the bit-identity baseline);
  * schedule + exec — the static GPipe cell schedule with ppermute
    handoffs over the ``pp`` mesh axis, realized as ONE shard_map over
    the combined (pp, intra) mesh.
"""
from repro.pipeline.partition import (PipelineSpec, Stage, batch_splittable,
                                      cut_tensors, partition_stages,
                                      scale_graph_batch)
from repro.pipeline.plan import plan_pipeline, stage_priced_cost
from repro.pipeline.schedule import PipelineSchedule, build_pipeline_schedule

__all__ = [
    "PipelineSpec", "Stage", "batch_splittable", "cut_tensors",
    "partition_stages", "scale_graph_batch", "plan_pipeline",
    "stage_priced_cost", "PipelineSchedule", "build_pipeline_schedule",
    "make_pipeline_runner",
]


def make_pipeline_runner(g, psched, mesh):
    from repro.pipeline.exec import make_pipeline_runner as _mk
    return _mk(g, psched, mesh)
