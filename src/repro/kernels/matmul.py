"""Tiled matmul Pallas kernel — the TRA kernel function K for contraction
nodes (the paper's MKL batch-GEMM, re-tiled for MXU/VMEM; DESIGN.md §2,
adaptation 5).

grid = (m_blocks, n_blocks, k_blocks) with the contraction (k) innermost and
sequential; the (blk_m, blk_n) f32 accumulator lives in VMEM scratch and the
output block is written once on the final k step.  Default tiles 128x128x128:
every matmul dim is MXU-aligned and the working set
(blk_m*blk_k + blk_k*blk_n + blk_m*blk_n floats) is ~192 KiB << VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(
    x: jnp.ndarray,  # (m, k)
    w: jnp.ndarray,  # (k, n)
    *,
    blk_m: int = 128,
    blk_n: int = 128,
    blk_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    blk_m, blk_n, blk_k = min(blk_m, m), min(blk_n, n), min(blk_k, k)
    assert m % blk_m == 0 and n % blk_n == 0 and k % blk_k == 0

    return pl.pallas_call(
        _mm_kernel,
        grid=(m // blk_m, n // blk_n, k // blk_k),
        in_specs=[
            pl.BlockSpec((blk_m, blk_k), lambda im, jn, ik: (im, ik)),
            pl.BlockSpec((blk_k, blk_n), lambda im, jn, ik: (ik, jn)),
        ],
        out_specs=pl.BlockSpec((blk_m, blk_n), lambda im, jn, ik: (im, jn)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((blk_m, blk_n), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
