"""Pure-jnp oracles for every Pallas kernel.

These define the semantics; each kernel's tests sweep shapes/dtypes and
assert_allclose against these.  They are also the lowering used for the
CPU dry-run (the Pallas kernels are the TPU *target*; on the CPU container
they are validated in interpret mode only — DESIGN.md §2, adaptation 5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention(
    q: jnp.ndarray,  # (b, hq, sq, d)
    k: jnp.ndarray,  # (b, hkv, sk, d)
    v: jnp.ndarray,  # (b, hkv, sk, d)
    *,
    causal: bool = True,
    window: int = 0,          # 0 = full; >0 = sliding window (causal)
    scale: float | None = None,
    q_offset: int = 0,        # absolute position of q[0] (decode steps)
    kv_offset: int = 0,       # absolute position of k[0] (ring-rotated blocks)
) -> jnp.ndarray:
    """Multi-head (grouped-query) attention, numerically-safe softmax."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = (d ** -0.5) if scale is None else scale

    qs = q.reshape(b, hkv, g, sq, d).astype(jnp.float32) * scale
    ks = k.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qs, ks)

    s = jnp.where(_mask(sq, sk, q_offset, kv_offset, causal, window)
                  [None, None, None], s, NEG_INF)

    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p / l, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)


def _mask(sq, sk, q_offset, kv_offset, causal, window):
    """(sq, sk) keep-mask for a (q block, kv block) pair at absolute
    positions ``q_offset`` / ``kv_offset`` (both may be traced scalars)."""
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk) + kv_offset
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    return mask


def attention_step(
    q: jnp.ndarray,  # (b, hq, sq, d)
    k: jnp.ndarray,  # (b, hkv, sk_blk, d)  — one kv block
    v: jnp.ndarray,  # (b, hkv, sk_blk, d)
    carry: tuple | None = None,  # (m, l, acc) from previous blocks, or None
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    q_offset: int = 0,        # absolute position of q[0] (may be traced)
    kv_offset: int = 0,       # absolute position of k[0] (may be traced)
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One online-softmax step over a kv block: fold the block's scores into
    the carried state ``(m, l, acc)`` (running max (b,hq,sq), normalizer
    (b,hq,sq), unnormalized accumulator (b,hq,sq,d), all f32).

    This is the ring-attention contract: chaining ``attention_step`` over
    every kv block of the sequence (in any order, with the matching
    ``kv_offset`` per block) and finalizing with ``attention_finalize``
    reproduces dense ``attention`` exactly — including the finite-``NEG_INF``
    convention for fully-masked rows, so partially- and fully-masked blocks
    contribute 0 weight in the merge without any special-casing.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = (d ** -0.5) if scale is None else scale

    qs = q.reshape(b, hkv, g, sq, d).astype(jnp.float32) * scale
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qs, k.astype(jnp.float32))
    s = jnp.where(_mask(sq, sk, q_offset, kv_offset, causal, window)
                  [None, None, None], s, NEG_INF)
    s = s.reshape(b, hq, sq, sk)

    if carry is None:
        m_prev = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
        l_prev = jnp.zeros((b, hq, sq), jnp.float32)
        acc_prev = jnp.zeros((b, hq, sq, d), jnp.float32)
    else:
        m_prev, l_prev, acc_prev = carry

    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])                  # (b, hq, sq, sk)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhgqk,bhkd->bhgqd",
                    p.reshape(b, hkv, g, sq, sk), v.astype(jnp.float32))
    acc_new = acc_prev * alpha[..., None] + pv.reshape(b, hq, sq, d)
    return m_new, l_new, acc_new


def attention_finalize(carry: tuple, dtype=jnp.float32) -> jnp.ndarray:
    """(m, l, acc) -> normalized output (b, hq, sq, d).  ``l == 0`` (state
    never touched by any block — possible only for chains that skipped
    fully-masked tiles) yields 0, matching the Pallas kernel's convention."""
    _, l, acc = carry
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(dtype)


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(m, k) @ (k, n) in f32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def gmm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Grouped (expert) matmul on capacity-padded buffers:
    (e, c, k) @ (e, k, n) -> (e, c, n), f32 accumulation."""
    return jnp.einsum(
        "eck,ekn->ecn", x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * r * g.astype(jnp.float32)).astype(x.dtype)
