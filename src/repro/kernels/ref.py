"""Pure-jnp oracles for every Pallas kernel.

These define the semantics; each kernel's tests sweep shapes/dtypes and
assert_allclose against these.  They are also the lowering used for the
CPU dry-run (the Pallas kernels are the TPU *target*; on the CPU container
they are validated in interpret mode only — DESIGN.md §2, adaptation 5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention(
    q: jnp.ndarray,  # (b, hq, sq, d)
    k: jnp.ndarray,  # (b, hkv, sk, d)
    v: jnp.ndarray,  # (b, hkv, sk, d)
    *,
    causal: bool = True,
    window: int = 0,          # 0 = full; >0 = sliding window (causal)
    scale: float | None = None,
    q_offset: int = 0,        # absolute position of q[0] (decode steps)
) -> jnp.ndarray:
    """Multi-head (grouped-query) attention, numerically-safe softmax."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = (d ** -0.5) if scale is None else scale

    qs = q.reshape(b, hkv, g, sq, d).astype(jnp.float32) * scale
    ks = k.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qs, ks)

    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)

    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p / l, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(m, k) @ (k, n) in f32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def gmm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Grouped (expert) matmul on capacity-padded buffers:
    (e, c, k) @ (e, k, n) -> (e, c, n), f32 accumulation."""
    return jnp.einsum(
        "eck,ekn->ecn", x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * r * g.astype(jnp.float32)).astype(x.dtype)
