"""FlashAttention for TPU in Pallas (the paper's attention hot-spot,
re-thought for the TPU memory hierarchy — DESIGN.md §2, adaptation 3).

Online-softmax attention with explicit VMEM tiling:

* grid = (batch, q_heads, q_blocks, kv_blocks); the kv axis is the innermost
  "arbitrary" (sequential) dimension so the output block is revisited and
  the running (m, l, acc) state lives in VMEM scratch.
* Q/K/V/O blocks are (1, 1, blk, d) slices; the kv-head index_map divides by
  the GQA group size so grouped-query attention reads each KV block once
  per query-head group member without materializing repeats in HBM.
* causal / sliding-window blocks that are fully masked are skipped via
  ``pl.when`` (no MXU work, no VMEM traffic for the P·V matmul).
* block sizes default to (128, 128) — MXU-aligned (multiples of 128 in the
  contracting and lane dims) and small enough that the working set
  q(128·d) + k,v(128·d each) + acc(128·d) fits VMEM for d ≤ 256.

Numerics: scores and the running state are f32 regardless of input dtype
(bf16 in production); the output is cast back.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,          # inputs
    o_ref,                        # output
    m_ref, l_ref, acc_ref,        # VMEM scratch (carried over kv grid dim)
    *,
    scale: float,
    causal: bool,
    window: int,
    blk_q: int,
    blk_k: int,
    q_offset: int,
    kv_offset: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * blk_q + q_offset
    k_start = ik * blk_k + kv_offset

    # block-level relevance: any (q, k) pair in this tile unmasked?
    relevant = True
    if causal:
        relevant = k_start <= q_start + blk_q - 1
    if window:
        relevant = jnp.logical_and(relevant, k_start + blk_k - 1 > q_start - window)

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (blk_q, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (blk_k, d)
        s = jax.lax.dot_general(                              # (blk_q, blk_k) on MXU
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

        if causal or window:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
            mask = jnp.ones((blk_q, blk_k), dtype=jnp.bool_)
            if causal:
                mask &= kpos <= qpos
            if window:
                mask &= kpos > qpos - window
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                                  # (blk_q,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])                       # (blk_q, blk_k)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)                   # (blk_k, d)
        pv = jax.lax.dot_general(                              # MXU
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[:, 0] = m_cur

    @pl.when(ik == nk - 1)
    def _fin():
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (b, hq, sq, d)
    k: jnp.ndarray,  # (b, hkv, sk, d)
    v: jnp.ndarray,  # (b, hkv, sk, d)
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    q_offset: int = 0,
    kv_offset: int = 0,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, "GQA requires hq % hkv == 0"
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else float(scale)
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, sk)
    assert sq % blk_q == 0 and sk % blk_k == 0, "seq must divide block"
    grid = (b, hq, sq // blk_q, sk // blk_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, q_offset=q_offset, kv_offset=kv_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, blk_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, blk_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),   # m
            pltpu.VMEM((blk_q, 1), jnp.float32),   # l
            pltpu.VMEM((blk_q, d), jnp.float32),   # acc
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Ring-attention step: one kv block folded into carried (m, l, acc) state
# ---------------------------------------------------------------------------


def _flash_step_kernel(
    offs_ref,                       # (1, 2) int32: [q_offset, kv_offset]
    q_ref, k_ref, v_ref,            # inputs
    m_in_ref, l_in_ref, acc_in_ref,  # carried state in
    m_out_ref, l_out_ref, acc_out_ref,  # carried state out
    m_s, l_s, acc_s,                # VMEM scratch (carried over kv grid dim)
    *,
    scale: float,
    causal: bool,
    window: int,
    blk_q: int,
    blk_k: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = m_in_ref[0, 0][:, None]
        l_s[...] = l_in_ref[0, 0][:, None]
        acc_s[...] = acc_in_ref[0, 0]

    q_start = iq * blk_q + offs_ref[0, 0]
    k_start = ik * blk_k + offs_ref[0, 1]

    # No block skipping here: every tile runs with the finite-NEG_INF mask
    # so the state transition matches kernels/ref.py attention_step exactly
    # (a fully-masked tile contributes weight exp(NEG_INF - m) == 0).
    q = q_ref[0, 0].astype(jnp.float32) * scale            # (blk_q, d)
    k = k_ref[0, 0].astype(jnp.float32)                    # (blk_k, d)
    s = jax.lax.dot_general(                               # (blk_q, blk_k)
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    if causal or window:
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = jnp.ones((blk_q, blk_k), dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_s[:, 0]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_s[:, 0] = l_s[:, 0] * alpha + jnp.sum(p, axis=1)
    v = v_ref[0, 0].astype(jnp.float32)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_s[...] = acc_s[...] * alpha[:, None] + pv
    m_s[:, 0] = m_cur

    @pl.when(ik == nk - 1)
    def _fin():
        m_out_ref[0, 0] = m_s[:, 0]
        l_out_ref[0, 0] = l_s[:, 0]
        acc_out_ref[0, 0] = acc_s[...]


def flash_attention_step(
    q: jnp.ndarray,  # (b, hq, sq, d)
    k: jnp.ndarray,  # (b, hkv, sk_blk, d) — one kv block of the ring
    v: jnp.ndarray,  # (b, hkv, sk_blk, d)
    carry: tuple | None = None,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    q_offset=0,      # absolute position of q[0]; int or traced scalar
    kv_offset=0,     # absolute position of k[0]; int or traced scalar
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Ring-attention step entry point: fold one kv block into the carried
    online-softmax state ``(m, l, acc)``.

    The offsets ride in as a (1, 2) int32 array, so they may be traced
    values (``lax.axis_index`` arithmetic inside ``shard_map``) — the causal
    / sliding-window masks compare against the block's *absolute* positions,
    which is what keeps rotated kv blocks correctly masked at every ring
    offset.  Finalize with ``ref.attention_finalize`` (acc / l).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, "GQA requires hq % hkv == 0"
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else float(scale)
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, sk)
    assert sq % blk_q == 0 and sk % blk_k == 0, "seq must divide block"
    grid = (b, hq, sq // blk_q, sk // blk_k)

    if carry is None:
        m = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
        l = jnp.zeros((b, hq, sq), jnp.float32)
        acc = jnp.zeros((b, hq, sq, d), jnp.float32)
    else:
        m, l, acc = carry
    offs = jnp.stack([jnp.asarray(q_offset, jnp.int32),
                      jnp.asarray(kv_offset, jnp.int32)]).reshape(1, 2)

    kernel = functools.partial(
        _flash_step_kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda ib, ih, iq, ik: (0, 0)),
            pl.BlockSpec((1, 1, blk_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, blk_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, blk_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, blk_q), lambda ib, ih, iq, ik: (ib, ih, iq)),
            pl.BlockSpec((1, 1, blk_q), lambda ib, ih, iq, ik: (ib, ih, iq)),
            pl.BlockSpec((1, 1, blk_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, blk_q), lambda ib, ih, iq, ik: (ib, ih, iq)),
            pl.BlockSpec((1, 1, blk_q), lambda ib, ih, iq, ik: (ib, ih, iq)),
            pl.BlockSpec((1, 1, blk_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, sq, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),   # m
            pltpu.VMEM((blk_q, 1), jnp.float32),   # l
            pltpu.VMEM((blk_q, d), jnp.float32),   # acc
        ],
        # the incoming carry is dead after the call: alias each (m, l, acc)
        # input buffer to its output so XLA updates the ring state in place
        # instead of allocating fresh HBM every ring step
        input_output_aliases={4: 0, 5: 1, 6: 2},
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(offs, q, k, v, m, l, acc)
