"""Grouped (expert) matmul Pallas kernel for MoE FFNs.

Operates on capacity-padded dispatch buffers (GShard layout):
    x (e, c, k) @ w (e, k, n) -> (e, c, n)
grid = (experts, c_blocks, n_blocks, k_blocks), contraction innermost with a
VMEM f32 accumulator.  The expert dim is fully parallel — exactly the label
the EinDecomp plan assigns a mesh axis to for expert parallelism (the
per-device call then sees its local expert slice).

Block sizes (128, 128, 128) keep all tiles MXU-aligned; the expert index
only selects blocks, so one expert's weight tile is fetched HBM->VMEM per
(c_block, n_block, k_block) visit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _fin():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def gmm(
    x: jnp.ndarray,  # (e, c, k)
    w: jnp.ndarray,  # (e, k, n)
    *,
    blk_c: int = 128,
    blk_n: int = 128,
    blk_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    e, c, k = x.shape
    e2, k2, n = w.shape
    assert e == e2 and k == k2
    blk_c, blk_n, blk_k = min(blk_c, c), min(blk_n, n), min(blk_k, k)
    assert c % blk_c == 0 and n % blk_n == 0 and k % blk_k == 0

    return pl.pallas_call(
        _gmm_kernel,
        grid=(e, c // blk_c, n // blk_n, k // blk_k),
        in_specs=[
            pl.BlockSpec((1, blk_c, blk_k), lambda ie, ic, jn, ik: (ie, ic, ik)),
            pl.BlockSpec((1, blk_k, blk_n), lambda ie, ic, jn, ik: (ie, ik, jn)),
        ],
        out_specs=pl.BlockSpec((1, blk_c, blk_n),
                               lambda ie, ic, jn, ik: (ie, ic, jn)),
        out_shape=jax.ShapeDtypeStruct((e, c, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((blk_c, blk_n), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
