"""Dispatching wrappers for the Pallas kernels.

``impl`` selects the implementation:
  * "auto"   — Pallas (compiled) on TPU, jnp reference elsewhere.  This is
               what the model stack calls: the dry-run on the CPU container
               lowers the XLA reference; on a real pod the same config runs
               the Pallas kernels.
  * "pallas" — Pallas, interpret-mode off-TPU (used by the kernel tests).
  * "ref"    — the pure-jnp oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import moe_gmm as _gmm
from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, scale=None, q_offset=0,
                    kv_offset=0, impl: str = "auto",
                    blk_q: int = 128, blk_k: int = 128):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.attention(q, k, v, causal=causal, window=window, scale=scale,
                             q_offset=q_offset, kv_offset=kv_offset)
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, scale=scale, q_offset=q_offset,
        kv_offset=kv_offset, blk_q=blk_q, blk_k=blk_k, interpret=not _on_tpu())


def flash_attention_step(q, k, v, carry=None, *, causal=True, window=0,
                         scale=None, q_offset=0, kv_offset=0,
                         impl: str = "auto", blk_q: int = 128, blk_k: int = 128):
    """One ring-attention step: fold a kv block into carried (m, l, acc).
    Offsets may be traced scalars (ring rotation inside shard_map)."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.attention_step(q, k, v, carry, causal=causal, window=window,
                                  scale=scale, q_offset=q_offset,
                                  kv_offset=kv_offset)
    return _fa.flash_attention_step(
        q, k, v, carry, causal=causal, window=window, scale=scale,
        q_offset=q_offset, kv_offset=kv_offset, blk_q=blk_q, blk_k=blk_k,
        interpret=not _on_tpu())


def attention_finalize(carry, dtype):
    """Normalize a carried (m, l, acc) ring state to the attention output."""
    return ref.attention_finalize(carry, dtype)


def kv_block_gather(pool, tables, kv_len: int):
    """Paged-KV block-table lookup: the serving tier's cache view.

    ``pool (n, p, k, d)`` is the block pool (``n`` blocks of ``p`` cache
    rows); ``tables (b, w)`` int32 maps each sequence's block index to a
    pool row.  Returns the gathered time-ordered cache ``(b, k, t, d)``
    with ``t = kv_len`` — ``kv_len <= w*p``; the padded tail of the last
    block is truncated.  Pure gather + reshape, so the generic VJP is a
    scatter-add into the pool (pool grads only; tables are integer).
    """
    pool = jnp.asarray(pool)
    tables = jnp.asarray(tables).astype(jnp.int32)
    n, p, k, d = pool.shape
    b, w = tables.shape
    if kv_len > w * p:
        raise ValueError(
            f"kv_block_gather: kv_len={kv_len} exceeds the table capacity "
            f"w*p={w * p}")
    g = jnp.take(pool, tables.reshape(-1), axis=0)       # (b*w, p, k, d)
    g = g.reshape(b, w * p, k, d)[:, :kv_len]
    return jnp.transpose(g, (0, 2, 1, 3))                # (b, k, t, d)


def matmul(x, w, *, impl: str = "auto", **blocks):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.matmul(x, w)
    return _mm.matmul(x, w, interpret=not _on_tpu(), **blocks)


def gmm(x, w, *, impl: str = "auto", **blocks):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.gmm(x, w)
    return _gmm.gmm(x, w, interpret=not _on_tpu(), **blocks)
