"""Dispatching wrappers for the Pallas kernels.

``impl`` selects the implementation:
  * "auto"   — Pallas (compiled) on TPU, jnp reference elsewhere.  This is
               what the model stack calls: the dry-run on the CPU container
               lowers the XLA reference; on a real pod the same config runs
               the Pallas kernels.
  * "pallas" — Pallas, interpret-mode off-TPU (used by the kernel tests).
  * "ref"    — the pure-jnp oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import moe_gmm as _gmm
from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, scale=None, q_offset=0,
                    impl: str = "auto", blk_q: int = 128, blk_k: int = 128):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.attention(q, k, v, causal=causal, window=window, scale=scale,
                             q_offset=q_offset)
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, scale=scale, q_offset=q_offset,
        blk_q=blk_q, blk_k=blk_k, interpret=not _on_tpu())


def matmul(x, w, *, impl: str = "auto", **blocks):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.matmul(x, w)
    return _mm.matmul(x, w, interpret=not _on_tpu(), **blocks)


def gmm(x, w, *, impl: str = "auto", **blocks):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.gmm(x, w)
    return _gmm.gmm(x, w, interpret=not _on_tpu(), **blocks)
