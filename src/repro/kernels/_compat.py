"""Cross-version jax/pallas compat aliases shared by all kernels."""
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across pallas releases
COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
