"""`Program`: one object owning the graph → plan → cache → runner lifecycle.

The paper's workflow is declare → decompose → execute; before this module
the repo exposed it as four separate entry points (``eindecomp``,
``plan_for``, ``engine.make_runner``, ``policy_from_plan``) glued together
by integer node ids.  ``Program`` is the single surface:

    x = ein.tensor("x", "b a", (8, 64))
    w = ein.tensor("w", "a f", (64, 128))
    y = ein.einsum("b a, a f -> b f", x, w)
    prog = ein.Program({"y": y})
    run = prog.compile(p=8, cache="plans.json")     # eindecomp + plan cache
    out = run({"x": X, "w": W})["y"]                # name-keyed I/O

``compile`` runs EinDecomp through the persistent plan cache (a hit skips
the §8 DP exactly as with the raw entry points), and the result is a
jit-compiled callable taking and returning **name-keyed dicts**.  ``.plan``
exposes the decomposition, ``.lower()`` the per-node partitionings and
PartitionSpecs, ``.policy()`` the production ShardingPolicy projection, and
``Program.grad(wrt=...)`` derives the training program via
``core/autodiff`` — still a plain Program, so the same DP plans forward and
backward jointly (the paper's Experiment 2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Mapping, Sequence

import numpy as np

from repro.core.einsum import EinGraph
from repro.frontend.expr import Expr, trace


class Program:
    """A declared computation with named inputs and named outputs.

    Construct from expressions — ``Program(z)``, ``Program([z1, z2])`` or
    ``Program({"logits": z})`` — or from an already-traced graph with
    ``Program.from_graph``.  Tracing happens once, eagerly; ``.graph`` is
    the underlying ``EinGraph``.
    """

    def __init__(self, outputs, *, name: str = "program"):
        named = _normalize_outputs(outputs)
        self.name = name
        self.graph, ids = trace(list(named.values()), name)
        self._out: dict[str, int] = {k: ids[e] for k, e in named.items()}
        self._default_ones: frozenset[str] = frozenset()

    @classmethod
    def from_graph(cls, g: EinGraph, outputs: Mapping[str, int], *,
                   default_ones: Sequence[str] = (),
                   name: str | None = None) -> "Program":
        """Wrap an existing EinGraph (node-id outputs) as a Program.

        ``default_ones`` names inputs that default to ``ones`` when unfed —
        used for gradient seeds, so a grad program is callable with just the
        forward feeds.
        """
        self = cls.__new__(cls)
        self.name = name if name is not None else g.name
        self.graph = g
        self._out = {str(k): int(v) for k, v in outputs.items()}
        self._default_ones = frozenset(default_ones)
        names = [n.name for n in g.nodes if n.kind == "input"]
        dups = sorted({x for x in names if names.count(x) > 1})
        if dups:
            raise ValueError(f"from_graph: duplicate input names {dups} — "
                             "Program I/O is name-keyed")
        for k, v in self._out.items():
            if not 0 <= v < len(g.nodes):
                raise ValueError(f"from_graph: output {k!r} -> bad node id {v}")
        return self

    # -- introspection --------------------------------------------------------

    @property
    def input_names(self) -> tuple[str, ...]:
        return tuple(n.name for n in self.graph.nodes if n.kind == "input")

    @property
    def output_names(self) -> tuple[str, ...]:
        return tuple(self._out)

    def __repr__(self):
        ins = ", ".join(self.input_names)
        outs = ", ".join(self._out)
        return (f"Program({self.name!r}, {len(self.graph.nodes)} nodes, "
                f"inputs=[{ins}], outputs=[{outs}])")

    # -- autodiff -------------------------------------------------------------

    def grad(self, wrt: str | Sequence[str], *,
             output: str | None = None) -> "Program":
        """The training program: outputs the differentiated value plus
        ``grad_<name>`` for every input in ``wrt`` (core/autodiff reverse
        mode — the backward pass is EinSum nodes in the same graph, so one
        EinDecomp run plans fwd+bwd jointly).

        The gradient seed is an input named ``dLoss_seed`` that defaults to
        ones; feed it explicitly to chain an incoming cotangent.
        """
        from repro.core.autodiff import grad_graph

        if output is None:
            if len(self._out) != 1:
                raise ValueError(
                    f"grad: program has outputs {list(self._out)}; pass "
                    "output=<name> to pick the one to differentiate")
            output = next(iter(self._out))
        wrt_names = [wrt] if isinstance(wrt, str) else list(wrt)
        by_name = {n.name: n.nid for n in self.graph.nodes if n.kind == "input"}
        unknown = [w for w in wrt_names if w not in by_name]
        if unknown:
            raise KeyError(f"grad: unknown inputs {unknown}; "
                           f"inputs are {sorted(by_name)}")
        gg, grads, seed = grad_graph(self.graph, self._out[output],
                                     [by_name[w] for w in wrt_names])
        outs = {output: self._out[output]}
        outs.update({f"grad_{w}": grads[by_name[w]] for w in wrt_names})
        return Program.from_graph(
            gg, outs, default_ones=(gg.nodes[seed].name,),
            name=f"{self.name}:grad")

    # -- compile --------------------------------------------------------------

    def compile(self, *, mesh=None, mesh_axes: dict[str, int] | None = None,
                p: int | None = None, cost_model: str = "paper",
                cache=None, offpath_repart: bool = True,
                executor: str = "gspmd", jit: bool = True,
                fuse: bool = True, lookahead: int = 1,
                donate: bool | Sequence[str] = False,
                pipeline=None, plan=None) -> "CompiledProgram":
        """Run EinDecomp (through the plan cache) and build the runner.

        Planning inputs mirror ``eindecomp``/``make_runner``: a jax ``mesh``
        (or explicit ``mesh_axes``) selects torus-conformable mesh mode and
        attaches GSPMD sharding constraints; a bare ``p`` selects the
        paper's power-of-two mode (plan only, no constraints); neither
        means no planning at all — a plain jit-compiled runner.  ``cache``
        is a ``PlanCache`` or a path to its JSON store; a hit skips the §8
        DP entirely.  ``cost_model`` is ``"paper"``, ``"collective"``, or a
        ``core.cost.CostModel`` instance — e.g.
        ``CostModel.with_measured("costs.json")`` for pricing calibrated
        from ``bench_spmd.py --emit-costs`` constants (the calibration
        coefficients enter the plan-cache key).

        ``executor`` picks how the plan is realized (``engine.EXECUTORS``):
        ``"gspmd"`` lowers to sharding-constraint hints, ``"shard_map"``
        emits the plan's join→agg→repartition dataflow as explicit
        collectives (core/spmd.py; requires a ``mesh``).  The shard_map
        executor's static collective schedule is exposed as
        ``CompiledProgram.collectives``.

        ``fuse`` (shard_map only; default on) routes repartitions through
        the fused chain planner whenever the fused chain moves fewer wire
        elems (``fuse=False`` restores the unfused per-step lowering).
        ``lookahead`` (shard_map only; default 1) is the graph-wide overlap
        window: each ready consumer's arg repartitions issue up to that
        many compute nodes before the consumer, so the collectives fly
        while earlier local blocks compute — outputs are bit-identical,
        only the traced issue order changes.  ``lookahead=0`` restores the
        serial issue order verbatim (the equivalence baseline).
        ``donate=True`` donates **every** input buffer to the jit-compiled
        runner (``jax.jit(donate_argnums=...)``), letting XLA reuse the
        feeds' device memory for outputs and temporaries; a sequence of
        input names donates just those.  Donation invalidates the caller's
        fed jax arrays after the call (numpy feeds are copied to device
        and always safe), so it is strictly opt-in; requires ``jit=True``.

        ``pipeline=PipelineSpec(stages=p, microbatches=m)`` compiles the
        pipelined realization (repro.pipeline): the graph is cut into
        ``p`` stage subgraphs, each planned by the same §8 DP against the
        intra-stage submesh (warm through ``cache``), and lowered to ONE
        shard_map over the combined mesh running the GPipe cell schedule
        with ppermute handoffs over the ``spec.axis`` (default ``"pp"``)
        mesh axis — the mesh must carry that axis at size ``stages``.
        Outputs are bit-identical to the unpipelined compile; ``.plan``
        is the stitched full-graph plan and ``.pipeline_schedule`` the
        static schedule (cells, per-stage traces, bubble fraction).
        Requires ``executor='shard_map'``; donation is not supported.

        ``plan=`` short-circuits planning with a caller-supplied mesh-mode
        plan (e.g. the pipeline tier's stitched plan, to compile the exact
        bit-identity baseline) — mutually exclusive with ``pipeline=``.
        """
        from repro.core.decomp import eindecomp
        from repro.core.engine import EXECUTORS, mesh_axes_dict
        from repro.core.plancache import PlanCache

        if executor not in EXECUTORS:
            raise ValueError(f"compile: unknown executor {executor!r}; "
                             f"choose from {EXECUTORS}")
        cache = PlanCache.coerce(cache)
        # cost_model may be "paper" / "collective" or a CostModel instance
        # (e.g. CostModel.with_measured(...)); eindecomp handles both and
        # keys the plan cache on the calibration coefficients.
        if mesh is not None and mesh_axes is None:
            mesh_axes = mesh_axes_dict(mesh)
        if executor == "shard_map" and mesh is None:
            raise ValueError("compile: executor='shard_map' needs a jax "
                             "mesh (mesh_axes alone cannot place shards)")
        if pipeline is not None:
            if plan is not None:
                raise ValueError("compile: pipeline= builds its own "
                                 "stitched plan — plan= is mutually "
                                 "exclusive with it")
            if executor != "shard_map" or mesh is None:
                raise ValueError("compile: pipeline= needs "
                                 "executor='shard_map' and a jax mesh "
                                 "carrying the pipeline axis")
            if donate:
                raise ValueError("compile: donate is not supported with "
                                 "pipeline= — microbatch chunks alias the "
                                 "fed batch buffers")
            from repro.pipeline import build_pipeline_schedule

            psched = build_pipeline_schedule(
                self.graph, pipeline, mesh_axes,
                [self._out[k] for k in self._out],
                cache=cache, offpath_repart=offpath_repart,
                cost_mode=cost_model, fuse=fuse, lookahead=lookahead)
            return CompiledProgram(self, plan=psched.stitched, mesh=mesh,
                                   jit=jit, executor="shard_map", fuse=fuse,
                                   lookahead=lookahead,
                                   pipeline_schedule=psched)
        if plan is not None:
            pass  # caller-supplied plan (e.g. the stitched baseline)
        elif mesh_axes is not None or p is not None:
            if p is None:
                p = math.prod(mesh_axes.values())
            plan = eindecomp(self.graph, p, mesh_axes=mesh_axes,
                             cost_mode=cost_model,
                             offpath_repart=offpath_repart, cache=cache)
        elif cache is not None:
            raise ValueError("compile: cache given but nothing to plan "
                             "with — pass mesh, mesh_axes, or p")
        return CompiledProgram(self, plan=plan, mesh=mesh, jit=jit,
                               executor=executor, fuse=fuse,
                               lookahead=lookahead, donate=donate)


class CompiledProgram:
    """A jit-compiled, name-keyed callable over a planned Program.

    ``run({"x": X, ...})`` (or keyword form ``run(x=X, ...)``) returns
    ``{output name: array}``.  ``.plan`` is the EinDecomp result (None if
    compiled without planning inputs), ``.lower()`` the introspection
    surface, ``.policy()`` the production ShardingPolicy.  ``.executor``
    names the execution strategy; for ``"shard_map"``, ``.collectives`` is
    the static ``CollectiveTrace`` (count + wire bytes per collective kind)
    the program will execute — for ``"gspmd"`` it is None (XLA decides).
    ``.collectives_by_rule`` breaks the trace down per opaque shard rule
    (``"ring"`` / ``"a2a"`` / ``"replicate"``; ``""`` is the einsum path),
    and ``.collectives.rule_by_node`` records which rule lowered each
    opaque node.  ``.donate_argnums`` records which positional inputs the
    jit-compiled runner donates (empty unless compiled with ``donate``).
    ``.lookahead`` is the graph-wide overlap window the shard_map schedule
    was built with (``collectives.prefetched_elems`` counts the wire it
    hoisted; ``lookahead=0`` means serial issue order).
    """

    def __init__(self, program: Program, *, plan=None, mesh=None,
                 jit: bool = True, executor: str = "gspmd",
                 fuse: bool = True, lookahead: int = 1,
                 donate: bool | Sequence[str] = False,
                 pipeline_schedule=None):
        import jax

        from repro.core import engine

        self.program = program
        self.plan = plan
        self.mesh = mesh
        self.executor = executor
        self.lookahead = int(lookahead)
        self.collectives = None
        self.pipeline_schedule = pipeline_schedule
        g = program.graph
        self._in_ids = g.input_ids()
        self._in_names = tuple(g.nodes[i].name for i in self._in_ids)
        self._out_names = tuple(program._out)
        out_ids = [program._out[k] for k in self._out_names]
        in_ids = self._in_ids

        if pipeline_schedule is not None:
            from repro.pipeline.exec import make_pipeline_runner

            # the combined trace is static — built at schedule time, with
            # (stage, microbatch) attribution and rule="handoff" ppermutes
            self.collectives = pipeline_schedule.trace
            _positional = make_pipeline_runner(g, pipeline_schedule, mesh)
        elif executor == "shard_map":
            from repro.core import spmd

            self.collectives = spmd.CollectiveTrace()
            _positional = spmd.make_spmd_runner(
                g, out_ids, plan=plan, mesh=mesh, trace=self.collectives,
                fuse=fuse, lookahead=lookahead)
        else:
            def _positional(*arrays):
                vals = engine.run(g, dict(zip(in_ids, arrays)),
                                  plan=plan, mesh=mesh)
                return tuple(vals[o] for o in out_ids)

        self.donate_argnums = self._donate_argnums(donate)
        if self.donate_argnums and not jit:
            raise ValueError("donate needs jit=True — donation is a "
                             "jax.jit(donate_argnums=...) contract")
        if jit:
            self._fn = jax.jit(_positional,
                               donate_argnums=self.donate_argnums)
        else:
            self._fn = _positional

    def _donate_argnums(self, donate) -> tuple[int, ...]:
        if donate is False or donate is None:
            return ()
        if donate is True:
            return tuple(range(len(self._in_names)))
        names = list(donate)
        unknown = sorted(set(names) - set(self._in_names))
        if unknown:
            raise KeyError(f"donate: unknown inputs {unknown}; "
                           f"program inputs are {sorted(self._in_names)}")
        return tuple(i for i, n in enumerate(self._in_names) if n in names)

    @property
    def graph(self) -> EinGraph:
        return self.program.graph

    @property
    def canonical_key(self) -> str:
        """Stable identity of this compiled handle: the canonical graph key
        (same string the plan cache is keyed on — structurally identical
        programs collide by design) plus the planning signature.  The
        serving tier's bucket registry uses it to recognize that a shape
        cell already holds a live compiled handle across restarts/buckets."""
        from repro.core import canon

        gk = canon.graph_key(self.graph)
        if self.plan is None:
            return f"{gk}:unplanned:{self.executor}"
        return f"{gk}:p{self.plan.p}:{self.plan.mode}:{self.executor}"

    @property
    def collectives_by_rule(self) -> dict | None:
        """{rule: {kind: {count, elems, bytes}}} for the shard_map executor
        (None under gspmd) — the per-rule view of ``.collectives``."""
        return None if self.collectives is None else self.collectives.by_rule()

    def __call__(self, feeds: Mapping[str, Any] | None = None, /,
                 **kw) -> dict[str, Any]:
        feeds = {**(feeds or {}), **kw}
        for name in self.program._default_ones:
            if name not in feeds:
                node = next(n for n in self.graph.nodes
                            if n.kind == "input" and n.name == name)
                feeds[name] = np.ones(node.shape, node.dtype)
        unknown = sorted(set(feeds) - set(self._in_names))
        if unknown:
            raise KeyError(f"unknown inputs {unknown}; "
                           f"program inputs are {sorted(self._in_names)}")
        missing = sorted(n for n in self._in_names if n not in feeds)
        if missing:
            raise ValueError(f"missing feeds for inputs {missing}")
        outs = self._fn(*[feeds[n] for n in self._in_names])
        return dict(zip(self._out_names, outs))

    def grad(self, wrt: str | Sequence[str], *,
             output: str | None = None) -> "Program":
        """Convenience: the (uncompiled) gradient program — compile it with
        the planning inputs of your choice."""
        return self.program.grad(wrt, output=output)

    def policy(self, *, fsdp_axes: Sequence[str] = (), remat: bool = True):
        """Collapse the mesh-mode plan to the production ``ShardingPolicy``
        (models/policy.py) the model stack applies via GSPMD."""
        from repro.models.policy import policy_from_plan

        if self.plan is None:
            raise ValueError("policy(): program was compiled without "
                             "planning inputs (no plan)")
        return policy_from_plan(self.plan, self.graph,
                                fsdp_axes=tuple(fsdp_axes), remat=remat)

    def lower(self) -> "LoweredProgram":
        """Introspection: the traced graph, the plan, and (in mesh mode)
        the per-node PartitionSpecs GSPMD will be constrained with."""
        shardings = None
        if self.plan is not None and self.plan.axes_by_node:
            from repro.core.engine import spec_for_node

            shardings = {
                n.nid: spec_for_node(n, self.plan.axes_by_node.get(n.nid, {}))
                for n in self.graph.nodes}
        return LoweredProgram(graph=self.graph, plan=self.plan,
                              shardings=shardings,
                              outputs=dict(self.program._out))


@dataclass
class LoweredProgram:
    """What ``CompiledProgram.lower()`` returns: everything between the
    declaration and the executable, in one inspectable object."""

    graph: EinGraph
    plan: Any
    shardings: dict[int, Any] | None
    outputs: dict[str, int]

    def as_text(self) -> str:
        lines = [repr(self.graph)]
        if self.plan is not None:
            lines.append(f"plan: p={self.plan.p} mode={self.plan.mode} "
                         f"cost={self.plan.cost:,} floats")
            for nid in sorted(self.plan.d_by_node):
                n = self.graph.nodes[nid]
                d = self.plan.d_by_node[nid]
                extra = ""
                if self.shardings is not None and nid in self.shardings:
                    extra = f"  {self.shardings[nid]}"
                lines.append(f"  [{nid:3d}] {n.name:20s} d={d}{extra}")
        outs = ", ".join(f"{k}=[{v}]" for k, v in self.outputs.items())
        lines.append(f"outputs: {outs}")
        return "\n".join(lines)

    def __repr__(self):
        return self.as_text()


def _normalize_outputs(outputs) -> dict[str, Expr]:
    if isinstance(outputs, Expr):
        outputs = [outputs]
    if isinstance(outputs, Mapping):
        named = {str(k): v for k, v in outputs.items()}
    else:
        named = {}
        for i, e in enumerate(outputs):
            if not isinstance(e, Expr):
                raise TypeError(f"Program: output {i} is {type(e).__name__}, "
                                "expected Expr")
            key = e.name or f"out{i}"
            if key in named:
                raise ValueError(f"Program: duplicate output name {key!r} — "
                                 "pass a dict to name outputs explicitly")
            named[key] = e
    if not named:
        raise ValueError("Program: no outputs")
    for k, e in named.items():
        if not isinstance(e, Expr):
            raise TypeError(f"Program: output {k!r} is {type(e).__name__}, "
                            "expected Expr")
    return named
