"""The declarative expression frontend (usually imported as ``ein``):

    from repro import frontend as ein

    x = ein.tensor("x", "b s a", (4, 128, 256))
    w = ein.tensor("w", "a f", (256, 1024))
    h = ein.einsum("b s a, a f -> b s f", x, w).map("silu")
    prog = ein.Program({"h": h})
    run = prog.compile(mesh_axes={"data": 4, "model": 2}, cache="plans.json")
    out = run({"x": X, "w": W})["h"]

``expr.py`` holds the lazy symbolic-tensor layer (declaration + trace into
the EinGraph IR), ``program.py`` the Program/CompiledProgram lifecycle
(graph → plan → cache → runner).
"""
from repro.frontend.expr import (Expr, einsum, map_, maximum, opaque,
                                 register_opaque, tensor, trace)
from repro.frontend.program import CompiledProgram, LoweredProgram, Program

__all__ = [
    "Expr", "einsum", "map_", "maximum", "opaque", "register_opaque",
    "tensor", "trace", "Program", "CompiledProgram", "LoweredProgram",
]
