"""The declarative expression frontend (usually imported as ``ein``):

    from repro import frontend as ein

    x = ein.tensor("x", "b s a", (4, 128, 256))
    w = ein.tensor("w", "a f", (256, 1024))
    h = ein.einsum("b s a, a f -> b s f", x, w).map("silu")
    prog = ein.Program({"h": h})
    run = prog.compile(mesh_axes={"data": 4, "model": 2}, cache="plans.json")
    out = run({"x": X, "w": W})["h"]

``expr.py`` holds the lazy symbolic-tensor layer (declaration + trace into
the EinGraph IR), ``program.py`` the Program/CompiledProgram lifecycle
(graph → plan → cache → runner).

New fused ops are declared once through the unified OpDef API —
``ein.defop`` (or the ``@ein.op`` decorator): one record bundling the
einsum-style label signature, dense impl, optional accelerator kernel,
VJP rule, comm declaration, and shard-rule binding.  ``ein.opaque`` then
infers shapes/labels from the signature, ``Program.grad`` differentiates
through the op, the DP prices its comm, and the shard_map executor lowers
it per shard.  (``register_opaque`` survives as a deprecation shim.)
"""
from repro.frontend.expr import (Expr, defop, einsum, map_, maximum, op,
                                 opaque, register_opaque, tensor, trace)
from repro.frontend.program import CompiledProgram, LoweredProgram, Program

__all__ = [
    "Expr", "defop", "einsum", "map_", "maximum", "op", "opaque",
    "register_opaque", "tensor", "trace", "Program", "CompiledProgram",
    "LoweredProgram",
]
