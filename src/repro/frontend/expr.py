"""Lazy symbolic tensors: the paper's declarative notation as Python values.

The paper's whole pitch (§1–3) is that the *programming abstraction* is a
fully declarative extended einsum — the user writes

    Z[l_Z]  <-  AGG_{l_agg}  COMBINE( X[l_X], Y[l_Y] )

and never talks about devices, partitionings, or node ids.  This module is
that surface: ``tensor(...)`` declares a named input, ``einsum(...)`` an
extended (⊗,⊕) node, operator overloading covers the elementwise ⊗ forms
(``x + y``, ``x * y``, ``x - y``, ``x / y``, scalar broadcasts as ``map``
nodes), and ``opaque(...)`` admits fused ops the notation cannot express
(flash attention, MoE dispatch, recurrent scans) while still carrying the
label metadata EinDecomp needs.

Expressions are *lazy*: building one does no numerics, it only records
structure.  ``trace(outputs)`` emits the reachable expressions into the
existing ``core.einsum.EinGraph`` IR — inputs keep their declared **names**
(the graph is then fed by name, not node id) and emission follows expression
*creation order*.  Creation order is topological (operands are constructed
before their consumers), and it reproduces node-for-node the sequence an
imperative ``EinGraph`` builder writing the same computation would produce,
so canonical graph keys (``core/canon.py``) — and therefore plan-cache
entries — are identical across the two surfaces.
"""
from __future__ import annotations

import itertools
import os
import sys
from typing import Any, Iterable, Sequence

import numpy as np

from repro.core.einsum import EinGraph, EinSpec, parse_einsum, _as_labels

_UID = itertools.count()

_FRONTEND_DIR = os.path.dirname(os.path.abspath(__file__))


def _caller_srcloc() -> str:
    """``"path/to/file.py:line"`` of the first stack frame *outside* this
    package — the user (or model-zoo) line that built the expression.  The
    static analyzer (``repro.analysis``) reports findings at these
    locations; canonical graph hashing never sees them (``canon.node_struct``
    enumerates hashed Node fields explicitly)."""
    f = sys._getframe(1)
    while f is not None:
        fn = f.f_code.co_filename
        if os.path.dirname(os.path.abspath(fn)) != _FRONTEND_DIR:
            return f"{fn}:{f.f_lineno}"
        f = f.f_back
    return ""


class Expr:
    """One node of a lazy expression DAG (input | einsum | map | opaque).

    Carries exactly the information its ``EinGraph`` node will carry —
    labels, shape, dtype, spec/op/params — plus references to its operand
    expressions instead of integer node ids.
    """

    __slots__ = ("uid", "kind", "name", "labels", "shape", "dtype", "args",
                 "spec", "op", "params", "shardable", "in_labels", "srcloc")

    def __init__(self, kind: str, labels: tuple[str, ...],
                 shape: tuple[int, ...], dtype: Any, *,
                 name: str = "", args: tuple["Expr", ...] = (),
                 spec: EinSpec | None = None, op: str = "",
                 params: dict | None = None,
                 shardable: frozenset[str] | None = None,
                 in_labels: tuple[tuple[str, ...], ...] = ()):
        self.uid = next(_UID)
        self.kind = kind
        self.name = name
        self.labels = tuple(labels)
        self.shape = tuple(int(s) for s in shape)
        self.dtype = dtype
        self.args = tuple(args)
        self.spec = spec
        self.op = op
        self.params = dict(params or {})
        self.shardable = shardable
        self.in_labels = tuple(tuple(ls) for ls in in_labels)
        self.srcloc = _caller_srcloc()

    # -- structure -----------------------------------------------------------

    @property
    def rank(self) -> int:
        return len(self.shape)

    def __repr__(self):
        lbl = " ".join(self.labels)
        op = self.spec.pretty() if self.spec else (self.op or self.kind)
        nm = f" {self.name!r}" if self.name else ""
        return f"<Expr{nm} {self.kind} [{lbl}] {self.shape} {op}>"

    # -- elementwise sugar ---------------------------------------------------
    # Binary ops between label-aligned expressions lower to elementwise
    # einsum nodes (agg=""); scalars lower to map nodes so constants never
    # become graph inputs (core/einsum.py map rationale).

    def _ew(self, other, combine: str, reverse: bool = False):
        if isinstance(other, Expr):
            if self.labels != other.labels:
                raise ValueError(
                    f"elementwise {combine}: labels {self.labels} vs "
                    f"{other.labels}; use einsum(...) for non-aligned operands")
            a, b = (other, self) if reverse else (self, other)
            s = " ".join(self.labels)
            return einsum(f"{s}, {s} -> {s}", a, b, combine=combine, agg="")
        return NotImplemented

    def __add__(self, other):
        if isinstance(other, (int, float)):
            return self.map("add_const", c=float(other))
        return self._ew(other, "add")

    __radd__ = __add__

    def __sub__(self, other):
        if isinstance(other, (int, float)):
            return self.map("add_const", c=-float(other))
        return self._ew(other, "sub")

    def __rsub__(self, other):
        if isinstance(other, (int, float)):
            return self.map("neg").map("add_const", c=float(other))
        return self._ew(other, "sub", reverse=True)

    def __mul__(self, other):
        if isinstance(other, (int, float)):
            return self.map("scale", c=float(other))
        return self._ew(other, "mul")

    __rmul__ = __mul__

    def __truediv__(self, other):
        if isinstance(other, (int, float)):
            return self.map("scale", c=1.0 / float(other))
        return self._ew(other, "div")

    def __rtruediv__(self, other):
        if isinstance(other, Expr):
            return self._ew(other, "div", reverse=True)
        return NotImplemented

    def __neg__(self):
        return self.map("neg")

    def __pow__(self, e):
        if e == 2:
            return self.map("square")
        return NotImplemented

    def map(self, fn: str, *, name: str = "", **params) -> "Expr":
        """Unary elementwise map (``relu``, ``scale``, … — the map-category
        ops of the OpDef registry, ``opdef.list_ops("map")``)."""
        return Expr("map", self.labels, self.shape, self.dtype,
                    name=name, args=(self,), op=fn, params=params)


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------


def tensor(name: str, labels: str | Sequence[str], shape: Sequence[int],
           dtype=np.float32) -> Expr:
    """Declare a named input tensor: ``tensor("x", "b s a", (4, 128, 256))``.

    The name is the feed key of the compiled program — inputs are name-based
    end to end, never integer node ids.
    """
    if not name:
        raise ValueError("tensor: inputs must be named (they are fed by name)")
    labels = _as_labels(labels)
    shape = tuple(int(s) for s in shape)
    if len(labels) != len(shape):
        raise ValueError(f"{name}: {len(labels)} labels vs rank {len(shape)}")
    return Expr("input", labels, shape, dtype, name=name)


def einsum(expr: str, *args: Expr, combine: str | None = None,
           agg: str | None = None, name: str = "") -> Expr:
    """Extended einsum over expressions: ``einsum("b s a, a f -> b s f", x,
    w)``, with the paper's full (⊗,⊕) generality via ``combine=``/``agg=``
    (``agg=""`` means elementwise — no aggregation).

    Defaults mirror the IR: binary nodes combine with ``mul``, unary with
    ``id``; ``agg`` defaults to ``sum`` when any label is contracted, else
    elementwise.
    """
    in_labels, out_labels = parse_einsum(expr)
    if len(args) != len(in_labels):
        raise ValueError(f"{expr}: expected {len(in_labels)} args, got {len(args)}")
    for a in args:
        if not isinstance(a, Expr):
            raise TypeError(f"{expr}: operands must be Exprs, got {type(a).__name__}")
    if combine is None:
        combine = "mul" if len(in_labels) == 2 else "id"
    tmp = EinSpec(in_labels, out_labels, combine, "sum")
    if agg is None:
        agg = "sum" if tmp.agg_labels else ""
    spec = EinSpec(in_labels, out_labels, combine, agg)
    bounds: dict[str, int] = {}
    for ls, a in zip(in_labels, args):
        if len(ls) != a.rank:
            raise ValueError(f"{expr}: operand rank {a.rank} vs labels {ls}")
        for l, b in zip(ls, a.shape):
            if bounds.setdefault(l, b) != b:
                raise ValueError(f"{expr}: label {l} bound mismatch "
                                 f"{bounds[l]} vs {b}")
    shape = tuple(bounds[l] for l in out_labels)
    return Expr("einsum", out_labels, shape, args[0].dtype,
                name=name, args=args, spec=spec)


def opaque(kind: str, args: Sequence[Expr],
           out_labels: str | Sequence[str] | None = None,
           out_shape: Sequence[int] | None = None, *,
           in_labels: Sequence[Sequence[str]] = (),
           shardable: Iterable[str] | None = None, dtype=None,
           name: str = "", **params) -> Expr:
    """A fused op the notation cannot express (flash attention, MoE
    dispatch, recurrent scan).

    For a kind registered through :func:`defop` (``ein.defop`` /
    ``@ein.op``), everything is inferred from the OpDef's label signature:
    output labels and shape, dtype, and the ``shardable`` set — all renamed
    into the caller's instance labels (pass ``in_labels`` to rename, e.g.
    flash attention's ring label ``l`` becomes ``s`` in prefill and ``t``
    in decode; omit it to use the signature's labels verbatim).  Label
    bounds are cross-validated against every argument at build time, and
    any explicitly-passed ``out_labels``/``out_shape`` is checked against
    the inference instead of trusted.  The comm declaration and shard rule
    live on the OpDef and are resolved at plan time — they are no longer
    embedded per call.

    Unregistered kinds fall back to the historical fully-explicit form
    (``out_labels`` + ``out_shape`` required).
    """
    from repro.core import opdef as _opdef

    args = tuple(args)
    od = _opdef.get(kind)
    if od is not None and od.signature is not None:
        bound = _opdef.bind_call(
            od, [a.shape for a in args], in_labels=in_labels,
            out_labels=_as_labels(out_labels) if out_labels is not None
            else None, params=params)
        if out_shape is not None and tuple(int(s) for s in out_shape) != \
                bound["out_shape"]:
            raise _opdef.OpDefError(
                f"{kind}: caller-supplied out_shape "
                f"{tuple(int(s) for s in out_shape)} contradicts the "
                f"signature-inferred {bound['out_shape']}")
        out_labels = bound["out_labels"]
        out_shape = bound["out_shape"]
        in_labels = bound["in_labels"]
        if shardable is None:
            shardable = bound["shardable"]
        if dtype is None and od.out_dtype is not None:
            dtype = od.out_dtype
    elif out_labels is None or out_shape is None:
        raise ValueError(
            f"opaque({kind!r}): kind is not registered (or has no "
            "signature) — pass out_labels and out_shape explicitly, or "
            f"declare the op once with ein.defop({kind!r}, '<signature>', "
            "fn=...)")
    out_labels = _as_labels(out_labels)
    dtype = dtype if dtype is not None else args[0].dtype
    return Expr("opaque", out_labels, tuple(int(s) for s in out_shape), dtype,
                name=name, args=args, op=kind, params=params,
                shardable=frozenset(shardable) if shardable is not None else None,
                in_labels=tuple(tuple(ls) for ls in in_labels))


def maximum(x: Expr, y: Expr, name: str = "") -> Expr:
    """Elementwise max of two label-aligned expressions."""
    if not isinstance(y, Expr):
        raise TypeError(f"maximum: operands must be Exprs, got "
                        f"{type(y).__name__}")
    out = x._ew(y, "maximum")
    if name:
        out.name = name
    return out


def map_(fn: str, x: Expr, *, name: str = "", **params) -> Expr:
    """Function form of ``Expr.map`` (``map`` shadows the builtin)."""
    return x.map(fn, name=name, **params)


def defop(kind: str, signature: str | None = None, **kw):
    """Declare one op kind as a single record — signature, dense impl,
    kernel dispatcher, VJP rule, comm declaration, shard rule (the unified
    ``core.opdef.defop``; see its docstring for every field)::

        ein.defop("my_fused", "b s f, f -> b s f",
                  fn=my_dense_impl, vjp="auto",
                  shardable="b s", shard_rule="local")

    After this single declaration, ``ein.opaque("my_fused", [x, g])``
    infers shapes/labels, ``Program.grad`` differentiates through it, the
    DP prices its declared comm, and the shard_map executor lowers it via
    its bound rule — no edits anywhere else.
    """
    from repro.core import opdef as _opdef

    return _opdef.defop(kind, signature, **kw)


def op(kind: str, signature: str | None = None, **kw):
    """Decorator sugar for :func:`defop`: the decorated function becomes
    the op's dense reference implementation::

        @ein.op("l2norm", "b s f -> b s f", shardable="b s",
                shard_rule="local", vjp="auto")
        def l2norm(x, eps=1e-6):
            ...
    """

    def wrap(fn):
        defop(kind, signature, fn=fn, **kw)
        return fn

    return wrap


def register_opaque(name: str, fn) -> None:
    """Deprecated: use :func:`defop` — one declarative record (signature,
    impl, kernel, vjp, comm, shard rule) instead of a bare impl."""
    from repro.core import opdef as _opdef

    _opdef.register_legacy(name, fn, surface="frontend.register_opaque")


# ---------------------------------------------------------------------------
# Tracing: Expr DAG -> EinGraph
# ---------------------------------------------------------------------------


def trace(outputs: Sequence[Expr], name: str = "program"
          ) -> tuple[EinGraph, dict[Expr, int]]:
    """Emit every expression reachable from ``outputs`` into an EinGraph.

    Returns ``(graph, {expr: node id})``.  Inputs keep their declared names
    and must be unique within one program (they are the feed keys).  Nodes
    are emitted in expression *creation order* — topological by
    construction, and identical to what an imperative builder writing the
    same calls would produce, so canonical keys and plan-cache entries are
    shared across the two surfaces.
    """
    reachable: dict[int, Expr] = {}
    stack = list(outputs)
    while stack:
        e = stack.pop()
        if not isinstance(e, Expr):
            raise TypeError(f"trace: outputs must be Exprs, got {type(e).__name__}")
        if e.uid in reachable:
            continue
        reachable[e.uid] = e
        stack.extend(e.args)

    g = EinGraph(name)
    ids: dict[Expr, int] = {}
    input_names: dict[str, Expr] = {}
    for e in sorted(reachable.values(), key=lambda e: e.uid):
        if e.kind == "input":
            prev = input_names.get(e.name)
            if prev is not None and prev is not e:
                raise ValueError(
                    f"trace: duplicate input name {e.name!r} — inputs are "
                    "fed by name and must be unique within a program")
            input_names[e.name] = e
            nid = g.input(e.name, e.labels, e.shape, e.dtype)
        elif e.kind == "einsum":
            nid = g.einsum(e.spec.pretty(), *[ids[a] for a in e.args],
                           combine=e.spec.combine, agg=e.spec.agg, name=e.name)
        elif e.kind == "map":
            nid = g.map(e.op, ids[e.args[0]], name=e.name, **e.params)
        else:
            nid = g.opaque(e.op, [ids[a] for a in e.args], e.labels, e.shape,
                           in_labels=e.in_labels, shardable=e.shardable,
                           dtype=e.dtype, name=e.name, **e.params)
        g.nodes[nid].srcloc = e.srcloc
        ids[e] = nid
    return g, ids
