"""Forcing a multi-device host platform, politely.

``--xla_force_host_platform_device_count`` only takes effect if it is in
``XLA_FLAGS`` *before* jax initializes its backends.  This helper appends it
(never clobbering a user-set ``XLA_FLAGS``, never duplicating the flag) and
skips the mutation when jax's backends are already up — at that point the
env var would silently do nothing, so the honest move is to leave the
environment untouched.

jax-free on purpose: callers (launch/dryrun.py, benchmarks/bench_spmd.py)
import it before their first ``import jax``.
"""
from __future__ import annotations

import os
import sys


def force_host_devices(n: int = 512) -> None:
    """Append ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``
    unless the flag is already set or jax can no longer honor it."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in flags:
        return
    if "jax" in sys.modules:
        try:
            from jax._src import xla_bridge

            if xla_bridge.backends_are_initialized():
                return  # too late: the env var would be ignored
        except (ImportError, AttributeError):
            # private API moved: set the flag anyway — harmless if backends
            # are already up (ignored), required if they are not
            pass
    os.environ["XLA_FLAGS"] = (
        (f"{flags} " if flags else "")
        + f"--xla_force_host_platform_device_count={n}")
