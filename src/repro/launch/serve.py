"""Serving driver: batched prefill + decode loop with KV/state caches.

``serve`` takes a batch of prompts, prefills them in one fused forward
(returning per-layer caches), then decodes greedily token-by-token with the
jitted serve_step.  Sliding-window archs keep ring-buffer caches, recurrent
archs carry constant-size state — the 500k-token decode shape runs in O(1)
memory per token (docs/architecture.md, "Serving tier").

``--continuous`` switches to the serving tier proper
(``repro.serving.ServingEngine``): slot-based continuous batching over a
paged KV-block pool, with prefill programs resolved through the
shape-bucket registry and the plan cache.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch import steps
from repro.launch.mesh import make_host_mesh, mesh_axes_dict
from repro.models import transformer as tf
from repro.models.attention import KVCache
from repro.models.eingraphs import program_for


def _ring_pack(cache_kv: KVCache, prompt_len: int, window: int) -> KVCache:
    """Re-pack a prefill cache (time-ordered) into decode ring order.
    Layout is (L, b, S, kh, hd) stacked per unit."""
    take = min(window, prompt_len)
    slots = (prompt_len - take + np.arange(take)) % window

    def pack(x):
        ring = jnp.zeros(x.shape[:2] + (window,) + x.shape[3:], x.dtype)
        src = x[:, :, prompt_len - take:prompt_len]
        return ring.at[:, :, slots].set(src)

    return KVCache(pack(cache_kv.k), pack(cache_kv.v))


def prepare_decode_caches(cfg, prefill_caches, prompt_len: int, kv_len: int):
    """Convert prefill-collected caches into decode-ready buffers."""
    out = []
    for blk, cache in zip(cfg.block_pattern, prefill_caches):
        if blk in ("attn", "hymba"):
            kv = cache[0] if blk == "hymba" else cache
            k, v = kv
            if cfg.window:
                kv2 = _ring_pack(KVCache(k, v), prompt_len, kv_len)
            else:
                pad = kv_len - k.shape[2]
                k2 = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                v2 = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                kv2 = KVCache(k2, v2)
            out.append((kv2, cache[1]) if blk == "hymba" else kv2)
        else:
            out.append(cache)
    return out


def decode_loop(decode, params, caches, first_tok, prompt_len: int,
                max_new: int):
    """Greedy decode: ``max_new`` tokens total — the prefill's argmax plus
    ``max_new - 1`` decode steps, every step's logits consumed.

    (The historical loop appended the prefill token first but still ran
    ``max_new`` decode steps, so the final call's logits were computed and
    thrown away — one wasted step per request, and a tok/s figure counting
    a token the decode path never produced.)

    Tokens are accumulated **on device** and fetched with a single host
    transfer at the end: the previous ``np.asarray(tok)`` per iteration
    blocked the host on every step, serializing dispatch against compute
    and capping tok/s at the round-trip latency — greedy argmax feeds the
    next step from device memory just fine, so the loop now runs fully
    async under jax's dispatch queue.

    Returns ``(generations (b, max_new) int32, caches, decode_steps)``.
    """
    b = first_tok.shape[0]
    if max_new <= 0:
        return np.zeros((b, 0), np.int32), caches, 0
    outs = [first_tok]
    tok = first_tok
    steps = 0
    for i in range(max_new - 1):
        logits, caches = decode(params, tok, caches, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(tok)
        steps += 1
    return np.asarray(jnp.concatenate(outs, axis=1)), caches, steps


def serve(cfg, prompts: np.ndarray, *, max_new: int = 32, mesh=None,
          kv_len: int | None = None, params=None, greedy: bool = True,
          seed: int = 0, plan_cache=None, executor: str = "gspmd"):
    """prompts: (b, prompt_len) int32.  Returns (b, max_new) generations.

    ``plan_cache`` is a ``core.plancache.PlanCache`` or a path to its JSON
    store: the planner warm-starts from it (a structurally identical graph
    planned by any earlier process is a cache hit, skipping the §8 DP) and
    persists the plan it used for the next restart.

    ``executor`` selects how the cell's Program realizes its plan
    (``engine.EXECUTORS``); with ``"shard_map"`` the compiled program's
    static collective schedule is printed (the serving steps themselves
    still run the production transformer stack under the derived policy).
    """
    mesh = mesh or make_host_mesh()
    b, prompt_len = prompts.shape
    kv_len = kv_len or (cfg.kv_len(ShapeConfig("serve", "decode",
                                               prompt_len + max_new, b)))
    shape = ShapeConfig("serve", "prefill", prompt_len, b)
    # declare -> trace -> decompose (through the plan cache) -> project:
    # the serving path runs entirely on the Program surface.
    compiled = program_for(cfg, shape).compile(
        mesh_axes=mesh_axes_dict(mesh), cache=plan_cache,
        mesh=mesh if executor == "shard_map" else None, executor=executor)
    policy = compiled.policy()
    if compiled.collectives is not None:
        print(f"[serve] shard_map executor schedule for {cfg.name}:")
        print(compiled.collectives.summary())

    if params is None:
        params = tf.init_params(cfg, jax.random.PRNGKey(seed))
    params = jax.device_put(params, tf.param_shardings(cfg, policy, mesh))

    prefill = jax.jit(steps.make_prefill_step(cfg, policy=policy, mesh=mesh))
    decode = jax.jit(steps.make_serve_step(cfg, policy=policy, mesh=mesh),
                     donate_argnums=(2,))

    t0 = time.time()
    logits, caches = prefill(params, {"tokens": jnp.asarray(prompts)})
    caches = prepare_decode_caches(cfg, caches, prompt_len, kv_len)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.time()
    gen, caches, decode_steps = decode_loop(decode, params, caches, tok,
                                            prompt_len, max_new)
    t_decode = time.time() - t0
    return gen, {"t_prefill_s": t_prefill, "t_decode_s": t_decode,
                 "decode_steps": decode_steps,
                 "tok_per_s": b * decode_steps / max(t_decode, 1e-9)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--plan-cache", default=None,
                    help="path to a persistent plan-cache JSON store; "
                         "warm-starts the planner across restarts")
    ap.add_argument("--executor", default="gspmd",
                    choices=["gspmd", "shard_map"],
                    help="plan realization: GSPMD sharding hints, or the "
                         "explicit-collective shard_map executor "
                         "(prints its static collective schedule)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching engine (repro.serving): "
                         "slot scheduler + paged KV pool + bucket registry; "
                         "prompts get mixed lengths around --prompt-len")
    ap.add_argument("--requests", type=int, default=8,
                    help="[--continuous] number of requests to submit")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="[--continuous] KV pool block size (cache rows)")
    ap.add_argument("--max-seq", type=int, default=0,
                    help="[--continuous] per-request capacity ceiling "
                         "(prompt+generated); default prompt-len + max-new")
    ap.add_argument("--bucket", default="auto",
                    choices=["auto", "pow2", "exact"],
                    help="[--continuous] prefill bucket policy: pow2 "
                         "rounding for pad-free archs under 'auto'")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    rng = np.random.default_rng(0)

    if args.continuous:
        from repro.serving import ServingEngine

        max_seq = args.max_seq or (args.prompt_len + args.max_new)
        eng = ServingEngine(cfg, batch=args.batch, max_seq=max_seq,
                            block=args.kv_block, plan_cache=args.plan_cache,
                            bucket=args.bucket)
        for _ in range(args.requests):
            plen = int(rng.integers(max(1, args.prompt_len // 2),
                                    args.prompt_len + 1))
            eng.submit(rng.integers(0, cfg.vocab, size=(plen,)), args.max_new)
        results, metrics = eng.run()
        for rid in sorted(results):
            print(f"request {rid}: {results[rid]}")
        print(metrics.summary())
        print(eng.registry.stats)
        return

    prompts = rng.integers(0, cfg.vocab,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    gen, stats = serve(cfg, prompts, max_new=args.max_new,
                       plan_cache=args.plan_cache, executor=args.executor)
    print("generations:\n", gen)
    print(stats)


if __name__ == "__main__":
    main()
