"""Compiled-HLO analysis: wire-accurate collective bytes with while-loop
trip-count accounting.

XLA's ``cost_analysis`` counts a while body ONCE regardless of trip count
(verified on this container), so naive parsing undercounts scanned models by
a factor of n_layers.  This walker:

  * splits the HLO module into computations,
  * sums collective wire bytes per computation (ring-model costs below),
  * finds ``while`` ops, reads the trip count from the loop-condition
    computation's compare-against-constant, and multiplies,
  * walks call edges (while bodies, conditionals) from ENTRY.

Wire bytes per op with result bytes R and replica-group size k:
  all-reduce          2 (k-1)/k R     (ring = reduce-scatter + all-gather)
  all-gather          (k-1)/k R       (R = gathered output)
  reduce-scatter      (k-1) R         (input = k R moves (k-1)/k of itself)
  all-to-all          (k-1)/k R
  collective-permute  R
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)"
    r"\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_COND_CALL_RE = re.compile(
    r"conditional\(.*?\),.*?branch_computations=\{([^}]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        numel = 1
        for d in m.group(2).split(","):
            if d:
                numel *= int(d)
        total += numel * _DTYPE_BYTES[m.group(1)]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return default


def _wire_bytes(kind: str, result_bytes: int, k: int) -> float:
    if k <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (k - 1) / k * result_bytes
    if kind == "all-gather":
        return (k - 1) / k * result_bytes
    if kind == "reduce-scatter":
        return float((k - 1) * result_bytes)
    if kind == "all-to-all":
        return (k - 1) / k * result_bytes
    return float(result_bytes)  # collective-permute


@dataclass
class _Comp:
    name: str
    coll_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    op_bytes_sum: int = 0       # plain operand-size sum (the brief's metric)
    whiles: list = field(default_factory=list)       # (cond, body)
    branches: list = field(default_factory=list)     # conditional branches
    max_const: int = 1


def parse_collectives(hlo_text: str, n_devices: int
                      ) -> tuple[float, dict[str, float], float]:
    """Returns (wire_bytes_per_device, by_kind, plain_operand_sum) with
    while-loop trip counts applied."""
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    entry: str | None = None

    for raw in hlo_text.splitlines():
        line = raw.strip()
        hdr = None
        if raw and not raw.startswith(" ") and raw.rstrip().endswith("{") \
                and "->" in raw:
            hdr = _COMP_HDR.match(raw)
        if hdr:
            cur = _Comp(hdr.group(1))
            comps[cur.name] = cur
            if raw.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None or "=" not in line:
            continue
        for m in _CONST_RE.finditer(line):
            cur.max_const = max(cur.max_const, int(m.group(1)))
        wm = _WHILE_RE.search(line)
        if wm:
            cur.whiles.append((wm.group(1), wm.group(2)))
        cm = _COND_CALL_RE.search(line)
        if cm:
            cur.branches.extend(
                x.strip().lstrip("%") for x in cm.group(1).split(","))
        for kind in _COLL_KINDS:
            token = f" {kind}("
            token_s = f" {kind}-start("
            if token in line or token_s in line:
                lhs = line.split("=", 1)[1]
                pos = lhs.find(f"{kind}-start(")
                if pos < 0:
                    pos = lhs.find(f"{kind}(")
                result = lhs[:pos]
                rb = _shape_bytes(result)
                k = _group_size(line, n_devices)
                if f"{kind}-start(" in line and kind == "all-reduce":
                    # async start result carries (operand, result): halve
                    rb //= 2
                wb = _wire_bytes(kind, rb, k)
                cur.coll_bytes += wb
                cur.coll_by_kind[kind] = cur.coll_by_kind.get(kind, 0.0) + wb
                cur.op_bytes_sum += rb
                break

    if entry is None:
        return 0.0, {}, 0.0

    memo: dict[str, tuple[float, dict, float]] = {}

    def total(name: str, depth=0) -> tuple[float, dict, float]:
        if name in memo or depth > 64:
            return memo.get(name, (0.0, {}, 0.0))
        c = comps.get(name)
        if c is None:
            return 0.0, {}, 0.0
        bytes_ = c.coll_bytes
        kinds = dict(c.coll_by_kind)
        plain = float(c.op_bytes_sum)
        for cond, body in c.whiles:
            trips = comps[cond].max_const if cond in comps else 1
            b, kk, pl = total(body, depth + 1)
            bc, kkc, plc = total(cond, depth + 1)
            bytes_ += trips * (b + bc)
            plain += trips * (pl + plc)
            for kname, v in kk.items():
                kinds[kname] = kinds.get(kname, 0.0) + trips * v
            for kname, v in kkc.items():
                kinds[kname] = kinds.get(kname, 0.0) + trips * v
        for br in c.branches:
            b, kk, pl = total(br, depth + 1)
            bytes_ += b
            plain += pl
            for kname, v in kk.items():
                kinds[kname] = kinds.get(kname, 0.0) + v
        memo[name] = (bytes_, kinds, plain)
        return memo[name]

    return total(entry)
