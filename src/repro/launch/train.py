"""Training driver: real training loop with checkpoint/restart, elastic
resharding, deterministic data replay and async checkpointing.

On a real cluster each host runs this under ``jax.distributed.initialize``
(one process per host; the mesh spans all pods).  On this container it runs
the same code path over the local devices — ``examples/train_lm.py`` drives
a ~100M-param model for a few hundred steps.

Fault tolerance (DESIGN.md §7):
  * checkpoints carry {params, opt_state, step} + the mesh/plan manifest;
  * restore reshards onto whatever mesh the restarted job has (elastic) —
    EinDecomp replans for the new device count;
  * the data pipeline is counter-based, so step N's global batch is
    identical across restarts regardless of host count;
  * checkpoint writes happen on a background thread (never blocks a step).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.data.synthetic import SyntheticLM, batch_shardings
from repro.launch import steps
from repro.launch.mesh import make_host_mesh, mesh_axes_dict
from repro.models import transformer as tf
from repro.models.eingraphs import fsdp_axes_for, program_for
from repro.optim import adamw_init
from repro.optim.schedules import cosine_schedule, wsd_schedule


def train(cfg, shape: ShapeConfig, *, steps_total: int = 100,
          mesh=None, ckpt_dir: str | None = None, ckpt_every: int = 50,
          schedule: str = "cosine", peak_lr: float = 3e-4,
          log_every: int = 10, seed: int = 0, plan_cache=None,
          executor: str = "gspmd", pp: int = 1,
          microbatches: int = 1) -> dict:
    mesh = mesh or make_host_mesh()
    axes = mesh_axes_dict(mesh)
    if pp > 1:
        _print_pipeline_summary(cfg, shape, axes, pp, microbatches)
    # warm-start planning from the persistent cache: on restart (or elastic
    # reshard onto a mesh some earlier job already planned) the §8 DP is a
    # cache hit instead of a re-run.  The training path runs on the Program
    # surface: declare -> trace -> decompose (cached) -> project to policy.
    compiled = program_for(cfg, shape).compile(
        mesh_axes=axes, cache=plan_cache,
        mesh=mesh if executor == "shard_map" else None, executor=executor)
    policy = compiled.policy(fsdp_axes=fsdp_axes_for(axes))
    if compiled.collectives is not None:
        print(f"[train] shard_map executor schedule for {cfg.name}:")
        print(compiled.collectives.summary())

    if schedule == "wsd":
        lr_fn = lambda s: wsd_schedule(s, peak_lr=peak_lr,
                                       warmup=max(steps_total // 10, 1),
                                       stable=steps_total // 2,
                                       decay=max(steps_total // 5, 1))
    else:
        lr_fn = lambda s: cosine_schedule(s, peak_lr=peak_lr,
                                          warmup=max(steps_total // 10, 1),
                                          total=steps_total)

    params = tf.init_params(cfg, jax.random.PRNGKey(seed))
    pshard = tf.param_shardings(cfg, policy, mesh)
    params = jax.device_put(params, pshard)
    opt_state = adamw_init(params)
    step_fn = jax.jit(
        steps.make_train_step(cfg, policy=policy, mesh=mesh, lr_fn=lr_fn),
        donate_argnums=(0, 1))

    data = SyntheticLM(cfg.vocab, shape.seq - cfg.prefix_len, shape.batch,
                       seed=seed)
    bshard = batch_shardings(policy, mesh,
                             tf.input_specs(cfg, shape))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if mgr is not None:
        restored = mgr.restore_latest(
            (params, opt_state),
            shardings=(pshard, jax.tree.map(lambda s: None, opt_state)))
        if restored is not None:
            start, (params, opt_state), _ = restored
            print(f"[train] restored step {start} (elastic reshard onto "
                  f"{axes})")

    history = []
    t0 = time.time()
    for step in range(start, steps_total):
        hb = data.global_batch_at(step)
        batch = {"tokens": jax.device_put(hb["tokens"], bshard["tokens"]),
                 "labels": jax.device_put(hb["labels"], bshard["labels"])}
        if cfg.prefix_len:
            rng = np.random.default_rng(step)
            pe = rng.normal(size=(shape.batch, cfg.prefix_len,
                                  cfg.d_model)).astype(np.float32)
            batch["prefix_embeds"] = jax.device_put(
                pe, bshard["prefix_embeds"])
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps_total - 1:
            loss = float(metrics["loss"])
            history.append((step, loss))
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state))
    if mgr is not None:
        mgr.save(steps_total, (params, opt_state), blocking=True)
    return {"history": history, "params": params, "opt_state": opt_state}


def _print_pipeline_summary(cfg, shape: ShapeConfig, intra_axes: dict,
                            pp: int, microbatches: int) -> None:
    """Static pipeline report for the forward program: partition the graph
    into ``pp`` stages over a combined (pp, intra) mesh, price the GPipe
    bubble and handoff wire, and print the fill/drain summary.  The
    training step itself still runs the unpipelined plan — 1F1B grad-path
    pipelining is the pipeline tier's documented stretch goal."""
    from repro.pipeline import PipelineSpec, build_pipeline_schedule

    prog = program_for(cfg, shape)
    combined = {"pp": pp, **intra_axes}
    psched = build_pipeline_schedule(
        prog.graph, PipelineSpec(stages=pp, microbatches=microbatches),
        combined, [prog._out[k] for k in prog._out])
    cut_b = sum(psched.cut_elems) * 4
    print(f"[train] pipeline (static): p={pp} m={psched.spec.microbatches} "
          f"bubble={psched.bubble:.3f} "
          f"(weighted {psched.bubble_weighted:.3f}) "
          f"cut={cut_b:,}B handoff={psched.handoff_elems:,} elems")
    for st in psched.stages:
        print(f"[train]   stage {st.index}: {len(st.nids)} nodes, "
              f"recv {len(st.recv)} tensors")
    print("[train] note: the optimizer step runs the unpipelined plan "
          "(1F1B grad pipelining is the tier's stretch goal)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-7b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--schedule", default="cosine")
    ap.add_argument("--plan-cache", default=None,
                    help="path to a persistent plan-cache JSON store; "
                         "warm-starts the planner across restarts")
    ap.add_argument("--executor", default="gspmd",
                    choices=["gspmd", "shard_map"],
                    help="plan realization: GSPMD sharding hints, or the "
                         "explicit-collective shard_map executor "
                         "(prints its static collective schedule)")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages: with --pp > 1, partition the "
                         "forward graph over a pp mesh axis and print the "
                         "static GPipe schedule (bubble, cut bytes, "
                         "handoff wire) before training")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="GPipe microbatches per step for the --pp summary "
                         "(must divide --batch)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    train(cfg, shape, steps_total=args.steps, ckpt_dir=args.ckpt,
          schedule=args.schedule, plan_cache=args.plan_cache,
          executor=args.executor, pp=args.pp,
          microbatches=args.microbatches)


if __name__ == "__main__":
    main()
