"""Step functions shared by the dry-run, the trainer and the server:
``train_step`` (fwd + bwd + AdamW), ``prefill_step`` and ``serve_step``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.transformer import loss_fn
from repro.optim import adamw_update


def make_train_step(cfg, *, policy=None, mesh=None,
                    lr_fn: Callable | None = None,
                    weight_decay: float = 0.1, unroll: bool = False,
                    grad_reduce_scatter: bool = True) -> Callable:
    lr_fn = lr_fn or (lambda step: 3e-4)
    gshard = None
    if policy is not None and mesh is not None and grad_reduce_scatter:
        # pin gradients to the parameter sharding right at production so
        # GSPMD lowers the batch-axis reduction as reduce-scatter instead
        # of all-reduce + slice (ZeRO-2; EXPERIMENTS.md §Perf)
        gshard = tf.param_shardings(cfg, policy, mesh)

    def train_step(params, opt_state, batch):
        def _loss(p):
            return loss_fn(p, batch, cfg, policy=policy, mesh=mesh,
                           unroll=unroll)

        (loss, metrics), grads = jax.value_and_grad(_loss, has_aux=True)(params)
        if gshard is not None:
            grads = jax.lax.with_sharding_constraint(grads, gshard)
        lr = lr_fn(opt_state.step)
        params, opt_state, gnorm = adamw_update(
            params, grads, opt_state, lr, weight_decay=weight_decay)
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm,
                        "lr": jnp.asarray(lr, jnp.float32)})
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, *, policy=None, mesh=None, unroll: bool = False) -> Callable:
    def prefill_step(params, batch):
        logits, caches, _ = tf.forward(
            params, batch["tokens"], cfg,
            prefix_embeds=batch.get("prefix_embeds"),
            policy=policy, mesh=mesh, collect_cache=True, remat=False,
            unroll=unroll, last_logit_only=True)
        return logits, caches

    return prefill_step


def make_serve_step(cfg, *, policy=None, mesh=None, unroll: bool = False) -> Callable:
    def serve_step(params, tokens, caches, pos):
        return tf.decode_step(params, tokens, caches, pos, cfg,
                              policy=policy, mesh=mesh, unroll=unroll)

    return serve_step


def make_bucket_prefill_step(cfg, *, policy=None, mesh=None,
                             unroll: bool = False) -> Callable:
    """Prefill over a bucket-padded prompt: identical to ``prefill_step``
    except the LM head runs at a caller-supplied ``last_index`` (the last
    *real* token) instead of the final — padded — position.  Structurally
    the same graph, so the two share a plan-cache entry per shape cell."""

    def bucket_prefill_step(params, batch, last_index):
        logits, caches, _ = tf.forward(
            params, batch["tokens"], cfg,
            prefix_embeds=batch.get("prefix_embeds"),
            policy=policy, mesh=mesh, collect_cache=True, remat=False,
            unroll=unroll, logit_index=last_index)
        return logits, caches

    return bucket_prefill_step


def make_paged_serve_step(cfg, *, policy=None, mesh=None,
                          unroll: bool = False) -> Callable:
    """Continuous-batching decode step: per-slot positions + block tables
    into the paged KV pool (``kv_block_gather`` OpDef)."""

    def paged_serve_step(params, tokens, caches, tables, pos):
        return tf.decode_step_paged(params, tokens, caches, tables, pos, cfg,
                                    policy=policy, mesh=mesh, unroll=unroll)

    return paged_serve_step
