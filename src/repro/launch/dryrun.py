"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For every (architecture x input-shape) cell and mesh:

  1. run EinDecomp on the cell's EinGraph -> ShardingPolicy,
  2. build abstract params / optimizer / caches / batch (ShapeDtypeStruct,
     no allocation) with shardings,
  3. ``jax.jit(step).lower(...).compile()`` the *production* (scan-rolled)
     step — success proves the sharding config is coherent on the mesh; its
     ``memory_analysis`` proves (or disproves) fit,
  4. extract roofline terms.  XLA's cost_analysis counts while bodies once
     (verified), so FLOPs/bytes/collectives come from lowering 1-unit and
     2-unit *unrolled* variants of the same cell and extrapolating
     affine-in-layers:  total = c1 + (units-1) * (c2 - c1).
     Collective bytes are wire-accurate ((k-1)/k ring terms) with while
     trip-count multipliers (launch/hlo_analysis.py).  Inner *time* scans
     (sLSTM / mLSTM chunk loops) are still once-counted; an analytic
     correction is added and reported separately.

Usage:
  python -m repro.launch.dryrun --arch llama-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod]
Artifacts land in artifacts/dryrun/*.json; EXPERIMENTS.md tables are built
from them by benchmarks/roofline.py.
"""
import argparse
import dataclasses
import json
import os
import time
import traceback

from repro.launch.hostdev import force_host_devices as _force_host_devices

_force_host_devices()

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import parse_collectives

# TPU v5e per-chip constants (the TARGET hardware; this container is CPU)
PEAK_FLOPS = 197e12     # bf16 FLOP/s
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s/link


# process-wide plan cache for the dry-run sweep: isomorphic cells (same
# block structure at the same bounds and mesh) plan once across the whole
# --all matrix, exactly like a disk-backed cache would across jobs.
_PLAN_CACHE = None


def _plan_cell(cfg, shape, axes, fsdp):
    """EinDecomp one cell through the Program surface -> (plan, policy)."""
    from repro.core.plancache import PlanCache
    from repro.models.eingraphs import fsdp_axes_for, program_for

    global _PLAN_CACHE
    if _PLAN_CACHE is None:
        _PLAN_CACHE = PlanCache(capacity=128)
    compiled = program_for(cfg, shape).compile(mesh_axes=axes,
                                               cache=_PLAN_CACHE)
    policy = compiled.policy(fsdp_axes=fsdp_axes_for(axes) if fsdp else ())
    return compiled.plan, policy


def build_cell(cfg, shape, mesh, *, fsdp: bool | None = None,
               policy_override=None, unroll: bool = False):
    """(step_fn, example_args_with_shardings, donate, plan, policy)."""
    from repro.data.synthetic import batch_shardings
    from repro.launch import steps
    from repro.launch.mesh import mesh_axes_dict
    from repro.models import transformer as tf
    from repro.optim import adamw_init

    axes = mesh_axes_dict(mesh)
    if fsdp is None:
        fsdp = shape.kind == "train"
    if policy_override is not None:
        policy, plan = policy_override, None
    else:
        plan, policy = _plan_cell(cfg, shape, axes, fsdp)

    params = tf.init_params(cfg, abstract=True)
    pshard = tf.param_shardings(cfg, policy, mesh)
    params = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params, pshard)
    batch = tf.input_specs(cfg, shape)
    bshard = batch_shardings(policy, mesh, batch)
    batch = {
        k: (jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=bshard[k])
            if bshard.get(k) is not None else v)
        for k, v in batch.items()}

    if shape.kind == "train":
        opt = adamw_init(params, abstract=True)
        # m/v moments inherit the parameter sharding (f32)
        opt = type(opt)(
            opt.step,
            jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=sh), opt.m, pshard),
            jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=sh), opt.v, pshard))
        step = steps.make_train_step(cfg, policy=policy, mesh=mesh,
                                     unroll=unroll)
        return step, (params, opt, batch), (0, 1), plan, policy
    if shape.kind == "prefill":
        step = steps.make_prefill_step(cfg, policy=policy, mesh=mesh,
                                       unroll=unroll)
        return step, (params, batch), (), plan, policy
    kv_len = cfg.kv_len(shape)
    caches = tf.init_caches(cfg, shape.batch, kv_len, abstract=True)
    cshard = tf.cache_shardings(cfg, shape.batch, kv_len, policy, mesh)
    caches = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        caches, cshard)
    step = steps.make_serve_step(cfg, policy=policy, mesh=mesh, unroll=unroll)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return step, (params, batch["tokens"], caches, pos), (2,), plan, policy


def _lower_compile(cfg, shape, mesh, *, fsdp, policy_override=None,
                   unroll=False):
    step, args, donate, plan, policy = build_cell(
        cfg, shape, mesh, fsdp=fsdp, policy_override=policy_override,
        unroll=unroll)
    with mesh:
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    return compiled, plan, policy


def _costs(compiled, chips) -> dict:
    ca = compiled.cost_analysis() or {}
    wire, by_kind, plain = parse_collectives(compiled.as_text(), chips)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_wire": wire,
        "coll_by_kind": by_kind,
        "coll_plain": plain,
    }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·tokens (train) / 2·N_active·tokens (fwd)."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n * shape.batch * shape.seq
    if shape.kind == "prefill":
        return 2.0 * n * shape.batch * shape.seq
    return 2.0 * n * shape.batch


def inner_scan_correction(cfg, shape) -> float:
    """Analytic FLOPs missing because inner *time* scans (sLSTM time loop,
    mLSTM chunk loop) are counted once by XLA cost analysis.  Returns a
    *global* FLOP count to add.  SSM chunk-loop bodies are O(s·b·d·n) —
    negligible vs the FFN — and are skipped (documented)."""
    if shape.kind == "decode":
        return 0.0  # decode takes one recurrent step: counted exactly
    s, b = shape.seq, shape.batch
    D = cfg.d_model
    mult = 3.0 if shape.kind == "train" else 1.0  # bwd ~ 2x fwd
    total = 0.0
    for blk in cfg.blocks():
        if blk == "slstm":
            per_unit = s * b * 16 * D * D          # x@W(4D) + h@R(4D) per step
            total += per_unit * (1 - 1 / max(s, 1)) * mult
        elif blk == "mlstm":
            L = min(256, s)
            H = cfg.n_heads
            dh = D // H
            trips = s // L
            per_chunk = b * H * (3 * 2 * L * L * dh + 2 * 2 * L * dh * dh)
            per_unit = trips * per_chunk
            total += per_unit * (1 - 1 / max(trips, 1)) * mult
    return total


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             fsdp: bool | None = None, policy_override=None,
             out_dir: str = "artifacts/dryrun", tag: str = "",
             skip_full: bool = False, cfg_override=None) -> dict:
    from repro.configs import SHAPES, get_config
    from repro.launch.mesh import make_production_mesh

    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": chips, "kind": shape.kind, "tag": tag, "ok": False}
    if not cfg.supports(shape):
        rec["skipped"] = ("long_500k needs sub-quadratic attention; "
                          f"{arch} is pure full-attention (DESIGN.md §4)")
        return rec

    # ---- 1. production (rolled) lower+compile: proof + memory ------------
    t0 = time.time()
    if not skip_full:
        compiled, plan, policy = _lower_compile(
            cfg, shape, mesh, fsdp=fsdp, policy_override=policy_override)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_gb": ma.argument_size_in_bytes / 1e9,
            "output_gb": ma.output_size_in_bytes / 1e9,
            "temp_gb": ma.temp_size_in_bytes / 1e9,
            "alias_gb": ma.alias_size_in_bytes / 1e9,
            "per_device_gb": (ma.argument_size_in_bytes
                              + ma.temp_size_in_bytes
                              + ma.output_size_in_bytes
                              - ma.alias_size_in_bytes) / 1e9,
        }
        rec["fits_16gb"] = rec["memory"]["per_device_gb"] <= 16.0
        del compiled
    else:
        _, plan, policy = (None, *_plan_only(cfg, shape, mesh, fsdp,
                                             policy_override))
    rec["compile_s"] = round(time.time() - t0, 1)
    if plan is not None:
        rec["plan_cost_floats"] = plan.cost
        rec["analysis"] = _static_analysis(cfg, shape, mesh, plan)
    rec["policy"] = {k: list(v) for k, v in policy.label_axes.items()}
    rec["fsdp"] = list(policy.fsdp_axes)

    # ---- 2. roofline: unrolled 1-unit / 2-unit extrapolation --------------
    period = len(cfg.block_pattern)
    units = cfg.n_layers // period
    ks = [1, 2] if units >= 2 else [1]
    costs = []
    for k in ks:
        cfg_k = dataclasses.replace(cfg, n_layers=k * period)
        ck, _, _ = _lower_compile(cfg_k, shape, mesh, fsdp=fsdp,
                                  policy_override=policy, unroll=True)
        costs.append(_costs(ck, chips))
        del ck
    c1 = costs[0]
    c2 = costs[-1]

    def extra(key):
        if len(costs) == 1:
            return c1[key] * units
        return c1[key] + (units - 1) * (c2[key] - c1[key])

    flops_dev = extra("flops")
    bytes_dev = extra("bytes")
    coll_dev = extra("coll_wire")
    coll_plain = extra("coll_plain")
    by_kind = {}
    for kname in set(c1["coll_by_kind"]) | set(c2["coll_by_kind"]):
        a = c1["coll_by_kind"].get(kname, 0.0)
        b = c2["coll_by_kind"].get(kname, 0.0)
        by_kind[kname] = a + (units - 1) * (b - a) if len(costs) > 1 else a * units

    corr = inner_scan_correction(cfg, shape) / chips
    flops_dev += corr

    mf = model_flops(cfg, shape)
    # buffer-touch floor: every live buffer read+written once per step.
    # XLA's bytes-accessed is a no-fusion-reuse UPPER bound; truth is in
    # [t_memory_lb, t_memory].
    touch = 0.0
    if "memory" in rec:
        touch = 2.0 * rec["memory"]["per_device_gb"] * 1e9
    rec["roofline"] = {
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "touch_bytes_per_dev": touch,
        "t_memory_lb_s": touch / HBM_BW,
        "collective_wire_bytes_per_dev": coll_dev,
        "collective_operand_bytes_per_dev": coll_plain,
        "collective_by_kind": by_kind,
        "inner_scan_flops_corr_per_dev": corr,
        "t_compute_s": flops_dev / PEAK_FLOPS,
        "t_memory_s": bytes_dev / HBM_BW,
        "t_collective_s": coll_dev / ICI_BW,
        "model_flops_global": mf,
        "useful_flops_ratio": mf / max(flops_dev * chips, 1.0),
    }
    terms = {"compute": rec["roofline"]["t_compute_s"],
             "memory": rec["roofline"]["t_memory_s"],
             "collective": rec["roofline"]["t_collective_s"]}
    rec["bottleneck"] = max(terms, key=terms.get)
    rec["roofline_fraction"] = terms["compute"] / max(max(terms.values()), 1e-30)
    terms_lb = dict(terms, memory=rec["roofline"]["t_memory_lb_s"])
    rec["bottleneck_lb"] = max(terms_lb, key=terms_lb.get)
    rec["roofline_fraction_lb"] = (terms_lb["compute"]
                                   / max(max(terms_lb.values()), 1e-30))
    rec["total_s"] = round(time.time() - t0, 1)
    rec["ok"] = True

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fn = f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def _static_analysis(cfg, shape, mesh, plan) -> dict:
    """Record the repro.analysis verdict for the planned cell next to the
    XLA numbers: the static verifier re-checks the exact plan the dry-run
    proved compilable (graph/plan/schedule/memory passes, backend-free).
    Informational — findings land in the artifact, they don't fail the
    sweep (RA regressions are gated by CI's `analysis` job on the zoo)."""
    from repro.analysis import analyze_program
    from repro.launch.mesh import mesh_axes_dict
    from repro.models.eingraphs import program_for

    try:
        report = analyze_program(program_for(cfg, shape),
                                 mesh_axes_dict(mesh), plan=plan)
    except Exception as e:  # never let verification sink the dry-run
        return {"error": f"{type(e).__name__}: {e}"}
    return {"n_errors": len(report.errors),
            "n_warnings": len(report.warnings),
            "codes": sorted(report.codes()),
            "peak_bytes_per_dev": report.memory.get("peak_bytes")}


def _plan_only(cfg, shape, mesh, fsdp, policy_override):
    from repro.launch.mesh import mesh_axes_dict

    if policy_override is not None:
        return None, policy_override
    if fsdp is None:
        fsdp = shape.kind == "train"
    return _plan_cell(cfg, shape, mesh_axes_dict(mesh), fsdp)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, SHAPES

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape in cells:
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           out_dir=args.out, tag=args.tag)
            if rec.get("skipped"):
                print(f"SKIP {arch:18s} {shape:12s} {rec['skipped'][:58]}",
                      flush=True)
                continue
            r = rec["roofline"]
            print(f"OK   {arch:18s} {shape:12s} mesh={rec['mesh']:8s} "
                  f"mem={rec['memory']['per_device_gb']:7.2f}GB "
                  f"t_c={r['t_compute_s']:.2e} t_m={r['t_memory_s']:.2e} "
                  f"t_x={r['t_collective_s']:.2e} {rec['bottleneck']:10s} "
                  f"frac={rec['roofline_fraction']:.2f} "
                  f"[{rec['total_s']}s]", flush=True)
        except Exception:
            failures += 1
            print(f"FAIL {arch:18s} {shape:12s}", flush=True)
            traceback.print_exc()
        finally:
            jax.clear_caches()  # keep host RAM bounded across 40 compiles
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
