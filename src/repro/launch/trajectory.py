"""Static predicted/traced cost-honesty trajectory for the model zoo.

The §8 DP optimizes the paper's §7 p2p upper bound; the shard_map executor
realizes the plan with ring-priced collectives.  The ratio between the two
— ``plan_cost / traced wire elems`` — is how much the DP *overprices* the
schedule it picked: a large ratio means the DP may forgo plans it misprices
(the gap the calibrated ``CostModel.with_measured`` closes), a ratio that
*shrinks* across PRs means the executor is squandering wire savings on
redundant movement.

Everything here is a pure function of (config, plan, mesh shape): the plan
comes from the deterministic paper-mode DP and the traced elems from the
static ``build_schedule`` — no jax arrays, no devices — so the per-family
ratios are bit-identical on every host.  ``benchmarks/bench_spmd.py``
records them into ``BENCH_spmd.json`` and
``tests/test_spmd_fastpath.py`` pins them against that committed
trajectory (update with ``REPRO_UPDATE_RATIOS=1``).
"""
from __future__ import annotations

import math

#: the CI bench mesh: 2x4 forced host devices
MESH_AXES = {"data": 2, "model": 4}

#: the zoo families the trajectory tracks (bench_spmd's FAMILIES)
FAMILIES = ("llama-7b", "mixtral-8x7b", "xlstm-125m", "hymba-1.5b")


def family_ratio(arch: str, phase: str = "prefill",
                 mesh_axes: dict[str, int] | None = None,
                 fuse: bool = True, lookahead: int = 1) -> dict:
    """Deterministic predicted/traced numbers for one zoo family.

    Returns ``{"arch", "phase", "predicted_elems", "traced_elems",
    "ratio"}`` where ``ratio = predicted / traced`` under the paper-mode
    plan and the static fused schedule, plus the graph-wide overlap
    numbers of the ``lookahead`` schedule: ``overlapped_elems`` (ring
    double-buffer + hoisted prefetches, counted once), ``overlap_frac``
    (overlapped / traced), and ``exposed_elems`` (wire left after hiding
    each issue site's overlappable traffic behind its compute window —
    ``core.cost.exposed_wire``).  Pure host Python.
    """
    from repro.configs import get_config, reduced
    from repro.configs.base import ShapeConfig
    from repro.core import spmd
    from repro.core.decomp import eindecomp, plan_cost
    from repro.models.eingraphs import program_for
    from repro.models.opaque_stubs import capacity_of, make_stub_opaques

    mesh_axes = dict(mesh_axes or MESH_AXES)
    cfg = reduced(get_config(arch))
    prog = program_for(cfg, ShapeConfig("bench", phase, 32, 4))
    g = prog.graph
    make_stub_opaques(capacity_of(g))
    # offpath_repart=True mirrors Program.compile's planning default, so
    # the trajectory prices the same plan bench_spmd executes
    plan = eindecomp(g, math.prod(mesh_axes.values()), mesh_axes=mesh_axes,
                     offpath_repart=True)
    out_ids = [prog._out[k] for k in prog._out]
    sched = spmd.build_schedule(g, plan, mesh_axes, out_ids, fuse=fuse,
                                lookahead=lookahead)
    predicted = int(plan_cost(g, plan))
    traced = int(sched.trace.total_elems)
    overlapped = int(sched.trace.overlapped_elems)
    return {"arch": arch, "phase": phase,
            "predicted_elems": predicted, "traced_elems": traced,
            "ratio": round(predicted / max(traced, 1), 4),
            "overlapped_elems": overlapped,
            "overlap_frac": round(overlapped / max(traced, 1), 4),
            "exposed_elems": int(sched.exposed_wire_elems())}


def family_ratios(fams=FAMILIES, **kw) -> list[dict]:
    return [family_ratio(a, **kw) for a in fams]
