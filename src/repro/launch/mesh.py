"""Production meshes.

Functions (not module constants) so importing never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.

``AxisType`` only exists in newer jax releases; on older installs we fall
back to plain meshes (every axis behaves as the legacy default), keeping the
module importable — and the test suite collectable — everywhere.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.4.38
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / host benchmarks)."""
    import numpy as np

    n = len(jax.devices())
    want = int(np.prod(shape))
    if want > n:
        shape = (1, n)
    return make_mesh(shape, axes)


from repro.core.engine import mesh_axes_dict  # noqa: E402  (re-export)
