"""Production meshes.

Functions (not module constants) so importing never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / host benchmarks)."""
    import numpy as np

    n = len(jax.devices())
    want = int(np.prod(shape))
    if want > n:
        shape = (1, n)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def mesh_axes_dict(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
