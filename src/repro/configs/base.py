"""Model + shape configuration system.

Every assigned architecture is a ``ModelConfig`` (src/repro/configs/<id>.py);
the four input shapes are ``ShapeConfig``s.  ``input_specs`` builds the
ShapeDtypeStruct stand-ins the dry-run lowers against (no allocation).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shapes (assigned set — LM transformer shapes)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # attention
    window: int = 0             # sliding-window size (0 = full attention)
    qkv_bias: bool = False
    rope_theta: float = 1e4

    # ffn
    act: str = "silu"           # silu | gelu | relu2
    gated_ffn: bool = True      # SwiGLU/GeGLU vs plain 2-matrix MLP

    # MoE
    moe: bool = False
    n_experts: int = 0          # routed experts (router size)
    n_experts_padded: int = 0   # dispatch-buffer experts (mesh divisibility)
    top_k: int = 0
    shared_expert_ff: int = 0   # total hidden width of always-on shared experts
    capacity_factor: float = 1.25
    moe_groups: int = 0         # >1: group-local dispatch (EXPERIMENTS §Perf)

    # ssm / recurrent
    block_pattern: tuple[str, ...] = ("attn",)  # cycled over layers
    ssm_state: int = 0
    ssm_conv: int = 4

    # frontend stub (vlm / audio)
    prefix_len: int = 0         # patch/frame embeddings prepended (stub)

    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    notes: str = ""

    # ---- derived ----------------------------------------------------------

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab, 256)

    @property
    def n_e(self) -> int:
        return self.n_experts_padded or self.n_experts

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token decode with bounded state?"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.window > 0

    @property
    def uniform_blocks(self) -> bool:
        return len(set(self.block_pattern)) == 1

    def blocks(self) -> list[str]:
        pat = self.block_pattern
        return [pat[i % len(pat)] for i in range(self.n_layers)]

    def supports(self, shape: ShapeConfig) -> bool:
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False  # pure full-attention: skipped per DESIGN.md §4
        return True

    def kv_len(self, shape: ShapeConfig) -> int:
        """KV-cache (or attention span) length for a decode shape: sliding-
        window archs keep a ring buffer of `window`, others the full seq."""
        if self.window:
            return min(self.window, shape.seq)
        return shape.seq

    # parameter count (for MODEL_FLOPS = 6 N D roofline term)
    def param_count(self, active_only: bool = False) -> int:
        D, H, K, hd, F, L = (self.d_model, self.n_heads, self.n_kv_heads,
                             self.hd, self.d_ff, self.n_layers)
        emb = self.vocab_padded * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        for blk in self.blocks():
            p = 2 * D  # two norms
            if blk in ("attn", "hymba"):
                p += D * H * hd + 2 * D * K * hd + H * hd * D
                if self.qkv_bias:
                    p += (H + 2 * K) * hd
            if blk == "hymba":
                n = self.ssm_state
                di = self.d_model  # ssm inner dim
                p += D * 2 * di + di * self.ssm_conv + di * (2 * n + 1) + di * D
            if blk in ("mlstm", "slstm"):
                p += 4 * D * D + 4 * D  # q/k/v/gates projections (approx)
            if blk in ("attn", "hymba", "mlstm", "slstm") and F:
                if self.moe:
                    e = self.top_k if active_only else self.n_e
                    width = 3 if self.gated_ffn else 2
                    p += e * width * D * F + D * self.n_e  # router
                    if self.shared_expert_ff:
                        p += width * D * self.shared_expert_ff
                else:
                    p += (3 if self.gated_ffn else 2) * D * F
            per_layer += p
        return emb + per_layer + D


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


ARCH_IDS = [
    "paligemma-3b", "mixtral-8x7b", "qwen2-moe-a2.7b", "musicgen-large",
    "xlstm-125m", "minicpm-2b", "qwen1.5-110b", "nemotron-4-15b",
    "yi-9b", "hymba-1.5b",
]


def _load_all() -> None:
    import importlib

    mods = ARCH_IDS + ["llama-7b"]
    for arch in mods:
        importlib.import_module(f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")


# ---------------------------------------------------------------------------
# Reduced (smoke) variants: same family/topology, tiny dims.
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    n_layers = min(cfg.n_layers, 2 * len(cfg.block_pattern))
    heads = min(cfg.n_heads, 4)
    kv = max(1, min(cfg.n_kv_heads, heads))
    if cfg.n_heads % cfg.n_kv_heads == 0 and heads % kv != 0:
        kv = 1
    return replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        n_experts=min(cfg.n_experts, 8) if cfg.moe else 0,
        n_experts_padded=min(cfg.n_e, 8) if cfg.moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.moe else 0,
        shared_expert_ff=64 if cfg.shared_expert_ff else 0,
        window=min(cfg.window, 16) if cfg.window else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        prefix_len=min(cfg.prefix_len, 4) if cfg.prefix_len else 0,
        dtype="float32",
    )


SMOKE_SHAPE = ShapeConfig("smoke", "train", 32, 2)
SMOKE_DECODE = ShapeConfig("smoke_decode", "decode", 64, 2)
