"""Mixtral-8x7B [moe] — 8 experts top-2, SWA 4096 [arXiv:2401.04088]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000,
    window=4096,
    moe=True, n_experts=8, n_experts_padded=8, top_k=2,
    act="silu", gated_ffn=True, rope_theta=1e6,
    notes="SWA window 4096 -> sub-quadratic decode; long_500k runs.",
))
