"""Nemotron-4-15B [dense] — GQA kv=8, squared-ReLU FFN [arXiv:2402.16819]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=256000,
    act="relu2", gated_ffn=False,
))
