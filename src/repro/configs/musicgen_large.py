"""MusicGen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].  The EnCodec frontend is a STUB: the backbone consumes
token ids over the 2048-entry codebook vocabulary directly (the brief's
"precomputed frame embeddings" are the embedding rows of those ids).
Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048,
    act="gelu", gated_ffn=False,
))
