"""xLSTM-125M [ssm] — alternating mLSTM / sLSTM blocks [arXiv:2405.04517].

d_ff=0: xLSTM blocks carry their own up/down projections instead of a
separate FFN.  Recurrent state -> all four shapes run, incl. long_500k.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab=50304,
    block_pattern=("mlstm", "slstm"),
    notes="mLSTM chunkwise-parallel; sLSTM lax.scan recurrence.",
))
