"""LLaMA-7B — the paper's own Experiment 3/4 subject [arXiv:2302.13971]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab=32000,
    act="silu", gated_ffn=True,
))
