"""PaliGemma-3B [vlm] — SigLIP + gemma decoder [arXiv:2407.07726].

The SigLIP vision tower is a STUB per the brief: ``input_specs`` provides
256 precomputed patch embeddings as a prefix.  Backbone: gemma-style MQA
(kv=1), GeGLU FFN, RMSNorm.  Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216,
    act="gelu", gated_ffn=True, rope_theta=1e4,
    prefix_len=256,
    notes="SigLIP frontend stubbed (patch embeddings in input_specs).",
))
