"""MiniCPM-2B [dense] — llama-like, WSD schedule [arXiv:2404.06395].
The WSD (warmup-stable-decay) schedule is wired in repro/optim/schedules."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
    d_ff=5760, vocab=122753,
    act="silu", gated_ffn=True, tie_embeddings=True,
))
