"""Hymba-1.5B [hybrid] — parallel attention + mamba heads per layer
[arXiv:2411.13676].  SWA on the attention path + SSM state -> sub-quadratic;
long_500k runs.  25 heads are not divisible by the model-axis size, so
EinDecomp shards the FFN hidden / sequence labels instead (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    window=1024,
    block_pattern=("hymba",),
    ssm_state=16,
    act="silu", gated_ffn=True,
))
