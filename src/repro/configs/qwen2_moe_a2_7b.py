"""Qwen1.5-MoE-A2.7B [moe] — 60 routed top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B].

60 routed experts are padded to 64 dispatch slots for mesh divisibility
(router logits for the 4 pad slots are masked to -inf); the 4 shared
experts are fused into one always-on FFN of width 4*1408 (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=151936,
    qkv_bias=True,
    moe=True, n_experts=60, n_experts_padded=64, top_k=4,
    shared_expert_ff=4 * 1408,
    act="silu", gated_ffn=True,
    notes="Full attention -> long_500k skipped.",
))
