from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, ShapeConfig,
                                all_configs, get_config, reduced, register)
