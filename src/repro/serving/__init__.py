"""Serving tier: continuous batching + shape-bucket registry + paged KV.

The decode-side data path is a *paged* KV cache declared as an OpDef
(``kv_block_gather``) so the planner prices it like any other opaque op;
the control path is a slot-based scheduler that admits prompts through
bucketed prefill programs resolved via the canonical plan cache.
"""
from repro.serving.buckets import BucketEntry, BucketRegistry, bucket_len, pad_free
from repro.serving.engine import Request, ServeMetrics, ServingEngine
from repro.serving.paged_kv import BlockAllocator, make_admit_fn

__all__ = [
    "BlockAllocator", "BucketEntry", "BucketRegistry", "Request",
    "ServeMetrics", "ServingEngine", "bucket_len", "make_admit_fn",
    "pad_free",
]
