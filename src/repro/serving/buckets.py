"""Shape-bucket registry: one live compiled handle per serving shape cell.

Continuous batching wants to admit arbitrary-length prompts without
recompiling per length.  The registry quantizes prompt lengths into
buckets and keeps exactly one ``CompiledProgram`` (plus its projected
``ShardingPolicy`` and jitted step function) per
``(arch, kind, bucket_len, batch[, kv_block])`` cell, resolved through
the canonical plan cache — the *second* process (or the second bucket
that is structurally isomorphic) skips the §8 DP entirely and only pays
XLA compilation.

Bucket policy: pure-attention, non-MoE archs round prompt lengths up to a
power of two (pad tokens sit behind the causal mask, so real positions
are unaffected); recurrent archs (ssm/xlstm blocks) and MoE archs get
exact-length buckets — a recurrent scan folds pad tokens into its final
state and MoE capacity couples rows, so padding would change real
outputs, not just waste FLOPs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from repro.configs.base import ShapeConfig
from repro.core.plancache import PlanCache
from repro.launch import steps
from repro.launch.mesh import mesh_axes_dict
from repro.models.eingraphs import program_for


def pad_free(cfg) -> bool:
    """True iff right-padding a prompt cannot change real-token outputs:
    every block is causal attention (pad keys are masked) and routing does
    not couple rows (no MoE)."""
    return all(b == "attn" for b in cfg.block_pattern) and not cfg.moe


def bucket_len(cfg, prompt_len: int, *, mode: str = "auto",
               min_bucket: int = 8) -> int:
    """Quantized prefill length for ``prompt_len`` under the policy."""
    if mode not in ("auto", "pow2", "exact"):
        raise ValueError(f"bucket mode {mode!r}")
    if mode == "exact" or (mode == "auto" and not pad_free(cfg)):
        return int(prompt_len)
    return max(min_bucket, 1 << (int(prompt_len) - 1).bit_length())


@dataclass
class BucketEntry:
    """One shape cell's live handle: the planned program, its policy
    projection, and the jitted step function serving requests."""

    key: tuple
    canonical_key: str
    compiled: Any
    policy: Any
    step: Callable
    plan_time_s: float
    cache_hit: bool
    hits: int = 0


@dataclass
class RegistryStats:
    compiles: int = 0
    lookups: int = 0
    plan_cache_hits: int = 0
    plan_time_s: float = 0.0


class BucketRegistry:
    """Per-(arch, shape-cell) compiled-handle cache over the plan cache."""

    def __init__(self, cfg, mesh, *, plan_cache=None, executor: str = "gspmd",
                 bucket: str = "auto", min_bucket: int = 8):
        self.cfg = cfg
        self.mesh = mesh
        self.executor = executor
        self.bucket = bucket
        self.min_bucket = min_bucket
        coerced = PlanCache.coerce(plan_cache)
        # explicit None test: an empty PlanCache is falsy (len 0), and a
        # caller-shared cache must not be silently replaced
        self.plan_cache = PlanCache() if coerced is None else coerced
        self.stats = RegistryStats()
        self._entries: dict[tuple, BucketEntry] = {}

    # -- shape-cell resolution ------------------------------------------------

    def bucket_len(self, prompt_len: int) -> int:
        return bucket_len(self.cfg, prompt_len, mode=self.bucket,
                          min_bucket=self.min_bucket)

    def prefill(self, prompt_len: int, batch: int = 1) -> BucketEntry:
        """The prefill cell covering ``prompt_len`` (bucketed)."""
        seq = self.bucket_len(prompt_len)
        return self._get("prefill", seq, batch, 0)

    def decode(self, seq: int, batch: int, kv_block: int) -> BucketEntry:
        """The persistent paged-decode cell for a batch bucket."""
        if seq % kv_block:
            raise ValueError(f"decode seq {seq} not a multiple of the "
                             f"kv block {kv_block}")
        return self._get("decode", seq, batch, kv_block)

    # -- internals ------------------------------------------------------------

    def _get(self, kind: str, seq: int, batch: int,
             kv_block: int) -> BucketEntry:
        self.stats.lookups += 1
        key = (self.cfg.name, kind, seq, batch, kv_block)
        ent = self._entries.get(key)
        if ent is not None:
            ent.hits += 1
            return ent

        shape = ShapeConfig("serve", kind, seq, batch)
        prog = program_for(self.cfg, shape, kv_block=kv_block)
        h0, m0 = self.plan_cache.hits, self.plan_cache.misses
        t0 = time.time()
        compiled = prog.compile(mesh_axes=mesh_axes_dict(self.mesh),
                                cache=self.plan_cache,
                                mesh=(self.mesh if self.executor == "shard_map"
                                      else None),
                                executor=self.executor)
        plan_t = time.time() - t0
        hit = (self.plan_cache.hits > h0 and self.plan_cache.misses == m0)
        policy = compiled.policy()
        step = self._make_step(kind, policy)
        ent = BucketEntry(key=key, canonical_key=compiled.canonical_key,
                          compiled=compiled, policy=policy, step=step,
                          plan_time_s=plan_t, cache_hit=hit)
        self._entries[key] = ent
        self.stats.compiles += 1
        self.stats.plan_time_s += plan_t
        if hit:
            self.stats.plan_cache_hits += 1
        return ent

    # -- static verification --------------------------------------------------

    def analyze(self, max_hbm: int | None = None) -> dict:
        """Statically re-verify every live bucket cell (repro.analysis):
        each entry's CompiledProgram is checked with its own plan and
        donation set under this registry's mesh shape — graph, plan,
        schedule, and memory passes, all backend-free, so it is safe to
        call on a loaded serving host.  Returns ``{bucket key: Report}``;
        callers gate on ``report.has_errors``."""
        from repro.analysis import analyze_compiled

        axes = mesh_axes_dict(self.mesh)
        return {
            key: analyze_compiled(
                ent.compiled, max_hbm=max_hbm, mesh_axes=axes,
                meta={"bucket": "/".join(str(k) for k in key)})
            for key, ent in sorted(self._entries.items())}

    def _make_step(self, kind: str, policy) -> Callable:
        cfg, mesh = self.cfg, self.mesh
        if kind == "prefill":
            return jax.jit(steps.make_bucket_prefill_step(
                cfg, policy=policy, mesh=mesh))
        base = steps.make_paged_serve_step(cfg, policy=policy, mesh=mesh)

        def decode_step(params, tokens, caches, tables, pos):
            import jax.numpy as jnp

            logits, caches = base(params, tokens, caches, tables, pos)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            return tok, caches

        # donate the caches: the pool is the dominant buffer and strictly
        # carried step-to-step
        return jax.jit(decode_step, donate_argnums=(2,))
