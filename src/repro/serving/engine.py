"""Continuous-batching serving engine on the plan cache.

``ServingEngine`` holds a fixed pool of decode *slots* (the persistent
paged-decode program's batch) plus an admission queue.  Each loop
iteration: (1) admit queued requests into free slots — a bucketed
batch-1 prefill through the ``BucketRegistry`` resolves the shape cell's
compiled handle (warm after first touch), then a jitted scatter moves the
prefill caches into the paged KV pool under the request's block table;
(2) evict finished requests and return their blocks; (3) run ONE batched
decode step for all live slots — per-slot positions and block tables mean
requests join and leave mid-flight without any recompilation.

Generated tokens stay on device (the decode step argmaxes inside the jit
and the per-step token vectors are simply accumulated); the host fetches
everything once at drain, so the loop never forces a per-token sync.
Length-based eviction is the default; passing ``eos_id`` enables early
exit at the cost of one host sync per step (documented, opt-in).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.serving.buckets import BucketRegistry
from repro.serving.paged_kv import BlockAllocator, make_admit_fn


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new: int
    submit_t: float = 0.0
    ttft_s: float | None = None   # submit -> first token (prefill argmax)
    slot: int = -1
    blocks: list[int] = field(default_factory=list)
    step_start: int = -1          # index of its first decode-step column
    n_dec: int = 0                # decode tokens produced so far
    first_tok: int = -1
    done: bool = False

    @property
    def total(self) -> int:
        return 1 + self.n_dec     # prefill token + decode tokens


@dataclass
class ServeMetrics:
    """Serving-tier observability: queue depth and batch occupancy are
    sampled once per decode step; TTFT once per request."""

    queue_depth: list[int] = field(default_factory=list)
    occupancy: list[float] = field(default_factory=list)
    ttft_s: dict[int, float] = field(default_factory=dict)
    prefills: int = 0
    decode_steps: int = 0
    tokens_generated: int = 0
    t_total_s: float = 0.0
    t_prefill_s: float = 0.0

    @property
    def tok_per_s(self) -> float:
        return self.tokens_generated / max(self.t_total_s, 1e-9)

    @property
    def mean_occupancy(self) -> float:
        return float(np.mean(self.occupancy)) if self.occupancy else 0.0

    def summary(self) -> dict:
        return {
            "prefills": self.prefills,
            "decode_steps": self.decode_steps,
            "tokens_generated": self.tokens_generated,
            "tok_per_s": self.tok_per_s,
            "mean_occupancy": self.mean_occupancy,
            "max_queue_depth": max(self.queue_depth, default=0),
            "mean_ttft_s": (float(np.mean(list(self.ttft_s.values())))
                            if self.ttft_s else 0.0),
            "t_total_s": self.t_total_s,
            "t_prefill_s": self.t_prefill_s,
        }


class ServingEngine:
    """Continuous batching over a paged KV pool.

    Parameters
    ----------
    cfg:
        Model config (``repro.configs``).
    batch:
        Decode slots — the persistent decode program's batch bucket.
    max_seq:
        Per-request capacity ceiling (prompt + generated), rounded up to
        whole blocks; sets the block-table width ``W``.
    block:
        KV block size (pool rows per block).
    n_blocks:
        Pool capacity.  Default sizes for all slots at full length plus
        the scratch block.
    bucket:
        Prefill bucket policy (``buckets.bucket_len``): "auto" (pow2 for
        pad-free archs, exact otherwise), "pow2", or "exact".
    eos_id:
        Optional early-exit token id.  Checking it costs one host sync
        per decode step, so it is opt-in; default is length-based
        eviction only.
    """

    def __init__(self, cfg, *, batch: int = 4, max_seq: int = 128,
                 block: int = 16, n_blocks: int | None = None, mesh=None,
                 params=None, seed: int = 0, plan_cache=None,
                 bucket: str = "auto", eos_id: int | None = None):
        self.cfg = cfg
        self.batch = batch
        self.block = block
        self.W = -(-max_seq // block)
        self.seq = self.W * block
        self.eos_id = eos_id
        self.mesh = mesh or make_host_mesh()
        if n_blocks is None:
            n_blocks = 1 + batch * self.W
        self.alloc = BlockAllocator(n_blocks, block)
        self.registry = BucketRegistry(cfg, self.mesh, plan_cache=plan_cache,
                                       bucket=bucket)

        dent = self.registry.decode(self.seq, batch, block)
        self.policy = dent.policy
        self._decode = dent.step
        if params is None:
            params = tf.init_params(cfg, jax.random.PRNGKey(seed))
        self.params = jax.device_put(
            params, tf.param_shardings(cfg, self.policy, self.mesh))

        self.caches = tf.init_paged_caches(cfg, batch, n_blocks, block)
        self.tokens = jnp.zeros((batch, 1), jnp.int32)
        self.tables = np.zeros((batch, self.W), np.int32)
        self.pos = np.zeros((batch,), np.int32)
        self.slots: list[Request | None] = [None] * batch
        self._admit = make_admit_fn(cfg)
        self._queue: deque[Request] = deque()
        self._done: list[Request] = []
        self._next_rid = 0
        self._step_log: list = []     # per-step (batch, 1) device tokens
        self.metrics = ServeMetrics()

    # -- API ------------------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        need = self.alloc.blocks_for(len(prompt) + max_new)
        if need > self.W:
            raise ValueError(f"request needs {need} blocks > table width "
                             f"{self.W} (raise max_seq)")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new=max_new,
                      submit_t=time.time())
        self._queue.append(req)
        return rid

    def run(self) -> tuple[dict[int, np.ndarray], ServeMetrics]:
        """Drain the queue; returns ({rid: (n_tokens,) int32}, metrics)."""
        t0 = time.time()
        while self._queue or any(s is not None for s in self.slots):
            admitted = self._admit_phase()
            active = [s for s in self.slots if s is not None]
            if not active:
                if self._queue and not admitted:
                    raise RuntimeError(
                        "admission deadlock: empty batch but queued request "
                        "cannot get blocks — pool too small for one request")
                continue
            self.metrics.queue_depth.append(len(self._queue))
            self.metrics.occupancy.append(len(active) / self.batch)
            self._decode_phase()
        results = self._drain()
        self.metrics.t_total_s += time.time() - t0
        return results, self.metrics

    # -- loop phases ----------------------------------------------------------

    def _admit_phase(self) -> int:
        admitted = 0
        while self._queue and None in self.slots:
            req = self._queue[0]
            blocks = self.alloc.alloc(
                self.alloc.blocks_for(len(req.prompt) + req.max_new))
            if blocks is None:
                break
            self._queue.popleft()
            self._prefill_into(req, self.slots.index(None), blocks)
            admitted += 1
        return admitted

    def _prefill_into(self, req: Request, slot: int, blocks: list[int]):
        t0 = time.time()
        plen = len(req.prompt)
        ent = self.registry.prefill(plen)
        bl = ent.key[2]
        padded = np.zeros((1, bl), np.int32)
        padded[0, :plen] = req.prompt
        logits, pre_caches = ent.step(self.params, {"tokens": padded},
                                      jnp.int32(plen - 1))
        tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)  # (1,)
        # TTFT is defined at the first token's availability: sync here (one
        # per request, not per step)
        req.first_tok = int(jax.device_get(tok0)[0])
        req.ttft_s = time.time() - req.submit_t
        self.metrics.ttft_s[req.rid] = req.ttft_s
        self.metrics.prefills += 1

        row = np.zeros((self.W,), np.int32)
        row[:len(blocks)] = blocks
        self.tables[slot] = row
        self.pos[slot] = plen
        self.caches, self.tokens = self._admit(
            self.caches, pre_caches, jnp.asarray(row), jnp.int32(slot),
            tok0, self.tokens)
        req.slot, req.blocks = slot, blocks
        req.step_start = len(self._step_log)
        self.slots[slot] = req
        self.metrics.t_prefill_s += time.time() - t0
        if req.max_new == 1:
            self._evict(req)

    def _decode_phase(self):
        tok, self.caches = self._decode(
            self.params, self.tokens, self.caches,
            jnp.asarray(self.tables), jnp.asarray(self.pos))
        self.tokens = tok
        self._step_log.append(tok)
        self.metrics.decode_steps += 1
        eos_row = (np.asarray(tok)[:, 0]
                   if self.eos_id is not None else None)  # opt-in sync
        for req in list(self.slots):
            if req is None:
                continue
            req.n_dec += 1
            self.pos[req.slot] += 1
            hit_eos = (eos_row is not None
                       and eos_row[req.slot] == self.eos_id)
            if req.total >= req.max_new or hit_eos:
                self._evict(req)

    def _evict(self, req: Request):
        self.alloc.release(req.blocks)
        self.tables[req.slot] = 0
        self.pos[req.slot] = 0
        self.slots[req.slot] = None
        req.done = True
        self._done.append(req)

    def _drain(self) -> dict[int, np.ndarray]:
        if self._step_log:
            mat = np.asarray(jnp.concatenate(self._step_log, axis=1))
        else:
            mat = np.zeros((self.batch, 0), np.int32)
        out: dict[int, np.ndarray] = {}
        for req in self._done:
            cols = range(req.step_start, req.step_start + req.n_dec)
            gen = np.asarray(
                [req.first_tok] + [int(mat[req.slot, j]) for j in cols],
                np.int32)
            self.metrics.tokens_generated += len(gen)
            out[req.rid] = gen
        self._step_log.clear()
        self._done.clear()
        return out
