"""Paged KV-cache plumbing for the serving tier.

Device side, the pool is ``models.attention.PagedKVCache`` — ``n_blocks``
blocks of ``block`` cache rows shared by every decode slot — and the
per-step lookup is the ``kv_block_gather`` OpDef, so the planner prices it
and the shard_map executor lowers it like any other op.  This module owns
the *host* side: a free-list block allocator, and the jitted admission
scatter that moves a bucketed prefill's collected caches into the pool
under a slot's block table.

Block 0 is reserved as scratch: idle slots keep all-zero table rows, so
their (masked, never-read) decode writes land there instead of in live
blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import PagedKVCache


class BlockAllocator:
    """Free-list allocator over pool blocks 1..n_blocks-1 (0 = scratch).

    ``alloc(n)`` hands out ``n`` block ids or ``None`` if the pool cannot
    satisfy the request (admission then waits for an eviction — all-or-
    nothing keeps table rows contiguous-by-request and deadlock analysis
    trivial).  ``release`` returns a request's blocks at eviction.
    """

    def __init__(self, n_blocks: int, block: int):
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is scratch)")
        self.n_blocks = int(n_blocks)
        self.block = int(block)
        # pop() from the tail -> ids hand out in 1, 2, 3, ... order
        self._free = list(range(self.n_blocks - 1, 0, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        return out

    def release(self, blocks: list[int]) -> None:
        live = set(self._free)
        for b in blocks:
            if not 0 < b < self.n_blocks or b in live:
                raise ValueError(f"release: bad/double-freed block {b}")
        self._free.extend(blocks)

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` cache rows."""
        return -(-int(tokens) // self.block)


def _scatter_kv(pool: PagedKVCache, k, v, blocks) -> PagedKVCache:
    """Write a prefill KV cache (L, 1, s, kh, hd) into a stacked pool
    (L, N, blk, kh, hd) under table row ``blocks`` (W,).

    The source is padded/truncated to the full W*blk rows: rows past the
    prompt land either in the slot's own not-yet-reached blocks (decode
    overwrites row ``pos`` before any mask admits it) or — where the table
    row is 0-padded — in the scratch block.  Fixed W keeps the jit shape
    stable across prompt lengths within a bucket.
    """
    blk = pool.k.shape[2]
    W = blocks.shape[0]

    def prep(x):
        x = x[:, 0]                         # (L, s, kh, hd)
        L, s, kh, hd = x.shape
        rows = W * blk
        if s < rows:
            x = jnp.pad(x, ((0, 0), (0, rows - s), (0, 0), (0, 0)))
        else:
            x = x[:, :rows]
        return x.reshape(L, W, blk, kh, hd)

    return PagedKVCache(pool.k.at[:, blocks].set(prep(k)),
                        pool.v.at[:, blocks].set(prep(v)))


def _set_slot(state, src, slot):
    """Insert a batch-1 prefill state tree into row ``slot`` of the stacked
    decode state tree (leaves (L, b, ...) <- (L, 1, ...))."""
    return jax.tree.map(lambda d, s: d.at[:, slot].set(s[:, 0]), state, src)


def make_admit_fn(cfg):
    """Jitted admission: scatter one request's prefill caches into the
    paged decode caches and seed its first token.

    Signature: ``admit(caches, pre_caches, blocks, slot, tok0, tokens) ->
    (caches, tokens)`` with ``blocks`` the (W,) int32 table row, ``slot``
    a traced scalar, ``tok0`` the prefill argmax (1,) int32.  Donates the
    caches (pure in-place update on device); the token buffer is NOT
    donated — the engine's step log aliases it.
    """
    pattern = cfg.block_pattern

    def admit(caches, pre_caches, blocks, slot, tok0, tokens):
        new = []
        for i, blk_kind in enumerate(pattern):
            cache, pre = caches[i], pre_caches[i]
            if blk_kind == "attn":
                k, v = pre
                new.append(_scatter_kv(cache, k, v, blocks))
            elif blk_kind == "hymba":
                (k, v), st_pre = pre
                pool, st = cache
                new.append((_scatter_kv(pool, k, v, blocks),
                            _set_slot(st, st_pre, slot)))
            else:  # mlstm / slstm: per-slot recurrent state rows
                new.append(_set_slot(cache, pre, slot))
        tokens = tokens.at[slot, 0].set(tok0[0])
        return new, tokens

    return jax.jit(admit, donate_argnums=(0,))
