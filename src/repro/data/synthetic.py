"""Deterministic synthetic token pipeline.

Every global step maps to a unique counter-based seed, so (a) a restarted
or elastically-rescaled run replays *exactly* the same global batches
(straggler/preemption recovery, DESIGN.md §7), and (b) each host
materializes only its addressable shard of the global batch.

The synthetic distribution is a Zipf-ish unigram mix with short repeated
motifs — enough structure that a real model's loss visibly drops, which the
training examples assert.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SyntheticLM:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0
    motif_len: int = 8
    n_motifs: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._motifs = rng.integers(
            0, self.vocab, size=(self.n_motifs, self.motif_len))
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)

    def global_batch_at(self, step: int) -> dict[str, np.ndarray]:
        """The full (global_batch, seq) batch for a step — deterministic."""
        rows = [self._row(step, i) for i in range(self.global_batch)]
        toks = np.stack(rows).astype(np.int32)
        return {"tokens": toks, "labels": toks}

    def host_batch_at(self, step: int, host_index: int, num_hosts: int
                      ) -> dict[str, np.ndarray]:
        """Only this host's contiguous rows of the global batch."""
        per = self.global_batch // num_hosts
        rows = [self._row(step, host_index * per + i) for i in range(per)]
        toks = np.stack(rows).astype(np.int32)
        return {"tokens": toks, "labels": toks}

    def _row(self, step: int, row: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, row]))
        out = rng.choice(self.vocab, size=self.seq, p=self._unigram)
        # splice motifs for learnable short-range structure
        n = max(1, self.seq // (4 * self.motif_len))
        for _ in range(n):
            m = rng.integers(0, self.n_motifs)
            pos = rng.integers(0, max(1, self.seq - self.motif_len))
            out[pos : pos + self.motif_len] = self._motifs[m]
        return out


def batch_shardings(policy, mesh, batch_spec: dict):
    """NamedShardings for a batch dict (tokens/labels on 'b s', embeds on
    'b s a')."""
    out = {}
    for k, sds in batch_spec.items():
        labels = "b s a" if k == "prefix_embeds" else "b s"
        if k == "pos":
            out[k] = None
            continue
        out[k] = policy.sharding(mesh, labels, sds.shape)
    return out
