from repro.data.synthetic import SyntheticLM, batch_shardings
