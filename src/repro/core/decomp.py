"""EinDecomp (paper §6, §8): choose a partitioning vector per EinGraph node.

Two search spaces (DESIGN.md §2, first adaptation):

* ``viable_pow2`` — the paper's space: every unique label gets a power-of-two
  partition count, the product over unique labels is exactly p ("enough
  parallel work": p join results = p kernel calls, §6).  Counting matches
  §8.1's balls-in-buckets formula.  Used by the reference TRA runtime and
  the paper-figure benchmarks.

* ``viable_mesh`` — the torus-conformable subset: assignments of whole named
  mesh axes to labels.  Every axis must be assigned (idle axes = replicated
  compute), so the product is exactly p = prod(mesh shape) whenever bounds
  permit.  Each element also records the label->axes map needed to emit a
  ``PartitionSpec`` (core/plan.py).

The DP is §8.2/8.3 verbatim: a table M[(v, d_Z)] = optimal cost of the
subgraph up to v given output partitioning d_Z, filled in topological order;
the input-side ``min over d_X of M[vX, dX] + cost_repart(...)`` is memoized
per (producer, target) pair.  General DAGs are linearized per §8.4.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Iterable, Sequence

# CostModel lives in core/cost.py (where the calibration hook is); it is
# re-exported here because the planner surface historically owned it.
from repro.core.cost import CostModel, cost_repart, node_cost  # noqa: F401
from repro.core.einsum import EinGraph, EinSpec, Node
from repro.core.tra import ld_concat, project

# ---------------------------------------------------------------------------
# Partitioning enumeration (§8.1)
# ---------------------------------------------------------------------------


def _pow2_splits(total_log2: int, n_buckets: int):
    """All ways to place `total_log2` balls into `n_buckets` buckets."""
    if n_buckets == 0:
        if total_log2 == 0:
            yield ()
        return
    for first in range(total_log2 + 1):
        for rest in _pow2_splits(total_log2 - first, n_buckets - 1):
            yield (first,) + rest


def count_partitionings(n_log2p: int, n_labels: int) -> int:
    """(N + D - 1)! / (N! (D-1)!) — §8.1."""
    return math.comb(n_log2p + n_labels - 1, n_labels - 1)


def node_label_universe(node: Node) -> tuple[str, ...]:
    """Unique labels of a node: for einsum the ⊙ of its input labels (join +
    agg structure); for opaque/input/map, output labels plus any declared
    input labels."""
    if node.kind == "einsum":
        if len(node.spec.in_labels) == 2:
            return tuple(ld_concat(*node.spec.in_labels))
        return tuple(node.spec.in_labels[0])
    labels = list(node.labels)
    for ls in node.in_labels:
        for l in ls:
            if l not in labels:
                labels.append(l)
    return tuple(labels)


def node_bounds(g: EinGraph, nid: int) -> dict[str, int]:
    """{label: bound} for every label in the node's universe."""
    node = g.nodes[nid]
    bounds: dict[str, int] = {}
    for l, s in zip(node.labels, node.shape):
        bounds[l] = s
    if node.kind == "einsum":
        for ls, a in zip(node.spec.in_labels, node.inputs):
            for l, s in zip(ls, g.nodes[a].shape):
                bounds[l] = s
    elif node.in_labels:
        for ls, a in zip(node.in_labels, node.inputs):
            for l, s in zip(ls, g.nodes[a].shape):
                bounds[l] = s
    return bounds


def viable_pow2(
    g: EinGraph, nid: int, p: int, *, divisible: bool = True
) -> list[dict[str, int]]:
    """All {label: parts} maps with power-of-two entries whose product over
    the node's unique labels is exactly p (§6: exactly p kernel calls).

    For opaque nodes, non-shardable labels are pinned to 1; if p parallel
    pieces are unreachable, the largest reachable power of two is used
    (beyond-paper necessity: the paper has no opaque nodes).
    """
    node = g.nodes[nid]
    labels = node_label_universe(node)
    bounds = node_bounds(g, nid)
    n = p.bit_length() - 1
    assert (1 << n) == p, "p must be a power of two (§8.1)"

    shardable = [True] * len(labels)
    if node.kind == "opaque" and node.shardable is not None:
        shardable = [l in node.shardable for l in labels]

    # per-label max log2 parts (2^m must divide the bound)
    def maxlog(l: str) -> int:
        b = bounds[l]
        m = 0
        while b % 2 == 0:
            m += 1
            b //= 2
        return m if divisible else max(0, bounds[l].bit_length() - 1)

    caps = [maxlog(l) if s else 0 for l, s in zip(labels, shardable)]
    target = min(n, sum(caps))
    out: list[dict[str, int]] = []
    for split in _pow2_splits(target, len(labels)):
        if all(e <= c for e, c in zip(split, caps)):
            out.append({l: 1 << e for l, e in zip(labels, split)})
    return out


@dataclass(frozen=True)
class MeshChoice:
    """One torus-conformable partitioning: parts per label + axis map."""

    d: tuple[tuple[str, int], ...]          # sorted (label, parts)
    axes: tuple[tuple[str, tuple[str, ...]], ...]  # label -> mesh axes

    @property
    def d_by_label(self) -> dict[str, int]:
        return dict(self.d)

    @property
    def axes_by_label(self) -> dict[str, tuple[str, ...]]:
        return dict(self.axes)


def viable_mesh(
    g: EinGraph, nid: int, mesh_axes: dict[str, int], *, allow_idle: bool = False
) -> list[MeshChoice]:
    """Torus-conformable partitionings: each named mesh axis is assigned to
    exactly one label (or left idle when ``allow_idle`` / unavoidable).
    Parts per label = product of its axes' sizes; must divide the bound."""
    node = g.nodes[nid]
    labels = node_label_universe(node)
    bounds = node_bounds(g, nid)
    shardable = set(labels)
    if node.kind == "opaque" and node.shardable is not None:
        shardable = {l for l in labels if l in node.shardable}

    axis_names = list(mesh_axes)
    options: list[MeshChoice] = []
    # each axis -> one of the labels, or None (idle).  Labels are offered in
    # node order so tie-optimal plans are deterministic across processes
    # (python set order is hash-randomized).
    ordered = [l for l in labels if l in shardable]
    slots: list[list[str | None]] = []
    for ax in axis_names:
        slots.append(ordered + [None])
    seen = set()
    for assign in itertools.product(*slots):
        if not allow_idle and any(a is None for a in assign):
            continue
        d: dict[str, int] = {l: 1 for l in labels}
        ax_map: dict[str, list[str]] = {}
        ok = True
        for ax, lab in zip(axis_names, assign):
            if lab is None:
                continue
            d[lab] *= mesh_axes[ax]
            ax_map.setdefault(lab, []).append(ax)
        for l in labels:
            if bounds[l] % d[l] != 0:
                ok = False
                break
        if not ok:
            continue
        key = (tuple(sorted(d.items())), tuple(sorted((k, tuple(v)) for k, v in ax_map.items())))
        if key in seen:
            continue
        seen.add(key)
        options.append(MeshChoice(
            tuple(sorted(d.items())),
            tuple(sorted((k, tuple(v)) for k, v in ax_map.items())),
        ))
    if not options and not allow_idle:
        return viable_mesh(g, nid, mesh_axes, allow_idle=True)
    return options


# ---------------------------------------------------------------------------
# Input partitioning domains
# ---------------------------------------------------------------------------


def input_partitionings(shape: Sequence[int], p: int) -> list[tuple[int, ...]]:
    """Possible pre-partitionings for a graph input: power-of-two slicings
    with total parts <= p (inputs are placed offline, §8.2: cost 0)."""
    n = p.bit_length() - 1
    caps = []
    for b in shape:
        m = 0
        bb = int(b)
        while bb % 2 == 0:
            m += 1
            bb //= 2
        caps.append(m)
    outs = set()
    for total in range(n + 1):
        for split in _pow2_splits(total, len(caps)):
            if all(e <= c for e, c in zip(split, caps)):
                outs.add(tuple(1 << e for e in split))
    return sorted(outs)


# ---------------------------------------------------------------------------
# The DP (§8.2, §8.3)
# ---------------------------------------------------------------------------


@dataclass
class Plan:
    """Result of EinDecomp: per-node partitioning (+ mesh axes if mesh mode)."""

    p: int
    d_by_node: dict[int, dict[str, int]] = field(default_factory=dict)
    axes_by_node: dict[int, dict[str, tuple[str, ...]]] = field(default_factory=dict)
    cost: int = 0
    mode: str = "pow2"  # or "mesh"

    def out_parts(self, g: EinGraph, nid: int) -> tuple[int, ...]:
        d = self.d_by_node[nid]
        return tuple(d.get(l, 1) for l in g.nodes[nid].labels)

    def to_json(self) -> dict:
        return {
            "p": self.p,
            "mode": self.mode,
            "cost": self.cost,
            "d": {str(k): v for k, v in self.d_by_node.items()},
            "axes": {str(k): {l: list(a) for l, a in v.items()}
                     for k, v in self.axes_by_node.items()},
        }

    @classmethod
    def from_json(cls, obj: dict) -> "Plan":
        plan = cls(p=obj["p"], mode=obj.get("mode", "pow2"), cost=obj.get("cost", 0))
        plan.d_by_node = {int(k): dict(v) for k, v in obj["d"].items()}
        plan.axes_by_node = {
            int(k): {l: tuple(a) for l, a in v.items()}
            for k, v in obj.get("axes", {}).items()}
        return plan


class _DPState:
    """M table + choice backpointers + memoized best-input costs."""

    def __init__(self, g: EinGraph, p: int, cm: "CostModel | None" = None):
        self.g = g
        self.p = p
        self.cm = cm or CostModel()
        # M[(nid, dZ)] = cost; dZ a tuple over node.labels
        self.M: dict[tuple[int, tuple[int, ...]], float] = {}
        # choice[(nid, dZ)] = full d_by_label achieving it
        self.choice: dict[tuple[int, tuple[int, ...]], dict[str, int]] = {}
        self._best_in: dict[tuple[int, tuple[int, ...], int], float] = {}

    def entries(self, nid: int) -> list[tuple[tuple[int, ...], float]]:
        return [(dz, c) for (v, dz), c in self.M.items() if v == nid]

    def best_input_cost(self, a: int, target: tuple[int, ...],
                        sites: int = 1) -> float:
        """min over dA of M[a, dA] + cost_repart(dA -> target)  (§8.3)."""
        key = (a, target, sites)
        if key in self._best_in:
            return self._best_in[key]
        bound = self.g.nodes[a].shape
        best = math.inf
        for da, c in self.entries(a):
            best = min(best, c + self.cm.repart(da, target, bound, sites=sites))
        self._best_in[key] = best
        return best


def _node_choices(g: EinGraph, nid: int, p: int,
                  mesh_axes: dict[str, int] | None) -> list[tuple[dict[str, int], dict]]:
    """(d_by_label, axes_by_label) candidates for a node."""
    if mesh_axes is None:
        return [(d, {}) for d in viable_pow2(g, nid, p)]
    return [(c.d_by_label, c.axes_by_label) for c in viable_mesh(g, nid, mesh_axes)]


def eindecomp(
    g: EinGraph,
    p: int,
    *,
    mesh_axes: dict[str, int] | None = None,
    offpath_repart: bool = False,
    cost_mode: str = "paper",
    cache: "object | None" = None,
) -> Plan:
    """Run EinDecomp over a general DAG via §8.4 linearization.

    ``offpath_repart=True`` is the beyond-paper EinDecomp+ refinement: when an
    off-path input already has a partitioning assigned from a previous path,
    charge the true repartition cost instead of ignoring it.

    ``cache`` is an optional ``core.plancache.PlanCache``.  When given, the
    cache is consulted first under the canonical key of ``(g, p, mesh_axes,
    cost_mode, offpath_repart)`` — a hit returns a label-translated copy of
    the stored plan without running the DP at all — and on a miss the fresh
    plan is inserted before returning.  The per-path DP is additionally
    memoized on canonical path signatures (plancache.path_memo_key), so
    isomorphic layers inside one graph plan once.

    ``cost_mode`` may also be a ``CostModel`` instance (e.g.
    ``CostModel.with_measured(...)``) — its calibration coefficients then
    enter the cache key, so calibrated and formula-priced plans never
    collide.
    """
    # plan-time validation: every opaque comm declaration must resolve to a
    # registered shard rule, so the executor can realize what the DP priced
    from repro.core import opaque_rules

    opaque_rules.validate_graph(g)
    if isinstance(cost_mode, CostModel):
        cm = cost_mode
        cost_mode = cm.mode if not cm.coeffs else (
            f"{cm.mode}|{sorted(cm.coeffs.items())}")
    else:
        cm = CostModel(cost_mode)
    cache_kw = dict(mesh_axes=mesh_axes, cost_mode=cost_mode,
                    offpath_repart=offpath_repart, algo="eindecomp")
    if cache is not None:
        hit = cache.lookup(g, p, **cache_kw)
        if hit is not None:
            return hit
        from repro.core import plancache as _pc

    mode = "mesh" if mesh_axes is not None else "pow2"
    plan = Plan(p=p, mode=mode)
    labeled: set[int] = set()

    while True:
        path = _longest_unlabeled_path(g, labeled)
        if not path:
            break
        memo_key = memo_val = None
        if cache is not None:
            memo_key = _pc.path_memo_key(g, path, labeled, plan, p,
                                         mesh_axes, cost_mode, offpath_repart)
            memo_val = cache.path_memo_get(memo_key)
        if memo_val is not None:
            _pc.apply_path(g, path, memo_val, plan)
        else:
            _optimize_path(g, path, p, plan, labeled, mesh_axes,
                           offpath_repart, cm=cm)
            if cache is not None:
                cache.path_memo_put(memo_key, _pc.snapshot_path(g, path, plan))
        labeled.update(path)

    # inputs + map nodes inherit partitionings from consumers / producers
    _finalize_inputs(g, plan)
    # the per-path DP cost is an upper bound (it double-counts off-path
    # boundaries); report the exact §7 objective of the final labeling
    # (always the *paper* objective so plans are comparable across modes)
    plan.cost = plan_cost(g, plan)
    if cache is not None:
        cache.insert(g, p, plan, **cache_kw)
    return plan


def eindecomp_tree(
    g: EinGraph, p: int, *, mesh_axes: dict[str, int] | None = None,
    cache: "object | None" = None,
) -> Plan:
    """The exact §8.2 DP — valid when no non-input vertex has >1 consumer.
    Used by the tests to validate the linearized version against optimal.
    ``cache`` behaves as in ``eindecomp`` (keyed separately: the tree DP's
    reported cost is the exact DP objective, not ``plan_cost``)."""
    from repro.core import opaque_rules

    opaque_rules.validate_graph(g)
    cache_kw = dict(mesh_axes=mesh_axes, algo="tree")
    if cache is not None:
        hit = cache.lookup(g, p, **cache_kw)
        if hit is not None:
            return hit
    cons = g.consumers()
    for n in g.nodes:
        if n.kind != "input" and len(cons[n.nid]) > 1:
            raise ValueError("eindecomp_tree requires single-consumer graphs (§8.4)")
    order = [nid for nid in g.topo_order() if g.nodes[nid].kind != "input"]
    plan = Plan(p=p, mode="mesh" if mesh_axes else "pow2")
    cost = _optimize_path(g, order, p, plan, set(), mesh_axes, False,
                          include_all_inputs=True, cm=CostModel())
    _finalize_inputs(g, plan)
    plan.cost = cost
    if cache is not None:
        cache.insert(g, p, plan, **cache_kw)
    return plan


def _longest_unlabeled_path(g: EinGraph, labeled: set[int]) -> list[int]:
    """Longest directed path through unlabeled non-input vertices (§8.4)."""
    best_len: dict[int, int] = {}
    best_pred: dict[int, int | None] = {}
    order = g.topo_order()
    for nid in order:
        n = g.nodes[nid]
        if n.kind == "input" or nid in labeled:
            continue
        best_len[nid] = 1
        best_pred[nid] = None
        for a in n.inputs:
            if a in best_len and best_len[a] + 1 > best_len[nid]:
                best_len[nid] = best_len[a] + 1
                best_pred[nid] = a
    if not best_len:
        return []
    end = max(best_len, key=lambda k: (best_len[k], k))
    path = [end]
    while best_pred[path[-1]] is not None:
        path.append(best_pred[path[-1]])
    path.reverse()
    return path


def _optimize_path(
    g: EinGraph,
    path: list[int],
    p: int,
    plan: Plan,
    labeled: set[int],
    mesh_axes: dict[str, int] | None,
    offpath_repart: bool,
    include_all_inputs: bool = False,
    cm: "CostModel | None" = None,
) -> int:
    """DP along one path (or a whole tree when include_all_inputs)."""
    cm = cm or CostModel()
    state = _DPState(g, p, cm)
    onpath = set(path)
    axes_choice: dict[tuple[int, tuple[int, ...]], dict] = {}

    # graph inputs need no seeding: _in_table/_input_cost enumerate their
    # pre-partitionings (§8.2, cost 0) directly wherever they are consumed

    for nid in path:
        n = g.nodes[nid]
        if n.kind == "map":
            # transparent: inherit the input's table (zero cost, no movement)
            a = n.inputs[0]
            for da, c in _in_table(state, g, a, p, onpath, labeled, plan,
                                   include_all_inputs, offpath_repart):
                key = (nid, da)
                if c < state.M.get(key, math.inf):
                    state.M[key] = c
                    state.choice[key] = dict(zip(n.labels, da))
            continue

        bounds = node_bounds(g, nid)
        for d, ax in _node_choices(g, nid, p, mesh_axes):
            if n.kind == "einsum":
                own = cm.node(n.spec, d, bounds)
            else:
                own = _opaque_comm_cost(g, n, d, bounds, p)
            total = float(own)
            feasible = True
            in_label_sets = (n.spec.in_labels if n.kind == "einsum" else
                             (n.in_labels or (n.labels,) * len(n.inputs)))
            for ls, a in zip(in_label_sets, n.inputs):
                target = tuple(d.get(l, 1) for l in ls)
                sites = _consumer_sites(n.kind, target, p)
                c = _input_cost(state, g, a, target, p, onpath, labeled, plan,
                                include_all_inputs, offpath_repart, sites)
                if c is None:
                    feasible = False
                    break
                total += c
            if not feasible:
                continue
            if offpath_repart:
                # EinDecomp+ (beyond §8.4): consumers already labeled on a
                # previous path pin their required input partitioning —
                # charge the true repart instead of ignoring the boundary.
                dz_here = tuple(d.get(l, 1) for l in n.labels)
                for m in _labeled_consumers(g, nid, labeled, onpath, plan):
                    for ls_m in g.edge_labels(m, nid):
                        dm = plan.d_by_node[m]
                        tgt = tuple(dm.get(l, 1) for l in ls_m)
                        total += cm.repart(
                            dz_here, tgt, n.shape,
                            sites=_consumer_sites(g.nodes[m].kind, tgt, p))
            dz = tuple(d.get(l, 1) for l in n.labels)
            key = (nid, dz)
            if total < state.M.get(key, math.inf):
                state.M[key] = total
                state.choice[key] = d
                axes_choice[key] = ax

    # pick the best final entry and backtrack
    finals = state.entries(path[-1])
    if not finals:
        raise RuntimeError(f"no feasible partitioning for path ending at {path[-1]}")
    dz_best, cost = min(finals, key=lambda t: (t[1], t[0]))
    _backtrack(g, state, axes_choice, path, dz_best, plan, p, onpath,
               labeled, include_all_inputs, offpath_repart)
    return int(cost)


def _consumer_sites(kind: str, target: Sequence[int], p: int) -> int:
    """Distinct consumer placement groups an input edge delivers to
    (ROADMAP fix: gathers to replicated consumers traced ~k× the priced
    cost).  Einsum consumers stay at 1 — ``cost_join`` already prices
    replication delivery to the join sites, so charging the edge again
    would double-count.  An opaque consumer with prod(target) distinct
    blocks on a p-device mesh runs each block on p // prod(target)
    replica groups; every group beyond the first receives the tensor
    once more (``cost_repart``'s ``sites`` term)."""
    if kind != "opaque":
        return 1
    t = 1
    for x in target:
        t *= int(x)
    return max(1, p // max(t, 1))


def _labeled_consumers(g, nid, labeled, onpath, plan):
    out = []
    for m in g.nodes:
        if nid in m.inputs and m.nid in plan.d_by_node and m.nid not in onpath:
            out.append(m.nid)
    return out


def _in_table(state, g, a, p, onpath, labeled, plan, include_all, offpath_repart):
    """Enumerate (parts, cost) options for consuming node `a`'s output."""
    node_a = g.nodes[a]
    if a in onpath or (include_all and node_a.kind != "input"):
        return state.entries(a)
    if node_a.kind == "input":
        return [(dparts, 0.0) for dparts in input_partitionings(node_a.shape, p)]
    if a in labeled:
        da = tuple(plan.d_by_node[a].get(l, 1) for l in node_a.labels)
        return [(da, 0.0)]  # its cost was already counted on its own path
    return None  # unlabeled off-path: §8.4 ignores it entirely


def _input_cost(state, g, a, target, p, onpath, labeled, plan,
                include_all, offpath_repart, sites=1):
    node_a = g.nodes[a]
    if a in onpath or (include_all and node_a.kind != "input"):
        c = state.best_input_cost(a, target, sites)
        return None if math.isinf(c) else c
    if node_a.kind == "input":
        # inputs are pre-placed: choose the best pre-partitioning, cost 0
        # if target itself is a valid pre-partitioning else min repart.
        opts = input_partitionings(node_a.shape, p)
        if target in opts:
            return 0.0
        return min(state.cm.repart(o, target, node_a.shape, sites=sites)
                   for o in opts)
    if a in labeled:
        if not offpath_repart:
            return 0.0  # paper-faithful §8.4: ignore cross-path repart
        da = tuple(plan.d_by_node[a].get(l, 1) for l in node_a.labels)
        return float(state.cm.repart(da, target, node_a.shape, sites=sites))
    return 0.0  # unlabeled off-path input: ignored (§8.4)


def _backtrack(g, state, axes_choice, path, dz_final, plan, p, onpath,
               labeled, include_all, offpath_repart):
    """Walk the path backwards assigning the d that realized each optimum."""
    need: dict[int, tuple[int, ...]] = {path[-1]: dz_final}
    for nid in reversed(path):
        n = g.nodes[nid]
        dz = need.get(nid)
        if dz is None:
            # node's output partitioning determined by its consumer's need —
            # if no on-path consumer recorded a need, pick its own best entry
            entries = state.entries(nid)
            dz = min(entries, key=lambda t: (t[1], t[0]))[0]
        key = (nid, dz)
        d = state.choice[key]
        plan.d_by_node[nid] = dict(d)
        if key in axes_choice and axes_choice[key]:
            plan.axes_by_node[nid] = dict(axes_choice[key])
        # propagate required partitionings to on-path producers
        in_label_sets = (n.spec.in_labels if n.kind == "einsum" else
                         (n.in_labels or ((n.labels,) * len(n.inputs))))
        if n.kind == "map":
            in_label_sets = (n.labels,)
        for ls, a in zip(in_label_sets, n.inputs):
            if a in onpath and g.nodes[a].kind != "input":
                target = tuple(d.get(l, 1) for l in ls)
                # producer chooses its own best dA for this target
                best, best_da = math.inf, None
                for da, c in state.entries(a):
                    t = c + cost_repart(da, target, g.nodes[a].shape)
                    if t < best:
                        best, best_da = t, da
                if best_da is not None and a not in plan.d_by_node:
                    need[a] = best_da


def _finalize_inputs(g: EinGraph, plan: Plan) -> None:
    """Assign input-node partitionings: what their first consumer requires.
    Map nodes missing (single-node paths edge cases) inherit their input.

    Labels are node-local, so entries are keyed by the node's *own* labels,
    translating positionally from the consumer's (or producer's) labels —
    the two may differ even though the graphs are semantically identical,
    and plan entries in foreign label spaces would not survive canonical
    translation (core/canon.py)."""
    for n in g.nodes:
        if n.nid in plan.d_by_node:
            continue
        if n.kind == "input":
            cons = [m for m in g.nodes if n.nid in m.inputs and m.nid in plan.d_by_node]
            if cons:
                m = cons[0]
                dm = plan.d_by_node[m.nid]
                for ls_i, a in zip(_in_labels_of(m), m.inputs):
                    if a == n.nid:
                        plan.d_by_node[n.nid] = {
                            nl: dm.get(cl, 1)
                            for nl, cl in zip(n.labels, ls_i)}
                        if m.nid in plan.axes_by_node:
                            am = plan.axes_by_node[m.nid]
                            plan.axes_by_node[n.nid] = {
                                nl: am[cl]
                                for nl, cl in zip(n.labels, ls_i) if cl in am}
                        break
            else:
                plan.d_by_node[n.nid] = {l: 1 for l in n.labels}
        elif n.kind == "map":
            a = n.inputs[0]
            if a in plan.d_by_node:
                src = plan.d_by_node[a]
                al = g.nodes[a].labels
                plan.d_by_node[n.nid] = {
                    nl: src.get(sl, 1) for nl, sl in zip(n.labels, al)}
                if a in plan.axes_by_node:
                    sax = plan.axes_by_node[a]
                    plan.axes_by_node[n.nid] = {
                        nl: sax[sl] for nl, sl in zip(n.labels, al)
                        if sl in sax}


def _in_labels_of(m: Node):
    if m.kind == "einsum":
        return m.spec.in_labels
    if m.kind == "map":
        return (m.labels,)
    return m.in_labels or tuple((m.labels,) * len(m.inputs))


def _opaque_comm_cost(g: EinGraph, n: Node, d: dict[str, int],
                      bounds: dict[str, int], p: int | None = None) -> int:
    """Internal communication of fused opaque ops (beyond-paper: the paper
    has no opaque nodes).  The declaration comes from the op's **OpDef**
    (``opdef.comm_for_node``: the registered comm template renamed into the
    node's instance labels; an explicit per-node ``params["comm"]`` still
    overrides), as entries
    [{"kind": "ring"|"a2a", "label": l, "input": i, "rule": name?}, ...]
    where ``input`` is an input index, or ``-1`` for the node's own output
    (the moved buffer of a combine-style op is its token-sided result, not
    its expert-sided input):

      ring — partitioning `l` r ways makes the referenced tensor circulate
             a ring: (r-1) * numel total floats (each device passes its
             1/r block r-1 hops — ring/flash sequence parallelism).
      a2a  — partitioning `l` r ways makes the referenced tensor cross an
             all-to-all: (r-1) * numel * (p/r) floats.  A *static-shape*
             all-to-all must size every (sender, receiver) lane for the
             worst case (one destination may claim a sender's whole block),
             so the per-group price equals the ring's, and when only r of
             the p processors shard `l` the remaining p/r groups carry the
             (replicated) buffer redundantly — the executor's shard rules
             (core/opaque_rules.py) emit exactly this schedule, which is
             what keeps traced-within-priced honest.  (The ragged
             (r-1)/r * numel ideal would under-price every realizable
             static schedule by p×.)  Without ``p`` the single-group price
             is used.

    The optional ``rule`` names the ``core.opaque_rules`` shard rule that
    *realizes* this schedule in the shard_map executor (defaulting to the
    kind's namesake), so pricing and lowering resolve the same schedule;
    ``eindecomp`` validates the resolution at plan time.
    """
    from repro.core.opdef import comm_for_node

    comm = comm_for_node(n)
    if not comm:
        return 0
    total = 0
    for c in comm:
        r = int(d.get(c["label"], 1))
        if r <= 1:
            continue
        idx = c["input"]
        ls = n.labels if idx == -1 else n.in_labels[idx]
        numel = 1
        for l in ls:
            numel *= bounds[l]
        dup = max((p or r) // r, 1) if c["kind"] == "a2a" else 1
        total += (r - 1) * numel * dup
    return total


# ---------------------------------------------------------------------------
# Baseline heuristics the paper compares against (§9)
# ---------------------------------------------------------------------------


def plan_sqrt(g: EinGraph, p: int) -> Plan:
    """The "SQRT" baseline (§9.2 Exp 1): slice the first two dimensions of
    every tensor sqrt(p) ways each, ignore everything else."""
    import math as _m

    s = 1 << (max(0, (p.bit_length() - 1)) // 2)
    plan = Plan(p=p, mode="pow2")
    for n in g.nodes:
        labels = node_label_universe(n)
        bounds = node_bounds(g, n.nid)
        d = {l: 1 for l in labels}
        picked = 0
        for l in labels:
            if picked >= 2:
                break
            if bounds[l] % s == 0:
                d[l] = s
                picked += 1
        plan.d_by_node[n.nid] = d
    plan.cost = plan_cost(g, plan)
    return plan


def plan_data_parallel(g: EinGraph, p: int, batch_label: str = "b") -> Plan:
    """Classic data parallelism: shard only the batch label everywhere."""
    plan = Plan(p=p, mode="pow2")
    for n in g.nodes:
        labels = node_label_universe(n)
        bounds = node_bounds(g, n.nid)
        d = {l: 1 for l in labels}
        if batch_label in d and bounds[batch_label] % p == 0:
            d[batch_label] = p
        plan.d_by_node[n.nid] = d
    plan.cost = plan_cost(g, plan)
    return plan


def plan_label(g: EinGraph, p: int, label: str) -> Plan:
    """Shard one named label p ways everywhere it appears (e.g. Megatron =
    shard the head/ffn-hidden label; "sequence" = shard s)."""
    plan = Plan(p=p, mode="pow2")
    for n in g.nodes:
        labels = node_label_universe(n)
        bounds = node_bounds(g, n.nid)
        d = {l: 1 for l in labels}
        if label in d and bounds[label] % p == 0:
            d[label] = p
        plan.d_by_node[n.nid] = d
    plan.cost = plan_cost(g, plan)
    return plan


def plan_cost_by_node(g: EinGraph, plan: Plan) -> dict[int, int]:
    """Per-node §7 cost of a fully-labeled plan: each einsum/opaque node's
    own cost (node cost / declared opaque movement) plus the priced
    repartitions of its input edges, attributed to the *consumer* — the
    same attribution ``CollectiveTrace.elems_by_node`` uses, so the
    predicted/traced ratio compares like-for-like per node."""
    out: dict[int, int] = {}
    for n in g.nodes:
        total = 0
        if n.kind == "einsum":
            d = plan.d_by_node[n.nid]
            total += node_cost(n.spec, d, node_bounds(g, n.nid))
        if n.kind == "opaque":
            total += _opaque_comm_cost(g, n, plan.d_by_node.get(n.nid, {}),
                                       node_bounds(g, n.nid), plan.p)
        if n.kind in ("einsum", "opaque"):
            in_sets = _in_labels_of(n)
            d = plan.d_by_node[n.nid]
            for ls, a in zip(in_sets, n.inputs):
                na = g.nodes[a]
                if na.kind == "input":
                    continue  # pre-placed (§8.2)
                da_map = plan.d_by_node.get(a, {})
                da = tuple(da_map.get(l, 1) for l in na.labels)
                target = tuple(d.get(l, 1) for l in ls)
                total += cost_repart(da, target, na.shape,
                                     _consumer_sites(n.kind, target, plan.p))
            out[n.nid] = total
    return out


def plan_cost(g: EinGraph, plan: Plan) -> int:
    """Total §7 cost of a fully-labeled plan: node costs + actual reparts
    between producers and consumers.  (The objective EinDecomp minimizes,
    evaluated exactly — used to compare heuristic plans apples-to-apples.)"""
    return sum(plan_cost_by_node(g, plan).values())


def opaque_node_bound(g: EinGraph, plan: Plan, nid: int) -> int:
    """What ``plan_cost`` attributes to one opaque node: the declared
    internal movement (``_opaque_comm_cost``) plus the priced repartitions
    of its input edges.  A shard rule that realizes the declared schedule
    keeps the node's traced wire elems within this bound — the per-node
    property ``bench_spmd.py --check`` asserts for ring/a2a-ruled nodes
    (the replicated fallback is ~p× over it on sharded inputs)."""
    n = g.nodes[nid]
    assert n.kind == "opaque", (nid, n.kind)
    d = plan.d_by_node.get(nid, {})
    total = _opaque_comm_cost(g, n, d, node_bounds(g, nid), plan.p)
    for ls, a in zip(_in_labels_of(n), n.inputs):
        na = g.nodes[a]
        if na.kind == "input":
            continue  # pre-placed (§8.2)
        da_map = plan.d_by_node.get(a, {})
        da = tuple(da_map.get(l, 1) for l in na.labels)
        target = tuple(d.get(l, 1) for l in ls)
        total += cost_repart(da, target, na.shape,
                             _consumer_sites("opaque", target, plan.p))
    return total
