"""Backward-graph construction for EinGraphs (paper Experiment 2 needs the
*training* computation as an EinGraph so EinDecomp can plan it).

Reverse-mode accumulation where every adjoint is itself an EinSum node:

* contraction  Z[lZ] = sum X[lX] * Y[lY]
    dX[lX] = einsum(dZ[lZ], Y[lY] -> lX)    (and symmetrically dY)
    — with a broadcast node first when lX contains labels absent from
      lZ ∪ lY (a label aggregated out of X alone).
* elementwise add/sub: adjoints pass through (negated for the sub rhs).
* elementwise mul: dX = dZ ⊙ Y.
* map f: dX = dZ ⊙ f'(x) — f' from the map op's OpDef ``grad`` link
  (the historical GRAD_MAPS registry, now a view over core/opdef.py).
* opaque f: the OpDef's VJP rule (``vjp="auto"`` emits derived
  ``<kind>@vjp<i>`` opaque nodes executed through ``jax.vjp`` of the
  forward impl; custom rules build arbitrary backward structure) — an
  OpDef without a VJP raises an actionable error naming the op.

The result is a plain EinGraph (forward + backward nodes), so the same
EinDecomp DP plans fwd+bwd jointly — exactly the paper's FFNN experiment.
"""
from __future__ import annotations

import copy
from typing import Sequence

from repro.core import opdef
from repro.core.einsum import EinGraph, EinSpec

#: map kind -> derivative map kind.  A live view over the unified OpDef
#: registry (every builtin elementwise map declares its grad link in
#: core/opdefs_builtin.py; tests/test_autodiff_gradmaps.py pins coverage).
#: softmax_last is deliberately grad-less: its Jacobian is not diagonal,
#: so it is not derivative-map eligible (grad_graph raises).
GRAD_MAPS = opdef.GRAD_MAPS


def grad_graph(
    g: EinGraph, loss_nid: int, wrt: Sequence[int]
) -> tuple[EinGraph, dict[int, int], int]:
    """Extend a copy of ``g`` with backward nodes.

    Returns (graph, {wrt input nid -> grad nid}, seed input nid).  The seed
    is a new graph input with the loss's shape; feed ones (or an incoming
    cotangent) to evaluate.
    """
    gg = copy.deepcopy(g)
    loss = gg.nodes[loss_nid]
    seed = gg.input("dLoss_seed", loss.labels, loss.shape, loss.dtype)

    adj: dict[int, list[int]] = {loss_nid: [seed]}

    def adjoint_of(nid: int) -> int | None:
        contribs = adj.get(nid)
        if not contribs:
            return None
        while len(contribs) > 1:
            a, b = contribs.pop(), contribs.pop()
            la = gg.nodes[a].labels
            s = " ".join(la)
            contribs.append(gg.einsum(f"{s}, {s} -> {s}", a, b, combine="add",
                                      agg="", name=f"accum{nid}"))
        return contribs[0]

    for nid in reversed(g.topo_order()):
        n = gg.nodes[nid]
        dz = adjoint_of(nid)
        if dz is None or n.kind == "input":
            continue
        if n.kind == "einsum":
            spec = n.spec
            if len(spec.in_labels) == 2:
                lx, ly = spec.in_labels
                lz = spec.out_labels
                if spec.combine == "mul" and spec.agg == "sum":
                    _back_contract(gg, adj, dz, n.inputs[0], lx, n.inputs[1], ly, lz)
                    _back_contract(gg, adj, dz, n.inputs[1], ly, n.inputs[0], lx, lz)
                elif spec.combine in ("add", "sub") and not spec.agg_labels:
                    adj.setdefault(n.inputs[0], []).append(
                        _reshape_adj(gg, dz, lz, lx))
                    rhs = _reshape_adj(gg, dz, lz, ly)
                    if spec.combine == "sub":
                        rhs = gg.map("neg", rhs)
                    adj.setdefault(n.inputs[1], []).append(rhs)
                elif spec.combine == "mul" and not spec.agg_labels:
                    for me, other, lme, loth in ((0, 1, lx, ly), (1, 0, ly, lx)):
                        d = gg.einsum(
                            f"{' '.join(lz)}, {' '.join(loth)} -> {' '.join(lme)}",
                            dz, n.inputs[other], combine="mul",
                            agg="sum" if set(loth) - set(lme) or set(lz) - set(lme)
                            else "")
                        adj.setdefault(n.inputs[me], []).append(d)
                else:
                    raise NotImplementedError(
                        f"grad for combine={spec.combine} agg={spec.agg}")
            else:
                (lx,) = spec.in_labels
                lz = spec.out_labels
                if spec.combine == "id" and spec.agg in ("", "sum"):
                    if set(lx) <= set(lz):
                        adj.setdefault(n.inputs[0], []).append(
                            _reshape_adj(gg, dz, lz, lx))
                    else:  # sum-reduction: adjoint broadcasts back up
                        node_in = gg.nodes[n.inputs[0]]
                        d = gg.opaque(
                            "broadcast_to", [dz], node_in.labels, node_in.shape,
                            in_labels=[tuple(lz)], shardable=node_in.labels,
                            labels=tuple(node_in.labels),
                            shape=tuple(node_in.shape), src_labels=tuple(lz))
                        adj.setdefault(n.inputs[0], []).append(d)
                else:
                    raise NotImplementedError(f"unary grad for {spec.combine}")
        elif n.kind == "map":
            gname = GRAD_MAPS.get(n.op)
            if gname is None:
                raise NotImplementedError(
                    f"grad for map {n.op}: its OpDef declares no grad link "
                    "(ein.defop(..., category='map', grad='<kind>'))")
            local = gg.map(gname, n.inputs[0], **n.params)
            s = " ".join(n.labels)
            d = gg.einsum(f"{s}, {s} -> {s}", dz, local, combine="mul", agg="")
            adj.setdefault(n.inputs[0], []).append(d)
        else:
            # opaque: the OpDef's VJP rule builds the backward nodes
            for a, d in zip(n.inputs, opdef.build_vjp(gg, n, dz)):
                if d is not None:
                    adj.setdefault(a, []).append(d)

    grads: dict[int, int] = {}
    for w in wrt:
        gnid = adjoint_of(w)
        if gnid is None:
            raise ValueError(f"no gradient path to node {w}")
        grads[w] = gnid
    return gg, grads, seed


def _back_contract(gg, adj, dz, target, lt, other, lo, lz):
    """dTarget = einsum(dZ, Other -> lT), broadcasting labels of lT that are
    in neither lZ nor lO (aggregated out of target alone)."""
    avail = set(lz) | set(lo)
    missing = [l for l in lt if l not in avail]
    keep = [l for l in lt if l in avail]
    agg_needed = bool((set(lz) | set(lo)) - set(keep))
    d = gg.einsum(
        f"{' '.join(lz)}, {' '.join(lo)} -> {' '.join(keep)}",
        dz, other, combine="mul", agg="sum" if agg_needed else "")
    if missing:
        node_t = gg.nodes[target]
        d = gg.opaque(
            "broadcast_to", [d], node_t.labels, node_t.shape,
            in_labels=[tuple(keep)], shardable=node_t.labels,
            labels=tuple(node_t.labels), shape=tuple(node_t.shape),
            src_labels=tuple(keep))
    adj.setdefault(target, []).append(d)


def _reshape_adj(gg, dz, l_from, l_to):
    """Transpose/broadcast an adjoint from labels l_from to l_to."""
    if tuple(l_from) == tuple(l_to):
        return dz
    if set(l_to) <= set(l_from):
        return gg.einsum(f"{' '.join(l_from)} -> {' '.join(l_to)}", dz,
                         combine="id",
                         agg="sum" if set(l_from) - set(l_to) else "")
    node = gg.nodes[dz]
    raise NotImplementedError(f"adjoint broadcast {l_from} -> {l_to}")
