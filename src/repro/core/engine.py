"""Execute an EinGraph with JAX, optionally under an EinDecomp plan.

This is the production counterpart of the TRA reference runtime
(core/tra.py): instead of physically pushing keyed sub-tensors through
join/agg/repartition operators, each node lowers to the corresponding jnp
op and the plan is applied as ``jax.lax.with_sharding_constraint`` on node
outputs.  GSPMD then materializes exactly the TRA dataflow — the join is the
per-device block computation, the aggregation is an all-reduce /
reduce-scatter over the mesh axes assigned to the contracted labels, and
repartitions appear as all-gather / all-to-all between nodes (DESIGN.md §2).

The engine is differentiable: ``jax.grad`` through ``run`` gives training
gradients (used by the FFNN experiment and the LM examples).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.einsum import EinGraph, EinSpec, Node, resolve_feeds

# ---------------------------------------------------------------------------
# Per-node lowering
# ---------------------------------------------------------------------------

_COMBINE2_J = {
    "mul": lambda x, y: x * y,
    "add": lambda x, y: x + y,
    "sub": lambda x, y: x - y,
    "div": lambda x, y: x / y,
    "sqdiff": lambda x, y: (x - y) ** 2,
    "absdiff": lambda x, y: jnp.abs(x - y),
    "maximum": jnp.maximum,
    "expsub": lambda x, y: jnp.exp(x - y),
}

_COMBINE1_J = {
    "id": lambda x: x,
    "exp": jnp.exp,
    "neg": lambda x: -x,
    "abs": jnp.abs,
    "square": lambda x: x * x,
}

_AGG_J = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min, "prod": jnp.prod}


def lower_einsum(spec: EinSpec, *args):
    """One EinSum node -> jnp.  Contractions go straight to jnp.einsum (XLA
    dot_general -> MXU); general (⊗,⊕) nodes lower to broadcast + reduce."""
    if spec.is_contraction and len(spec.in_labels) == 2:
        return jnp.einsum(spec.einsum_str(), *args)
    if spec.is_contraction and len(spec.in_labels) == 1 and spec.combine == "id":
        return jnp.einsum(spec.einsum_str(), *args)

    all_labels = spec.all_labels

    def lift(arr, labels):
        perm_src = list(labels)
        for l in all_labels:
            if l not in perm_src:
                arr = arr[..., None]
                perm_src.append(l)
        return jnp.transpose(arr, [perm_src.index(l) for l in all_labels])

    lifted = [lift(a, ls) for a, ls in zip(args, spec.in_labels)]
    if len(lifted) == 2:
        joined = _COMBINE2_J[spec.combine](*lifted)
    else:
        joined = _COMBINE1_J[spec.combine](lifted[0])
    if spec.agg and spec.agg_labels:
        axes = tuple(i for i, l in enumerate(all_labels) if l in spec.agg_labels)
        joined = _AGG_J[spec.agg](joined, axis=axes)
    kept = [l for l in all_labels if l not in spec.agg_labels]
    return jnp.transpose(joined, [kept.index(l) for l in spec.out_labels])


# ---------------------------------------------------------------------------
# map / opaque execution registries.  Since the OpDef redesign these are
# *live views* over the one unified registry (core/opdef.py): built-in ops
# are declared in core/opdefs_builtin.py, new ops through ``ein.defop``.
# The views stay dict-compatible (shared with the dense numpy oracle — all
# impls are backend-polymorphic via jnp) so in-core callers and test
# monkeypatching keep working; direct use outside core/ is lint-banned.
# ---------------------------------------------------------------------------

from repro.core.opdef import MAP_FNS, OPAQUE_FNS  # noqa: E402


def register_opaque(name: str, fn: Callable) -> None:
    """Deprecated: register through the unified OpDef API instead —
    ``ein.defop(name, "<signature>", fn=...)`` bundles the signature, dense
    impl, kernel dispatcher, VJP, comm declaration, and shard rule in one
    record (this shim installs a bare impl with none of that metadata)."""
    from repro.core import opdef

    opdef.register_legacy(name, fn, surface="engine.register_opaque")


# ---------------------------------------------------------------------------
# Plan -> PartitionSpec
# ---------------------------------------------------------------------------


def mesh_axes_dict(mesh: Mesh) -> dict[str, int]:
    """{axis name: size} for a jax Mesh — the planner's mesh description.
    (Re-exported by launch/mesh.py; lives here so core never imports launch.)"""
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for_node(node: Node, axes_by_label: dict[str, tuple[str, ...]]) -> P:
    """PartitionSpec for a node's output from its label->mesh-axes map."""
    entries = []
    for l in node.labels:
        ax = axes_by_label.get(l, ())
        if not ax:
            entries.append(None)
        elif len(ax) == 1:
            entries.append(ax[0])
        else:
            entries.append(tuple(ax))
    # trailing Nones can be dropped but keep explicit for clarity
    return P(*entries)


def plan_shardings(g: EinGraph, plan, mesh: Mesh) -> dict[int, NamedSharding]:
    """NamedSharding per node output for a mesh-mode plan."""
    out = {}
    for n in g.nodes:
        ax = plan.axes_by_node.get(n.nid, {})
        out[n.nid] = NamedSharding(mesh, spec_for_node(n, ax))
    return out


# ---------------------------------------------------------------------------
# Graph execution
# ---------------------------------------------------------------------------


def run(
    g: EinGraph,
    feeds: dict[int, Any],
    *,
    plan=None,
    mesh: Mesh | None = None,
    constrain: bool = True,
) -> dict[int, jnp.ndarray]:
    """Evaluate the graph with jnp.  If a mesh-mode plan is given, each node
    output gets a ``with_sharding_constraint`` so GSPMD realizes the
    EinDecomp decomposition.

    ``feeds`` may be keyed by input *name* or node id (resolve_feeds): the
    reference runtimes and the frontend agree on I/O keys."""
    feeds = resolve_feeds(g, feeds)
    specs = None
    if plan is not None and mesh is not None and plan.axes_by_node:
        specs = {nid: NamedSharding(
            mesh, spec_for_node(g.nodes[nid], plan.axes_by_node.get(nid, {})))
            for nid in range(len(g.nodes))}

    vals: dict[int, jnp.ndarray] = {}
    for nid in g.topo_order():
        n = g.nodes[nid]
        if n.kind == "input":
            v = jnp.asarray(feeds[nid])
        elif n.kind == "einsum":
            v = lower_einsum(n.spec, *[vals[a] for a in n.inputs])
        elif n.kind == "map":
            v = MAP_FNS[n.op](vals[n.inputs[0]], **n.params)
        else:
            v = OPAQUE_FNS[n.op](*[vals[a] for a in n.inputs], **n.call_params)
        if specs is not None and constrain and nid in specs:
            v = jax.lax.with_sharding_constraint(v, specs[nid])
        vals[nid] = v
    return vals


#: executors ``make_runner`` / ``Program.compile`` can build:
#:   gspmd     — per-node ``with_sharding_constraint`` hints; XLA's
#:               partitioner chooses the realized collective schedule.
#:   shard_map — core/spmd.py: the plan's TRA dataflow emitted literally as
#:               named collectives inside one ``jax.shard_map``; opaque
#:               nodes dispatch per-shard through the shard-rule registry
#:               (core/opaque_rules.py: ring attention, a2a expert
#:               parallelism, replicate fallback).
EXECUTORS = ("gspmd", "shard_map")


def make_runner(g: EinGraph, out_ids: Sequence[int] | None = None, *,
                plan=None, mesh: Mesh | None = None, cache=None,
                mesh_axes: dict[str, int] | None = None, p: int | None = None,
                cost_mode: str = "paper",
                offpath_repart: bool = True,
                executor: str = "gspmd",
                collective_trace=None,
                fuse: bool = True,
                lookahead: int = 1) -> Callable:
    """Build a jit-able ``f(feed_list) -> outputs`` for the graph.  Feeds are
    passed positionally in input-node order (differentiable wrt any of them).

    ``executor`` selects how the plan is realized (see ``EXECUTORS``):
    ``"gspmd"`` (default) applies sharding constraints and lets XLA pick the
    collectives; ``"shard_map"`` emits the plan's join→agg→repartition
    dataflow as explicit collectives (requires a mesh-mode plan and a mesh —
    a bare ``mesh`` therefore self-plans under shard_map, where the gspmd
    executor would run unconstrained).
    ``collective_trace`` (a ``core.spmd.CollectiveTrace``) receives the
    static collective schedule of the shard_map executor at build time —
    including the per-node / per-shard-rule attribution (``rule_by_node``,
    ``by_rule``) of the opaque ring/a2a programs.  ``fuse`` (default on,
    shard_map only) routes repartitions through the fused chain planner
    when it moves fewer wire elems; ``fuse=False`` restores the unfused
    per-step lowering.  ``lookahead`` (default 1, shard_map only) is the
    graph-wide overlap window — ready consumers' arg repartitions issue up
    to that many compute nodes early so collectives fly behind local
    compute; ``lookahead=0`` restores the serial issue order verbatim.

    If no ``plan`` is given but planning inputs are (``p``, ``mesh_axes``,
    or a ``mesh`` together with a ``cache``), the runner plans the graph
    itself — consulting ``cache`` (a ``core.plancache.PlanCache``) before
    running the DP, so repeated runner construction for isomorphic graphs
    pays planner latency once.  Sharding constraints only apply when a
    ``mesh`` is given; without one, self-planning is allowed solely to warm
    a ``cache`` (planning with neither is an error — the DP's result would
    be discarded).  An explicit ``plan`` always takes precedence: the other
    planning inputs (``cache``/``p``/``mesh_axes``/``cost_mode``/
    ``offpath_repart``) are then ignored, and in particular the cache is
    not warmed with a caller-provided plan (its planning inputs are
    unknown, so no sound cache key exists for it)."""
    if executor not in EXECUTORS:
        raise ValueError(f"make_runner: unknown executor {executor!r}; "
                         f"choose from {EXECUTORS}")
    if collective_trace is not None and executor != "shard_map":
        raise ValueError("make_runner: collective_trace is only produced by "
                         "the shard_map executor")
    if (plan is None and cache is not None and mesh is None
            and p is None and mesh_axes is None):
        raise ValueError(
            "make_runner: cache given but nothing to plan with — pass "
            "mesh, mesh_axes, or p")
    if plan is None and (p is not None or mesh_axes is not None
                         or (cache is not None and mesh is not None)
                         or (executor == "shard_map" and mesh is not None)):
        from repro.core.decomp import eindecomp

        if mesh is None and cache is None:
            raise ValueError(
                "make_runner: planning inputs (p/mesh_axes) have no effect "
                "without a mesh to shard by or a cache to warm")
        if mesh_axes is None and mesh is not None:
            mesh_axes = mesh_axes_dict(mesh)
        if p is None:
            if not mesh_axes:
                raise ValueError("make_runner: planning needs p or mesh/mesh_axes")
            p = math.prod(mesh_axes.values())
        plan = eindecomp(g, p, mesh_axes=mesh_axes, cost_mode=cost_mode,
                         offpath_repart=offpath_repart, cache=cache)
    in_ids = g.input_ids()
    out_ids = list(out_ids) if out_ids is not None else g.outputs()

    if executor == "shard_map":
        from repro.core import spmd

        if mesh is None or plan is None:
            raise ValueError("make_runner: executor='shard_map' needs a "
                             "mesh and a (mesh-mode) plan")
        mapped = spmd.make_spmd_runner(g, out_ids, plan=plan, mesh=mesh,
                                       trace=collective_trace, fuse=fuse,
                                       lookahead=lookahead)

        def f_spmd(*arrays):
            outs = mapped(*arrays)
            return outs[0] if len(outs) == 1 else outs

        return f_spmd

    def f(*arrays):
        feeds = dict(zip(in_ids, arrays))
        vals = run(g, feeds, plan=plan, mesh=mesh)
        outs = tuple(vals[o] for o in out_ids)
        return outs[0] if len(outs) == 1 else outs

    return f
