"""The built-in op catalog: every map and opaque op the stack ships with,
declared through the unified OpDef API (core/opdef.py).

This module is imported lazily on first registry access
(``opdef._ensure_builtins``).  Each entry is one declarative record —
signature, dense reference impl, optional accelerator kernel dispatcher,
VJP rule, comm declaration, shard-rule binding — replacing the five
separate registries that previously held these pieces (``engine.MAP_FNS``
/ ``engine.OPAQUE_FNS`` / ``autodiff.GRAD_MAPS`` / ``opaque_rules`` comm
dicts / per-call model-builder metadata).

All impls are backend-polymorphic via jnp (the dense numpy oracle calls
them with numpy arrays).  MoE dispatch/combine and the recurrent scans are
*declared* here but carry no production impl — ``models/opaque_stubs``
provides the deterministic reference semantics through
``opdef.provide_impl`` (checked against the signatures declared here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.opdef import defop

# ---------------------------------------------------------------------------
# Elementwise map ops (+ their derivative maps, linked via grad=)
# ---------------------------------------------------------------------------


def _softmax(x, axis=-1):
    x = jnp.asarray(x)
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def _rsqrt_eps(x, eps=1e-6):
    return jax.lax.rsqrt(jnp.asarray(x) + eps)


_MAPS: dict[str, tuple] = {
    # kind: (fn, derivative map kind or None)
    "id": (lambda x: jnp.asarray(x), "one"),
    "exp": (lambda x: jnp.exp(jnp.asarray(x)), "exp"),  # d/dx e^x = e^x
    "neg": (lambda x: -jnp.asarray(x), "neg_one"),
    "relu": (lambda x: jnp.maximum(jnp.asarray(x), 0), "relu_grad"),
    "relu2": (lambda x: jnp.square(jnp.maximum(jnp.asarray(x), 0)),
              "relu2_grad"),
    "silu": (lambda x: jax.nn.silu(jnp.asarray(x)), "silu_grad"),
    "gelu": (lambda x: jax.nn.gelu(jnp.asarray(x)), "gelu_grad"),
    "scale": (lambda x, c=1.0: jnp.asarray(x) * c, "scale_grad"),
    "add_const": (lambda x, c=0.0: jnp.asarray(x) + c, "one"),
    "rsqrt_eps": (_rsqrt_eps, "rsqrt_eps_grad"),
    # softmax_last is deliberately grad-less: its Jacobian is not diagonal,
    # so it is not derivative-map eligible (grad_graph raises).
    "softmax_last": (lambda x: _softmax(x, axis=-1), None),
    "sigmoid": (lambda x: jax.nn.sigmoid(jnp.asarray(x)), "sigmoid_grad"),
    "tanh": (lambda x: jnp.tanh(jnp.asarray(x)), "tanh_grad"),
    "square": (lambda x: jnp.square(jnp.asarray(x)), "two_x"),
    "cast_f32": (lambda x: jnp.asarray(x, jnp.float32), "one"),
}

#: derivative-only helper maps (no grad links of their own)
_DERIV_MAPS = {
    "relu_grad": lambda x: (jnp.asarray(x) > 0).astype(jnp.asarray(x).dtype),
    "relu2_grad": lambda x: 2 * jnp.maximum(jnp.asarray(x), 0),
    "silu_grad": lambda x: jax.grad(
        lambda v: jnp.sum(jax.nn.silu(v)))(jnp.asarray(x)),
    "tanh_grad": lambda x: 1 - jnp.tanh(jnp.asarray(x)) ** 2,
    "sigmoid_grad": lambda x: jax.nn.sigmoid(jnp.asarray(x))
    * (1 - jax.nn.sigmoid(jnp.asarray(x))),
    "two_x": lambda x: 2 * jnp.asarray(x),
    "scale_grad": lambda x, c=1.0: jnp.full_like(jnp.asarray(x), c),
    "one": lambda x, **_: jnp.ones_like(jnp.asarray(x)),
    "gelu_grad": lambda x: jax.grad(
        lambda v: jnp.sum(jax.nn.gelu(v)))(jnp.asarray(x)),
    "neg_one": lambda x: jnp.full_like(jnp.asarray(x), -1),
    # d/dx (x + eps)^(-1/2) = -1/2 (x + eps)^(-3/2)
    "rsqrt_eps_grad": lambda x, eps=1e-6: (
        -0.5 * jax.lax.rsqrt(jnp.asarray(x) + eps) / (jnp.asarray(x) + eps)),
}

# check_impl=False everywhere below: invoking an impl initializes the jax
# backend, and loading this catalog must stay legal from the pure-planning
# path (a metadata-only registry consumer).  tests/test_opdef.py sweeps
# opdef.check_impl over every builtin instead.
# derivative helpers first: defop validates grad= links eagerly
for _kind, _fn in _DERIV_MAPS.items():
    defop(_kind, None, fn=_fn, category="map",
          vjp_reason="derivative helper map — appears only in backward "
                     "graphs and is never itself differentiated")
for _kind, (_fn, _grad) in _MAPS.items():
    defop(_kind, None, fn=_fn, grad=_grad, category="map",
          vjp_reason=None if _grad is not None else
          "softmax Jacobian is not diagonal, so no derivative map exists; "
          "grad_graph rejects it and models differentiate the explicit "
          "exp/sum einsum form instead")


# ---------------------------------------------------------------------------
# Flash attention: signature over (batch, heads, kv-heads, q-seq, ring
# label, head_dim); the ring label ``l`` is what K/V circulate over — the
# model builders rename it to ``s`` (prefill, shared with q) or ``t``
# (decode, the kv-cache time label).
# ---------------------------------------------------------------------------


def _flash_attention_ref(q, k, v, causal=True, window=0, scale=None):
    """Dense reference (b h s d layout), jnp everywhere."""
    from repro.kernels import ops

    return ops.flash_attention(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), causal=causal, window=window,
                               scale=scale, impl="ref")


def _flash_attention_kernel(q, k, v, causal=True, window=0, scale=None):
    """Accelerator dispatcher (kernels/ops.py): Pallas on TPU, the jnp
    reference elsewhere — what execution actually calls."""
    from repro.kernels import ops

    return ops.flash_attention(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), causal=causal, window=window,
                               scale=scale)


defop(
    "flash_attention", "b h s d, b k l d, b k l d -> b h s d",
    fn=_flash_attention_ref, kernel=_flash_attention_kernel, vjp="auto",
    check_impl=False, shardable="b h k l",
    comm=[{"kind": "ring", "label": "l", "input": 1},
          {"kind": "ring", "label": "l", "input": 2}],
    shard_rule="ring")


# ---------------------------------------------------------------------------
# Embedding gather: rows of a (vocab, d_model) table by int ids.  The ids
# are int32 (in_dtypes steers the registration check) and carry no
# gradient; the table grads flow through the auto VJP (a scatter-add).
# ---------------------------------------------------------------------------


def _gather_rows(table, ids):
    return jnp.take(jnp.asarray(table), jnp.asarray(ids).astype(jnp.int32),
                    axis=0)


defop("gather_rows", "v a, b s -> b s a", fn=_gather_rows, vjp="auto",
      check_impl=False, shardable="b s a", in_dtypes=(None, "int32"))


# ---------------------------------------------------------------------------
# Paged KV cache: the serving tier's block-table lookup, declared so the
# planner prices it and the shard_map executor lowers it like any other op
# (the ``gather_rows`` pattern generalized to a two-level block gather).
# ``pool (n, p, k, d)`` holds ``n`` blocks of ``p`` cache rows; ``tables
# (b, w)`` maps each sequence's blocks into the pool; the output is the
# time-ordered cache view ``(b, k, t, d)`` with ``t`` bound by the
# ``kv_len`` call param (t <= w*p; the last block's padding is truncated).
#
# Sharding: batch / kv-heads / head_dim shard freely (the gather is
# independent along them); the cache-time label ``t`` is declared in the
# comm template as an all-to-all — sharding t re-buckets table stripes
# across devices — which the bound ``paged`` rule realizes with zero wire
# whenever the pool is replicated over the t-axes (each device gathers its
# own stripe of table rows locally), so traced <= priced holds with room.
# The block-index labels n/p/w never shard (a split block has no local
# lookup), hence their absence from the shardable set.
# ---------------------------------------------------------------------------


def _kv_block_gather(pool, tables, kv_len):
    from repro.kernels import ops

    return ops.kv_block_gather(pool, tables, int(kv_len))


defop(
    "kv_block_gather", "n p k d, b w -> b k t d",
    fn=_kv_block_gather, vjp="auto", check_impl=False,
    shardable="b k d t", param_bounds={"t": "kv_len"},
    in_dtypes=(None, "int32"),
    comm=[{"kind": "a2a", "label": "t", "input": -1, "rule": "paged"}],
    shard_rule="paged")


# ---------------------------------------------------------------------------
# broadcast_to: the autodiff adjoint carrier (labels/shape arrive as call
# params — fully dynamic, so no signature and no inference).
# ---------------------------------------------------------------------------


def _broadcast(x, src_labels, out_labels, out_shape):
    src = list(src_labels)
    for l in out_labels:
        if l not in src:
            x = x[..., None]
            src.append(l)
    x = jnp.transpose(x, [src.index(l) for l in out_labels])
    return jnp.broadcast_to(x, tuple(out_shape))


defop("broadcast_to", None,
      fn=lambda x, labels=(), shape=(), src_labels=(): (
          _broadcast(jnp.asarray(x), src_labels, labels, shape)),
      vjp_reason="autodiff adjoint carrier — only ever *emitted by* the "
                 "backward pass, never differentiated through")


# ---------------------------------------------------------------------------
# MoE dispatch / combine: expert-parallel a2a schedule.  The capacity
# dimension ``c`` appears in no input — it binds from the ``capacity``
# call param (param_bounds).  Impls are provided by models/opaque_stubs
# (deterministic top-1 routing shared with the a2a shard rule).
# ---------------------------------------------------------------------------

defop(
    "moe_dispatch", "b s a, b s e -> e c a",
    vjp_reason="discrete top-1 routing has no meaningful cotangent; MoE "
               "training backward is future work (ROADMAP)",
    shardable="e c b s", param_bounds={"c": "capacity"},
    comm=[{"kind": "a2a", "label": "e", "input": 0},
          {"kind": "a2a", "label": "c", "input": 0}],
    shard_rule="a2a")

defop(
    "moe_combine", "e c a, b s e -> b s a",
    vjp_reason="discrete top-1 routing has no meaningful cotangent; MoE "
               "training backward is future work (ROADMAP)",
    shardable="e c b s",
    # the moved buffer is the token-sided *output* (input -1): combine
    # returns each token its expert's result, it never moves the full
    # (e, c, a) expert buffer
    comm=[{"kind": "a2a", "label": "e", "input": -1},
          {"kind": "a2a", "label": "c", "input": -1}],
    shard_rule="a2a")


# ---------------------------------------------------------------------------
# Recurrent scans: sequence label is non-partitionable (recurrence), but
# the channel labels are — mLSTM/SSM chunkwise forms are channel-local, so
# the ``local`` shard rule runs the scan per channel shard with zero
# collectives (sLSTM's dense recurrent matrix couples the whole width, so
# only b shards).  Impls from models/opaque_stubs.
# ---------------------------------------------------------------------------

for _scan in ("ssm_scan", "mlstm_scan"):
    defop(_scan, "b s f -> b s f", shardable="b f", shard_rule="local",
          vjp="auto")
defop("slstm_scan", "b s f -> b s f", shardable="b", shard_rule="local",
      vjp="auto")
