"""Explicit-collective SPMD executor: the TRA rewrite executed literally.

The GSPMD engine (core/engine.py) only *hints* the EinDecomp dataflow to XLA
via ``with_sharding_constraint`` — the partitioner is then free to realize a
different repartition schedule than the one the §8 DP priced.  This module
closes that gap: a planned ``EinGraph`` lowers to **one**
``shard_map``-ped function over the mesh in which every data movement the
§4.3 join→agg→repartition rewrite implies is emitted as an explicit named
collective:

  * the *join* is the per-device local block computation (2-ary contractions
    route through ``repro.kernels.ops.matmul`` so the Pallas kernel runs
    per-shard on TPU; everything else lowers through the engine's einsum
    semantics on local blocks);
  * the *aggregation* over mesh-mapped contracted labels is ``lax.psum``
    (or ``pmax``/``pmin``; ``prod`` gathers then reduces) on exactly the
    axes the plan assigned — fused to ``lax.psum_scatter`` when every
    consumer wants the reduced output sharded on the same axis;
  * inter-node *repartitions* are derived statically from
    ``(d_from, d_to)``: un-sharding a dimension is ``lax.all_gather``,
    moving a mesh axis between dimensions is ``lax.all_to_all``, swapping
    which axis shards a dimension is ``lax.ppermute``, and sharding a
    replicated dimension is a free local slice.

Because the whole schedule is a pure function of (graph, plan, mesh shape),
it is computed **before tracing**: ``build_schedule`` returns the per-node
collective program plus a ``CollectiveTrace`` (count + wire bytes per
collective kind, attributed per node and per shard rule) without touching a
single array — the instrumentation the ``bench_spmd`` benchmark compares
against the §7 ``plan_cost`` prediction.

Opaque nodes dispatch through the **shard-rule registry**
(core/opaque_rules.py): a node's ``comm`` declaration resolves to a rule
that emits its per-device program — ring attention circulates K/V via
``ppermute`` with carried online-softmax state, MoE dispatch/combine cross
a real ``all_to_all`` over the expert axis — and every opaque op without a
declared rule (or whose rule's preconditions fail) falls back to the
replicate-gather path: inputs gathered, dense compute, consumers re-slice.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.einsum import EinGraph, EinSpec, Node

#: a layout maps each tensor dimension to the (major→minor) mesh axes that
#: shard it — the executor-side mirror of a PartitionSpec.
Layout = tuple[tuple[str, ...], ...]

#: collective kinds that move data over the wire (local slices are free).
#: a grouped reduce-scatter records as kind "psum_scatter" (one event).
WIRE_KINDS = ("all_gather", "all_to_all", "ppermute", "psum", "psum_scatter",
              "psum_scatter_grouped", "pmax", "pmin", "gather_reduce")


# ---------------------------------------------------------------------------
# Collective trace
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CollectiveEvent:
    """One emitted collective: what, where, and how many wire bytes."""

    kind: str                # one of WIRE_KINDS
    axes: tuple[str, ...]    # mesh axes the collective runs over
    nid: int                 # graph node the movement belongs to
    elems: int               # floats crossing the wire, summed over devices
    nbytes: int              # elems * itemsize
    rule: str = ""           # shard rule that emitted it ("" = einsum path)
    fused: bool = False      # emitted by the fused repartition planner
    overlap: bool = False    # issued to overlap with local compute
    # ppermute only: the exact (src, dst) pairs the executor will issue over
    # the flattened device group — the static analyzer's bijectivity check
    # (repro.analysis RA201) runs over this, so it verifies the permutation
    # that actually executes, not a re-derivation.
    perm: tuple = ()
    # graph-wide lookahead attribution: the consumer node whose argument
    # this event prefetches (-1 = not a hoisted issue).  ``nid`` stays the
    # consumer, so per-node bounds and elems_by_node are issue-order
    # independent; rule-internal overlaps (the ring's double buffer) keep
    # prefetch_for = -1 and are never double-counted against a hoist.
    prefetch_for: int = -1
    # pipeline attribution (repro.pipeline): which stage's sub-schedule
    # emitted the event and during which microbatch it runs (-1 = the
    # unpipelined executor).  Stage handoffs record as rule="handoff"
    # ppermute events over the `pp` axis with both fields set.
    stage: int = -1
    microbatch: int = -1


class CollectiveTrace:
    """Count + wire bytes per collective kind for one compiled program.

    Filled statically at schedule-build time (the schedule is a pure
    function of graph/plan/mesh shape, so no tracing is needed); the same
    numbers the executed program realizes.  Wire costs use ring pricing —
    all-gather moves (k-1)·n_loc per device, all-reduce 2·(k-1)/k·n_loc,
    all-to-all (k-1)/k·n_loc, reduce-scatter (k-1)/k·n_loc, permute n_loc —
    matching launch/hlo_analysis.py's accounting of the GSPMD path.

    Events carry their node and the shard rule that emitted them
    (``rule_by_node`` records which rule lowered each opaque node), so the
    ring/a2a traffic of an opaque hot spot is separable from the einsum
    repartition flow: ``by_rule`` / ``bytes_by_node`` are what
    ``bench_spmd --check`` asserts the per-node ``_opaque_comm_cost`` bound
    against.
    """

    def __init__(self):
        self.events: list[CollectiveEvent] = []
        self.rule_by_node: dict[int, str] = {}

    def add(self, kind: str, axes: Sequence[str], nid: int, elems: int,
            nbytes: int, rule: str = "", *, fused: bool = False,
            overlap: bool = False, perm: Sequence = (),
            prefetch_for: int = -1, stage: int = -1,
            microbatch: int = -1) -> None:
        self.events.append(CollectiveEvent(kind, tuple(axes), nid,
                                           int(elems), int(nbytes), rule,
                                           fused, overlap,
                                           tuple(tuple(p) for p in perm),
                                           int(prefetch_for), int(stage),
                                           int(microbatch)))

    def extend(self, other: "CollectiveTrace") -> None:
        self.events.extend(other.events)
        self.rule_by_node.update(other.rule_by_node)

    def extend_tagged(self, other: "CollectiveTrace", *, stage: int,
                      microbatch: int,
                      nid_map: dict[int, int] | None = None) -> None:
        """Re-emit ``other``'s events with pipeline (stage, microbatch)
        attribution — how the pipeline tier replays one stage's static
        sub-schedule per microbatch into the combined trace.  ``nid_map``
        translates the stage schedule's local node ids back to global
        graph ids, so per-node accounting stays meaningful."""
        remap = nid_map or {}
        self.events.extend(
            dataclasses.replace(e, nid=remap.get(e.nid, e.nid),
                                stage=int(stage),
                                microbatch=int(microbatch))
            for e in other.events)
        self.rule_by_node.update(
            (remap.get(n, n), r) for n, r in other.rule_by_node.items())

    def reset(self) -> None:
        self.events.clear()
        self.rule_by_node.clear()

    @property
    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    @property
    def elems_by_kind(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + e.elems
        return out

    @property
    def bytes_by_kind(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + e.nbytes
        return out

    @property
    def total_elems(self) -> int:
        return sum(e.elems for e in self.events)

    @property
    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self.events)

    @property
    def elems_by_node(self) -> dict[int, int]:
        out: dict[int, int] = {}
        for e in self.events:
            out[e.nid] = out.get(e.nid, 0) + e.elems
        return out

    @property
    def bytes_by_node(self) -> dict[int, int]:
        out: dict[int, int] = {}
        for e in self.events:
            out[e.nid] = out.get(e.nid, 0) + e.nbytes
        return out

    @property
    def fused_elems(self) -> int:
        """Wire elems carried by fused-planner repartitions — each event is
        attributed to the originating (d_from, d_to) pair's consumer node,
        never recorded alongside the unfused steps it replaced."""
        return sum(e.elems for e in self.events if e.fused)

    @property
    def overlapped_elems(self) -> int:
        """Wire elems issued to overlap with local compute — the ring's
        double-buffered K/V hops plus the graph-wide lookahead prefetches
        — the statically auditable overlap attribution.  Each event counts
        once: a hoisted chain is marked ``prefetch_for >= 0``, a
        rule-internal overlap keeps ``prefetch_for = -1``; no event is
        ever both."""
        return sum(e.elems for e in self.events if e.overlap)

    @property
    def prefetched_elems(self) -> int:
        """Wire elems carried by graph-wide lookahead prefetches only
        (hoisted arg repartitions; excludes rule-internal overlaps like
        the ring's double buffer)."""
        return sum(e.elems for e in self.events if e.prefetch_for >= 0)

    @property
    def overlap_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for e in self.events:
            if e.overlap:
                out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def by_rule(self) -> dict[str, dict[str, dict[str, int]]]:
        """{rule: {kind: {"count": n, "elems": e, "bytes": b}}} — the
        per-rule breakdown surfaced as
        ``CompiledProgram.collectives_by_rule``.  Einsum-path events group
        under ``""``."""
        out: dict[str, dict[str, dict[str, int]]] = {}
        for e in self.events:
            slot = out.setdefault(e.rule, {}).setdefault(
                e.kind, {"count": 0, "elems": 0, "bytes": 0})
            slot["count"] += 1
            slot["elems"] += e.elems
            slot["bytes"] += e.nbytes
        return out

    def __len__(self) -> int:
        return len(self.events)

    def summary(self) -> str:
        if not self.events:
            return "collectives: none (fully local program)"
        lines = ["collectives (kind: count / wire bytes):"]
        nb = self.bytes_by_kind
        for kind, cnt in sorted(self.counts.items()):
            lines.append(f"  {kind:14s} {cnt:4d}  {nb[kind]:,} B")
        lines.append(f"  {'total':14s} {len(self.events):4d}  "
                     f"{self.total_bytes:,} B")
        for rule, kinds in sorted(self.by_rule().items()):
            if not rule:
                continue
            tot = sum(s["bytes"] for s in kinds.values())
            cnt = sum(s["count"] for s in kinds.values())
            lines.append(f"  [{rule}]{'':9s} {cnt:4d}  {tot:,} B")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Repartition planning: (d_from, d_to) -> explicit collective steps
# ---------------------------------------------------------------------------
#
# A *step* is a tuple whose head names the op:
#   ("all_gather", ax, dim)             un-shard dim's minor-most axis
#   ("all_to_all", ax, src_dim, dst_dim) move ax between dims
#   ("ppermute", ax_old, ax_new, dim)   swap which axis shards dim
#   ("slice", ax, dim)                  shard a replicated dim (local, free)
#   ("psum"|"pmax"|"pmin", axes)        cross-device reduction
#   ("psum_scatter", ax, dim)           fused reduce + shard of dim
#   ("psum_scatter_grouped", ((ax, dim), ...))
#                                       one reduce-scatter over the combined
#                                       axis group, scattering several dims
#                                       at once (same wire bytes as the
#                                       sequential per-axis form, one pass)
#   ("gather_reduce", ax, reducer)      gather + local reduce (prod)


def plan_repart(src: Layout, dst: Layout) -> list[tuple]:
    """Decompose a repartition into explicit collective steps.

    Per-axis moves use ``all_to_all`` when the axis is minor-most on both
    sides, axis swaps on a single dimension use ``ppermute``, and the
    general fallback is gather-to-prefix + local re-slice — always correct,
    never silently wrong, at worst pricier than optimal.  Idle axes whose
    target extends a dimension's already-correct prefix are sliced *early*
    (free), both shrinking every later transfer and unlocking ``all_to_all``
    moves whose destination prefix they complete — e.g. replicated →
    ``(data, model)``-on-one-dim with ``model`` arriving from another dim is
    slice(data) + all_to_all(model), not gather + slice + slice.
    """
    if len(src) != len(dst):
        raise ValueError(f"repartition rank mismatch: {src} vs {dst}")
    cur = [list(t) for t in src]
    want = [tuple(t) for t in dst]
    steps: list[tuple] = []

    def dim_of(ax: str, layout) -> int | None:
        for d, axes in enumerate(layout):
            if ax in axes:
                return d
        return None

    # 1. interleave (a) free slices of idle axes that extend a dim's correct
    #    prefix with (b) all_to_all moves: ax minor-most at its source dim,
    #    landing minor-most at a destination dim whose prefix is in place.
    changed = True
    while changed:
        changed = False
        for d in range(len(cur)):
            while (len(cur[d]) < len(want[d])
                   and tuple(cur[d]) == want[d][:len(cur[d])]
                   and dim_of(want[d][len(cur[d])], cur) is None):
                ax = want[d][len(cur[d])]
                steps.append(("slice", ax, d))
                cur[d].append(ax)
                changed = True
        for i, axes in enumerate(cur):
            if not axes:
                continue
            ax = axes[-1]
            j = dim_of(ax, want)
            if j is None or j == i:
                continue
            if want[j] == tuple(cur[j]) + (ax,):
                steps.append(("all_to_all", ax, i, j))
                cur[i].pop()
                cur[j].append(ax)
                changed = True

    # 2. ppermute: dim stays sharded but by a different (same-size checked by
    #    the caller) axis, old axis sharding nothing else, new axis idle.
    for d in range(len(cur)):
        if (len(cur[d]) == 1 and len(want[d]) == 1
                and cur[d][0] != want[d][0]
                and dim_of(want[d][0], cur) is None
                and dim_of(cur[d][0], want) in (None, d)):
            steps.append(("ppermute", cur[d][0], want[d][0], d))
            cur[d] = [want[d][0]]

    # 3. gather: pop minor-most axes until each dim is a prefix of its target.
    for d in range(len(cur)):
        while cur[d] and tuple(cur[d]) != want[d][:len(cur[d])]:
            steps.append(("all_gather", cur[d][-1], d))
            cur[d].pop()

    # 4. slice: append the remaining target axes major→minor (local, free).
    for d in range(len(cur)):
        for ax in want[d][len(cur[d]):]:
            steps.append(("slice", ax, d))
            cur[d].append(ax)

    assert [tuple(t) for t in cur] == list(want), (src, dst, steps)
    return steps


def plan_repart_fused(src: Layout, dst: Layout,
                      sizes: dict[str, int]) -> list[tuple]:
    """Fused repartition planner: the same (d_from, d_to) chain as
    ``plan_repart`` with the all_to_all landing condition *relaxed* so
    consecutive gather+re-slice pairs collapse into single collectives.

    ``plan_repart`` only fires an all_to_all when the moved axis completes
    the destination dim's target outright (``want[j] == cur[j] + (ax,)``);
    axes that land mid-prefix fall through to gather-to-prefix + local
    re-slice, which pays the full ``(k-1)·n_loc`` gather for data the next
    step throws away.  Here an axis may land whenever it is the *next
    prefix element* of its destination dim (``want[j][len(cur[j])] == ax``),
    so e.g. the zoo's lm_head chain

        [all_gather(model, 0), all_gather(data, 2), slice(data, 0)]

    becomes ``[all_gather(model, 0), all_to_all(data, 2, 0)]`` — the
    gather+slice pair fused into one all_to_all at 1/k the wire cost.
    When no free slice / all_to_all / equal-size ppermute applies, one
    minor-most axis of the first out-of-place dim is gathered and the
    passes rerun — gathers interleave with fusions instead of running as a
    monolithic gather-all phase.

    Termination: whenever every dim's current layout is a prefix of its
    target but the repartition is unfinished, some dim's next-needed axis
    is either idle (a free slice fires) or parked minor-most under a
    non-prefix dim (the gather fallback fires, since a mesh axis appears
    at most once per layout); every pass therefore makes progress.
    """
    if len(src) != len(dst):
        raise ValueError(f"repartition rank mismatch: {src} vs {dst}")
    cur = [list(t) for t in src]
    want = [tuple(t) for t in dst]
    steps: list[tuple] = []

    def dim_of(ax: str, layout) -> int | None:
        for d, axes in enumerate(layout):
            if ax in axes:
                return d
        return None

    def is_prefix(d: int) -> bool:
        return tuple(cur[d]) == want[d][:len(cur[d])]

    n_axes = sum(len(t) for t in src) + sum(len(t) for t in dst)
    for _ in range(4 * n_axes + 8):
        if [tuple(t) for t in cur] == list(want):
            break
        progress = False
        # (a) free slices: an idle axis extends a dim's correct prefix
        for d in range(len(cur)):
            while (is_prefix(d) and len(cur[d]) < len(want[d])
                   and dim_of(want[d][len(cur[d])], cur) is None):
                ax = want[d][len(cur[d])]
                steps.append(("slice", ax, d))
                cur[d].append(ax)
                progress = True
        # (b) relaxed all_to_all: ax minor-most at its source dim, landing
        #     as the NEXT prefix element of its destination dim
        for i in range(len(cur)):
            if not cur[i]:
                continue
            ax = cur[i][-1]
            j = dim_of(ax, want)
            if j is None or j == i:
                continue
            if (is_prefix(j) and len(cur[j]) < len(want[j])
                    and want[j][len(cur[j])] == ax):
                steps.append(("all_to_all", ax, i, j))
                cur[i].pop()
                cur[j].append(ax)
                progress = True
        if progress:
            continue
        # (c) ppermute: dim stays sharded but by a different equal-size
        #     axis, old axis idle in the target, new axis idle now
        for d in range(len(cur)):
            if (len(cur[d]) == 1 and len(want[d]) == 1
                    and cur[d][0] != want[d][0]
                    and sizes[cur[d][0]] == sizes[want[d][0]]
                    and dim_of(want[d][0], cur) is None
                    and dim_of(cur[d][0], want) is None):
                steps.append(("ppermute", cur[d][0], want[d][0], d))
                cur[d] = [want[d][0]]
                progress = True
        if progress:
            continue
        # (d) stalled: gather one minor-most axis off the first dim whose
        #     layout is not a prefix of its target, then rerun the passes
        for d in range(len(cur)):
            if cur[d] and not is_prefix(d):
                steps.append(("all_gather", cur[d][-1], d))
                cur[d].pop()
                progress = True
                break
        assert progress, (src, dst, cur, want, steps)

    assert [tuple(t) for t in cur] == list(want), (src, dst, steps)
    return steps


def _chain_wire_elems(steps: list[tuple], shape: tuple[int, ...],
                      sizes: dict[str, int], n_devices: int) -> int:
    """Total ring-priced wire elems of a step chain applied to local blocks
    of ``shape`` (the shape evolves step to step)."""
    total = 0
    for st in steps:
        total += _wire_elems(st, shape, sizes, n_devices)
        shape = _step_shape(shape, st, sizes)
    return total


def plan_repart_best(src: Layout, dst: Layout, sizes: dict[str, int],
                     src_local: tuple[int, ...],
                     n_devices: int) -> tuple[list[tuple], bool]:
    """``(steps, fused)`` — the cheaper of the fused and unfused chains by
    traced wire elems (ties broken toward fewer steps, then the unfused
    PR-3 path).  Taking the min guarantees the fused executor never moves
    more elements than the unfused one on any (src, dst) pair."""
    unfused = _plan_repart_sized(src, dst, sizes)
    fused = plan_repart_fused(src, dst, sizes)
    if fused == unfused:
        return unfused, False
    cu = _chain_wire_elems(unfused, src_local, sizes, n_devices)
    cf = _chain_wire_elems(fused, src_local, sizes, n_devices)
    if cf < cu or (cf == cu and len(fused) < len(unfused)):
        return fused, True
    return unfused, False


def _ppermute_size_ok(step, sizes) -> bool:
    return sizes[step[1]] == sizes[step[2]]


def _plan_repart_sized(src: Layout, dst: Layout,
                       sizes: dict[str, int]) -> list[tuple]:
    """plan_repart, demoting any ppermute whose two axes differ in size
    (the swap is only a pure permutation for equal sizes) to gather+slice."""
    steps = plan_repart(src, dst)
    if all(st[0] != "ppermute" or _ppermute_size_ok(st, sizes)
           for st in steps):
        return steps
    out: list[tuple] = []
    for st in steps:
        if st[0] == "ppermute" and not _ppermute_size_ok(st, sizes):
            _, ax_old, ax_new, dim = st
            out.append(("all_gather", ax_old, dim))
            out.append(("slice", ax_new, dim))
        else:
            out.append(st)
    return out


def local_shape(shape: Sequence[int], layout: Layout,
                sizes: dict[str, int]) -> tuple[int, ...]:
    """Per-device block shape of a tensor under a layout."""
    out = []
    for s, axes in zip(shape, layout):
        k = math.prod(sizes[a] for a in axes) if axes else 1
        if s % k != 0:
            raise ValueError(f"axes {axes} (x{k}) do not divide dim {s}")
        out.append(s // k)
    return tuple(out)


def _step_shape(shape: tuple[int, ...], step: tuple,
                sizes: dict[str, int]) -> tuple[int, ...]:
    """Local block shape after one repartition step."""
    s = list(shape)
    kind = step[0]
    if kind == "all_gather":
        s[step[2]] *= sizes[step[1]]
    elif kind == "all_to_all":
        _, ax, i, j = step
        s[i] *= sizes[ax]
        s[j] //= sizes[ax]
    elif kind == "slice":
        s[step[2]] //= sizes[step[1]]
    elif kind == "psum_scatter":
        s[step[2]] //= sizes[step[1]]
    elif kind == "psum_scatter_grouped":
        for ax, d in step[1]:
            s[d] //= sizes[ax]
    # ppermute / psum / pmax / pmin / gather_reduce keep the block shape
    return tuple(s)


def _wire_elems(step: tuple, shape: tuple[int, ...], sizes: dict[str, int],
                n_devices: int) -> int:
    """Ring-priced floats crossing the wire, summed over all devices, for
    one step applied to local blocks of ``shape``."""
    n_loc = math.prod(shape) if shape else 1
    kind = step[0]
    if kind == "all_gather":
        k = sizes[step[1]]
        return n_devices * (k - 1) * n_loc
    if kind == "all_to_all":
        k = sizes[step[1]]
        return n_devices * (k - 1) * n_loc // k
    if kind == "ppermute":
        return n_devices * n_loc
    if kind in ("psum", "pmax", "pmin"):
        k = math.prod(sizes[a] for a in step[1])
        return n_devices * 2 * (k - 1) * n_loc // k
    if kind == "psum_scatter":
        k = sizes[step[1]]
        return n_devices * (k - 1) * n_loc // k
    if kind == "psum_scatter_grouped":
        k = math.prod(sizes[ax] for ax, _ in step[1])
        # identical to the sequential per-axis total: n·(k1k2-1)/(k1k2)
        return n_devices * (k - 1) * n_loc // k
    if kind == "gather_reduce":
        k = sizes[step[1]]
        return n_devices * (k - 1) * n_loc
    return 0  # slice: local


# ---------------------------------------------------------------------------
# Schedule: per-node collective programs + layouts, computed before tracing
# ---------------------------------------------------------------------------


@dataclass
class NodeProgram:
    """Everything the body needs to execute one node: per-arg repartition
    steps, the post-compute reduction/slice steps, and the output layout.
    Opaque nodes additionally carry the shard rule that lowered them and
    its ``run`` closure (the per-device local program).

    ``prefetch`` lists the (consumer nid, arg index) chains the lookahead
    pass hoisted to this node: the runner issues them before this node's
    local compute block, so the wire flies while the block runs.
    ``prefetch_src`` is the consumer-side mirror — arg index → the node
    whose iteration issues that arg's chain."""

    nid: int
    arg_steps: list[list[tuple]] = field(default_factory=list)
    post_steps: list[tuple] = field(default_factory=list)
    layout: Layout = ()
    rule: str = ""
    run: Callable | None = None
    prefetch: list[tuple[int, int]] = field(default_factory=list)
    prefetch_src: dict[int, int] = field(default_factory=dict)


@dataclass(frozen=True)
class Prefetch:
    """One hoisted repartition's buffer lifetime: consumer node
    ``consumer``'s argument ``arg`` has its wire chain issued just before
    node ``issue``'s local compute block, so the repartitioned shard is
    live from ``issue`` until ``consumer`` reads it.  ``elems`` is the
    chain's total ring-priced wire elems (the overlappable volume the
    cost model's exposed-wire term hides behind ``issue``'s compute
    window)."""

    consumer: int
    arg: int
    issue: int
    elems: int


@dataclass
class Schedule:
    """The full static lowering of (graph, plan, mesh shape).

    ``lookahead`` records the window the schedule was built with;
    ``prefetches`` the hoisted buffer lifetimes (empty at lookahead=0 —
    that lowering is verbatim the serial PR-6 one); ``compute_elems`` a
    per-node local-compute window proxy (local output elems) bounding how
    much wire each node's compute can hide."""

    programs: list[NodeProgram]
    layouts: dict[int, Layout]
    trace: CollectiveTrace
    sizes: dict[str, int]
    lookahead: int = 0
    prefetches: list[Prefetch] = field(default_factory=list)
    compute_elems: dict[int, int] = field(default_factory=dict)

    def exposed_wire_elems(self) -> int:
        """Wire elems left exposed after overlap: total minus what each
        issue site's local-compute window can hide (``cost.exposed_wire``
        — overlap can't hide unbounded traffic behind a small block).
        Rule-internal overlaps (ring double buffer) hide behind their own
        node's compute; hoisted chains behind their issue node's."""
        from repro.core.cost import exposed_wire

        overlap_by_site: dict[int, int] = {}
        for e in self.trace.events:
            if e.overlap and e.prefetch_for < 0:
                overlap_by_site[e.nid] = (overlap_by_site.get(e.nid, 0)
                                          + e.elems)
        for pf in self.prefetches:
            overlap_by_site[pf.issue] = (overlap_by_site.get(pf.issue, 0)
                                         + pf.elems)
        return exposed_wire(self.trace.total_elems, overlap_by_site,
                            self.compute_elems)


def _norm_axes(axes, sizes: dict[str, int]) -> tuple[str, ...]:
    """Drop size-1 mesh axes — they shard nothing and must not show up as
    collectives (an all-"None" plan emits zero collectives)."""
    return tuple(a for a in axes if sizes.get(a, 1) > 1)


def _plan_layout(node: Node, axes_by_label: dict[str, tuple[str, ...]],
                 sizes: dict[str, int]) -> Layout:
    return tuple(_norm_axes(axes_by_label.get(l, ()), sizes)
                 for l in node.labels)


def _itemsize(dtype) -> int:
    try:
        return np.dtype(dtype).itemsize
    except TypeError:
        return 4


def _record_steps(trace: CollectiveTrace, steps: list[tuple],
                  shape: tuple[int, ...], sizes: dict[str, int],
                  n_devices: int, nid: int, itemsize: int,
                  rule: str = "", *, fused: bool = False) -> tuple[int, ...]:
    """Account every step in the trace; returns the final local shape.

    When ``fused`` is set the chain came from the fused planner: every
    event carries the flag and is attributed to the consumer node of the
    originating (d_from, d_to) pair — the steps it replaced are never
    recorded, so per-node bounds compare like-for-like with no
    double-counting."""
    for st in steps:
        kind = st[0]
        if kind in WIRE_KINDS:
            perm: tuple = ()
            if kind in ("psum", "pmax", "pmin"):
                axes = tuple(st[1])
            elif kind == "ppermute":
                axes = (st[1], st[2])
                # mirror the executor's transpose formula exactly (the
                # run-time closure below) so the static analyzer verifies
                # the permutation that actually ships
                k = sizes[st[1]]
                perm = tuple((j * k + i, i * k + j)
                             for i in range(k) for j in range(k))
            elif kind == "psum_scatter_grouped":
                axes = tuple(ax for ax, _ in st[1])
            else:
                axes = (st[1],)
            elems = _wire_elems(st, shape, sizes, n_devices)
            rec = "psum_scatter" if kind == "psum_scatter_grouped" else kind
            trace.add(rec, axes, nid, elems, elems * itemsize, rule,
                      fused=fused, perm=perm)
        shape = _step_shape(shape, st, sizes)
    return shape


def _scatter_dim(g: EinGraph, plan, nid: int, ax: str,
                 consumers: dict[int, list[int]], out_ids: set[int],
                 sizes: dict[str, int]) -> int | None:
    """Output dim to psum_scatter axis ``ax`` onto: defined when every
    consumer wants exactly that axis on the same output dimension (and the
    node is not itself a program output, whose layout the plan pins)."""
    if nid in out_ids or not consumers.get(nid):
        return None
    dims: set[int] = set()
    for m in consumers[nid]:
        ax_m = plan.axes_by_node.get(m, {})
        for ls in g.edge_labels(m, nid):
            found = [d for d, l in enumerate(ls)
                     if _norm_axes(ax_m.get(l, ()), sizes) == (ax,)]
            if len(found) != 1:
                return None
            dims.add(found[0])
    return dims.pop() if len(dims) == 1 else None


def _lower_einsum(g: EinGraph, n: Node, plan, ax_n, layouts, sizes,
                  trace: CollectiveTrace, n_dev: int, consumers,
                  out_set, fuse: bool = True,
                  spans: dict | None = None) -> NodeProgram:
    """join→agg lowering of one einsum node: per-arg repartitions to the
    plan layout, then the aggregation collectives (psum / pmax / pmin /
    gather-reduce), with sum-aggregations fused to reduce-scatters when the
    consumers pin the scattered dim — one *grouped* reduce-scatter when
    several contracted axes scatter to distinct output dims."""
    nid = n.nid
    spec = n.spec
    prog = NodeProgram(nid=nid)
    itemsize = _itemsize(n.dtype)
    for ai, (ls, a) in enumerate(zip(spec.in_labels, n.inputs)):
        req = tuple(_norm_axes(ax_n.get(l, ()), sizes) for l in ls)
        src_shape = local_shape(g.nodes[a].shape, layouts[a], sizes)
        if fuse:
            steps, was_fused = plan_repart_best(layouts[a], req, sizes,
                                                src_shape, n_dev)
        else:
            steps, was_fused = _plan_repart_sized(layouts[a], req,
                                                  sizes), False
        prog.arg_steps.append(steps)
        e0 = len(trace.events)
        got = _record_steps(trace, steps, src_shape, sizes, n_dev,
                            nid, _itemsize(g.nodes[a].dtype),
                            fused=was_fused)
        if spans is not None:
            spans[(nid, ai)] = (e0, len(trace.events))
        want_shape = local_shape(g.nodes[a].shape, req, sizes)
        assert got == want_shape, (nid, a, got, want_shape)

    prog.layout = _plan_layout(n, ax_n, sizes)
    agg_axes: list[str] = []
    for l in spec.agg_labels:
        agg_axes.extend(_norm_axes(ax_n.get(l, ()), sizes))
    if agg_axes:
        out_loc = list(local_shape(n.shape, prog.layout, sizes))
        if spec.agg == "sum":
            plain: list[str] = []
            scatters: list[tuple[str, int]] = []
            for ax in agg_axes:
                d = _scatter_dim(g, plan, nid, ax, consumers,
                                 out_set, sizes)
                if (d is not None and not prog.layout[d]
                        and d not in [sd for _, sd in scatters]):
                    scatters.append((ax, d))
                    lay = list(prog.layout)
                    lay[d] = (ax,)
                    prog.layout = tuple(lay)
                else:
                    plain.append(ax)
            if len(scatters) == 1:
                prog.post_steps.append(("psum_scatter",) + scatters[0])
            elif scatters:
                prog.post_steps.append(
                    ("psum_scatter_grouped", tuple(scatters)))
            if plain:
                # reduce first, then scatter the fused axes
                prog.post_steps.insert(0, ("psum", tuple(plain)))
        elif spec.agg in ("max", "min"):
            prog.post_steps.append(
                ("pmax" if spec.agg == "max" else "pmin",
                 tuple(agg_axes)))
        else:  # prod: gather partial products, reduce locally
            for ax in agg_axes:
                prog.post_steps.append(("gather_reduce", ax, "prod"))
        _record_steps(trace, prog.post_steps, tuple(out_loc), sizes,
                      n_dev, nid, itemsize)
    return prog


def _lower_opaque(g: EinGraph, n: Node, ax_n, layouts, sizes,
                  trace: CollectiveTrace, n_dev: int,
                  fuse: bool = True,
                  spans: dict | None = None) -> NodeProgram:
    """Dispatch one opaque node through the shard-rule registry
    (core/opaque_rules.py).  The resolved rule requests per-input layouts
    (repartitioned by the generic machinery, so arbitrary producers are
    handled), contributes its internal collective events to the trace
    (ring ppermute hops, a2a token payloads), and supplies the ``run``
    closure executed in the shard_map body.  Rules whose structural
    preconditions fail fall back to the replicate-gather path."""
    from repro.core import opaque_rules

    nid = n.nid
    prog = NodeProgram(nid=nid)
    rule_name = opaque_rules.resolve_rule_name(n)
    low = None
    if rule_name != "replicate":
        rule = opaque_rules.RULES.get(rule_name)
        low = rule.lower(g, n, ax_n, sizes) if rule is not None else None
    if low is None:
        rule_name = "replicate"
        low = opaque_rules.RULES["replicate"].lower(g, n, ax_n, sizes)
    prog.rule = rule_name
    prog.run = low.run
    trace.rule_by_node[nid] = rule_name

    for ai, (a, req) in enumerate(zip(n.inputs, low.arg_layouts)):
        src_shape = local_shape(g.nodes[a].shape, layouts[a], sizes)
        if fuse:
            steps, was_fused = plan_repart_best(layouts[a], req, sizes,
                                                src_shape, n_dev)
        else:
            steps, was_fused = _plan_repart_sized(layouts[a], req,
                                                  sizes), False
        prog.arg_steps.append(steps)
        e0 = len(trace.events)
        got = _record_steps(trace, steps, src_shape, sizes, n_dev, nid,
                            _itemsize(g.nodes[a].dtype), rule_name,
                            fused=was_fused)
        if spans is not None:
            spans[(nid, ai)] = (e0, len(trace.events))
        want_shape = local_shape(g.nodes[a].shape, req, sizes)
        assert got == want_shape, (nid, a, got, want_shape)
    for ev in low.events:
        # rules may tag an event as overlapped (5th element) — the ring's
        # double-buffered K/V hops issued alongside local compute — and
        # expose the exact ppermute (src, dst) pairs (6th element) for the
        # static bijectivity check
        kind, axes, elems, nbytes = ev[:4]
        overlap = bool(ev[4]) if len(ev) > 4 else False
        perm = tuple(ev[5]) if len(ev) > 5 else ()
        trace.add(kind, axes, nid, elems, nbytes, rule_name,
                  overlap=overlap, perm=perm)
    prog.post_steps = list(low.post_steps)
    prog.layout = low.out_layout
    # rule post steps are layout-conforming local slices (free, no wire
    # events); any internal wire movement must be declared via low.events
    assert all(st[0] == "slice" for st in prog.post_steps), prog.post_steps
    return prog


#: arg repartition chains are composed of exactly these wire kinds (plus
#: free local slices) — the hoistable set of the lookahead pass.
_HOISTABLE_KINDS = ("all_gather", "all_to_all", "ppermute")


def _hoist_prefetches(g: EinGraph, programs: list[NodeProgram],
                      trace: CollectiveTrace, spans: dict,
                      lookahead: int) -> list[Prefetch]:
    """Graph-wide lookahead pass: each wire-carrying arg chain of an
    einsum/opaque consumer M hoists to the ``lookahead``-th computing node
    before M — never before the chain's *own* producer (per-argument
    readiness: the chain reads only that producer's value, so sibling args
    still in flight don't serialize it) — and the collectives fly while
    the intervening local compute blocks run.  Topo positions equal nids
    (``topo_order`` is construction order — the invariant the memory pass
    already relies on).  Hoisted events are retroactively marked
    ``overlap=True, prefetch_for=M``; their ``nid`` stays M so per-node
    attribution is issue-order independent.  Returns the hoisted buffer
    lifetimes."""
    progs = {p.nid: p for p in programs}
    prefetches: list[Prefetch] = []
    for n in g.nodes:
        if n.kind in ("input", "map"):
            continue  # inputs don't execute; maps repartition nothing
        m = n.nid
        prog = progs[m]
        for ai in range(len(prog.arg_steps)):
            span = spans.get((m, ai))
            if not span or span[0] == span[1]:
                continue  # slice-only chain: nothing crosses the wire
            evs = trace.events[span[0]:span[1]]
            if any(e.kind not in _HOISTABLE_KINDS for e in evs):
                continue
            # per-arg readiness: the chain needs its own producer computed
            # (graph inputs are bound before the loop — always ready)
            a = n.inputs[ai]
            ready = a + 1 if g.nodes[a].kind != "input" else 0
            # the issue point is the ``lookahead``-th *computing* node
            # before M (input nodes never execute an iteration, so they
            # don't consume the window), clamped at readiness
            issue, p, seen = m, m - 1, 0
            while p >= ready and seen < lookahead:
                if g.nodes[p].kind != "input":
                    issue, seen = p, seen + 1
                p -= 1
            if issue >= m:
                continue  # no intervening compute to hide the wire behind
            for idx in range(span[0], span[1]):
                trace.events[idx] = dataclasses.replace(
                    trace.events[idx], overlap=True, prefetch_for=m)
            progs[issue].prefetch.append((m, ai))
            prog.prefetch_src[ai] = issue
            prefetches.append(Prefetch(m, ai, issue,
                                       sum(e.elems for e in evs)))
    return prefetches


def build_schedule(g: EinGraph, plan, mesh_axes: dict[str, int],
                   out_ids: Sequence[int] | None = None, *,
                   fuse: bool = True, lookahead: int = 1) -> Schedule:
    """Lower (graph, plan, mesh shape) to the static collective schedule.

    Pure Python over static shapes — no jax, no devices — so trace
    assertions (e.g. "an unsharded plan emits zero collectives") run on any
    host, and the runner body just replays the recorded decisions.

    ``fuse=True`` (the default) routes every repartition through
    ``plan_repart_best`` — the fused chain when it moves strictly fewer
    wire elems, the PR-3 unfused chain otherwise; ``fuse=False`` restores
    the unfused lowering verbatim (the equivalence baseline
    tests/test_spmd_fastpath.py diffs against).

    ``lookahead`` (default 1) is the graph-wide overlap window: each ready
    consumer's wire-carrying arg chains are hoisted up to ``lookahead``
    nodes before the consumer (never before the consumer's producers), so
    the collectives issue while the intervening local compute runs —
    recorded as ``Prefetch`` lifetimes and ``prefetch_for``-marked events.
    ``lookahead=0`` restores the serial lowering verbatim.
    """
    sizes = {a: int(s) for a, s in mesh_axes.items()}
    n_dev = math.prod(sizes.values()) if sizes else 1
    out_set = set(out_ids) if out_ids is not None else set(g.outputs())
    consumers = g.consumers()
    trace = CollectiveTrace()
    layouts: dict[int, Layout] = {}
    programs: list[NodeProgram] = []
    compute_elems: dict[int, int] = {}
    spans: dict[tuple[int, int], tuple[int, int]] = {}

    for nid in g.topo_order():
        n = g.nodes[nid]
        ax_n = plan.axes_by_node.get(nid, {}) if plan is not None else {}

        if n.kind == "input":
            prog = NodeProgram(nid=nid)
            prog.layout = _plan_layout(n, ax_n, sizes)
        elif n.kind == "map":
            # elementwise on the local block; layout rides through untouched
            prog = NodeProgram(nid=nid)
            prog.layout = layouts[n.inputs[0]]
        elif n.kind == "einsum":
            prog = _lower_einsum(g, n, plan, ax_n, layouts, sizes, trace,
                                 n_dev, consumers, out_set, fuse, spans)
        else:
            prog = _lower_opaque(g, n, ax_n, layouts, sizes, trace, n_dev,
                                 fuse, spans)

        layouts[nid] = prog.layout
        programs.append(prog)
        if n.kind != "input":
            try:
                compute_elems[nid] = math.prod(
                    local_shape(n.shape, prog.layout, sizes))
            except (ValueError, KeyError):
                pass  # unrealizable layout: the analysis passes flag it

    prefetches: list[Prefetch] = []
    if lookahead > 0:
        prefetches = _hoist_prefetches(g, programs, trace, spans,
                                       int(lookahead))

    return Schedule(programs=programs, layouts=layouts, trace=trace,
                    sizes=sizes, lookahead=int(lookahead),
                    prefetches=prefetches, compute_elems=compute_elems)


# ---------------------------------------------------------------------------
# Local einsum compute: contraction -> kernels.ops.matmul when it is one
# ---------------------------------------------------------------------------


def _as_matmul(spec: EinSpec) -> tuple[list[str], list[str], list[str]] | None:
    """(free_x, contracted, free_y) when the node is a clean matmul: binary
    mul+sum, the shared labels are exactly the contracted ones (no batch
    labels), every label partitions into one of the three groups."""
    if not (spec.is_contraction and len(spec.in_labels) == 2):
        return None
    lx, ly = spec.in_labels
    shared = [l for l in lx if l in ly]
    if set(shared) != set(spec.agg_labels):
        return None
    free_x = [l for l in lx if l not in shared]
    free_y = [l for l in ly if l not in shared]
    if set(spec.out_labels) != set(free_x) | set(free_y):
        return None
    return free_x, shared, free_y


def local_einsum(spec: EinSpec, x, y=None):
    """One node's *local* join block.  Clean 2-ary contractions go through
    ``repro.kernels.ops.matmul`` (Pallas per shard on TPU, jnp.dot
    elsewhere); everything else lowers through the engine semantics."""
    import jax.numpy as jnp

    from repro.core import engine

    args = (x,) if y is None else (x, y)
    mm = _as_matmul(spec) if y is not None else None
    if mm is not None and all(jnp.issubdtype(a.dtype, jnp.floating)
                              for a in args):
        from repro.kernels import ops

        free_x, shared, free_y = mm
        lx, ly = spec.in_labels
        xa = jnp.transpose(x, [lx.index(l) for l in free_x + shared])
        ya = jnp.transpose(y, [ly.index(l) for l in shared + free_y])
        fx_shape = xa.shape[:len(free_x)]
        fy_shape = ya.shape[len(shared):]
        k = math.prod(xa.shape[len(free_x):])  # 1 for outer products
        z = ops.matmul(xa.reshape(-1, k), ya.reshape(k, -1))
        z = z.reshape(tuple(fx_shape) + tuple(fy_shape))
        order = free_x + free_y
        return jnp.transpose(z, [order.index(l) for l in spec.out_labels])
    return engine.lower_einsum(spec, *args)


# ---------------------------------------------------------------------------
# Step execution inside the shard_map body
# ---------------------------------------------------------------------------


def _run_steps(x, steps: list[tuple], sizes: dict[str, int]):
    import jax.numpy as jnp
    from jax import lax

    for st in steps:
        kind = st[0]
        if kind == "all_gather":
            x = lax.all_gather(x, st[1], axis=st[2], tiled=True)
        elif kind == "all_to_all":
            _, ax, src_dim, dst_dim = st
            x = lax.all_to_all(x, ax, split_axis=dst_dim,
                               concat_axis=src_dim, tiled=True)
        elif kind == "ppermute":
            _, ax_old, ax_new, _dim = st
            k = sizes[ax_old]
            # device (old=i, new=j) must end up with block j — sourced from
            # (old=j, new=i); linear index over (ax_old, ax_new) is row-major
            perm = [(j * k + i, i * k + j)
                    for i in range(k) for j in range(k)]
            x = lax.ppermute(x, (ax_old, ax_new), perm)
        elif kind == "slice":
            _, ax, dim = st
            k = sizes[ax]
            sz = x.shape[dim] // k
            x = lax.dynamic_slice_in_dim(x, lax.axis_index(ax) * sz, sz,
                                         axis=dim)
        elif kind == "psum":
            x = lax.psum(x, tuple(st[1]))
        elif kind == "pmax":
            x = lax.pmax(x, tuple(st[1]))
        elif kind == "pmin":
            x = lax.pmin(x, tuple(st[1]))
        elif kind == "psum_scatter":
            x = lax.psum_scatter(x, st[1], scatter_dimension=st[2],
                                 tiled=True)
        elif kind == "psum_scatter_grouped":
            # one reduce-scatter over the combined axis group, scattering
            # several dims at once: split each target dim into (k_i, rest),
            # bring the k_i factors to the front in axis order (matching the
            # row-major device linearization of the axes tuple), flatten,
            # scatter, and unflatten.
            pairs = st[1]
            ks = [sizes[ax] for ax, _ in pairs]
            dims = [d for _, d in pairs]
            new_shape: list[int] = []
            split_pos: dict[int, int] = {}
            for i, s in enumerate(x.shape):
                if i in dims:
                    k = ks[dims.index(i)]
                    split_pos[i] = len(new_shape)
                    new_shape += [k, s // k]
                else:
                    new_shape.append(s)
            y = x.reshape(new_shape)
            front = [split_pos[d] for d in dims]
            rest = [i for i in range(len(new_shape)) if i not in front]
            y = jnp.transpose(y, front + rest)
            kk = math.prod(ks)
            y = y.reshape((kk,) + y.shape[len(front):])
            y = lax.psum_scatter(y, tuple(ax for ax, _ in pairs),
                                 scatter_dimension=0, tiled=True)
            x = y.reshape(y.shape[1:])
        elif kind == "gather_reduce":
            if st[2] != "prod":  # the only agg without a ring collective
                raise ValueError(f"gather_reduce reducer {st[2]!r} unknown")
            x = lax.all_gather(x, st[1], axis=0, tiled=False)
            x = jnp.prod(x, axis=0)
        else:
            raise ValueError(f"unknown step {st}")
    return x


def _pspec(layout: Layout):
    from jax.sharding import PartitionSpec as P

    entries = []
    for axes in layout:
        if not axes:
            entries.append(None)
        elif len(axes) == 1:
            entries.append(axes[0])
        else:
            entries.append(tuple(axes))
    return P(*entries)


def _shard_map(f, mesh, in_specs, out_specs):
    """Version-portable shard_map with replication checking off (manual
    axis_index slicing defeats the rep checker by design)."""
    try:
        from jax.experimental.shard_map import shard_map

        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except (ImportError, TypeError):  # pragma: no cover - newer jax
        from jax import shard_map

        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


# ---------------------------------------------------------------------------
# The runner
# ---------------------------------------------------------------------------


def make_spmd_runner(
    g: EinGraph,
    out_ids: Sequence[int] | None = None,
    *,
    plan,
    mesh,
    trace: CollectiveTrace | None = None,
    fuse: bool = True,
    lookahead: int = 1,
) -> Callable:
    """Build ``f(*input_arrays) -> tuple(outputs)`` executing the planned
    graph as one ``shard_map`` with explicit collectives.

    Requires a mesh-mode plan (``plan.axes_by_node``); ``trace`` (optional)
    receives the static ``CollectiveEvent`` schedule at build time.
    ``fuse=False`` disables the fused repartition planner (the unfused
    PR-3 lowering, kept as the equivalence baseline).  ``lookahead``
    (default 1) enables the graph-wide overlap pass: ready consumers' arg
    repartitions issue before an earlier node's compute block — the same
    values flow through the same collectives in a different issue order,
    so outputs are bit-identical to ``lookahead=0``.  Jit-able and
    differentiable like the GSPMD runner.
    """
    from repro.core import engine

    if plan is None or mesh is None:
        raise ValueError("make_spmd_runner: shard_map execution needs both "
                         "a plan and a mesh")
    if plan.mode != "mesh":
        raise ValueError(
            f"make_spmd_runner: plan mode {plan.mode!r} is not mesh-mode — "
            "plan with mesh_axes so labels map to named mesh axes")
    out_ids = list(out_ids) if out_ids is not None else g.outputs()
    sizes = engine.mesh_axes_dict(mesh)
    sched = build_schedule(g, plan, sizes, out_ids, fuse=fuse,
                           lookahead=lookahead)
    if trace is not None:
        trace.extend(sched.trace)

    in_ids = g.input_ids()
    in_specs = tuple(_pspec(sched.layouts[i]) for i in in_ids)
    out_specs = tuple(_pspec(sched.layouts[o]) for o in out_ids)

    def body(*local_inputs):
        import jax.numpy as jnp

        vals: dict[int, Any] = {}
        for i, arr in zip(in_ids, local_inputs):
            vals[i] = jnp.asarray(arr)
        run_schedule_body(g, sched, vals)
        return tuple(vals[o] for o in out_ids)

    return _shard_map(body, mesh, in_specs, out_specs)


def run_schedule_body(g: EinGraph, sched: Schedule,
                      vals: dict[int, Any]) -> dict[int, Any]:
    """Execute a built ``Schedule``'s per-node programs inside a shard_map
    body.  ``vals`` maps every input node id to its local block on entry;
    on return it additionally holds every computed node's local value.

    Shared by the unpipelined runner above and the pipeline tier
    (repro.pipeline.exec), which calls it once per (stage, microbatch)
    cell with the stage subgraph and a ``vals`` dict pre-fed from handoff
    buffers — so both executors realize the identical per-node lowering.
    """
    from repro.core import engine

    progs = {p.nid: p for p in sched.programs}
    prefetched: dict[tuple[int, int], Any] = {}
    for nid in g.topo_order():
        n = g.nodes[nid]
        if n.kind == "input":
            continue
        prog = progs[nid]
        # hoisted issue points first: downstream consumers' repartition
        # chains enter the traced program before this node's compute
        # block, giving XLA's latency-hiding scheduler room to run the
        # wire behind it (same ops on the same values — bit-identical)
        for (m, ai) in prog.prefetch:
            a = g.nodes[m].inputs[ai]
            prefetched[(m, ai)] = _run_steps(
                vals[a], progs[m].arg_steps[ai], sched.sizes)
        args = [prefetched.pop((nid, i))
                if (nid, i) in prefetched
                else _run_steps(vals[a], steps, sched.sizes)
                for i, (a, steps) in enumerate(zip(n.inputs,
                                                   prog.arg_steps))]
        if n.kind == "einsum":
            v = local_einsum(n.spec, *args)
            v = _run_steps(v, prog.post_steps, sched.sizes)
        elif n.kind == "map":
            v = engine.MAP_FNS[n.op](vals[n.inputs[0]], **n.params)
        else:  # opaque: the shard rule's per-device program
            v = prog.run(args)
            v = _run_steps(v, prog.post_steps, sched.sizes)
        vals[nid] = v
    return vals
