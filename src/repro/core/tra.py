"""Tensor relations and the tensor-relational algebra (paper §4).

A *tensor relation* stores a tensor ``R`` with bound vector ``b`` as a set of
keyed sub-tensors, controlled by a *partitioning vector* ``d``:

    R : I(d)  ->  ( I(b/d) -> float )

i.e. a dict mapping partition keys (tuples in I(d)) to numpy blocks of shape
b/d.  The TRA has three operations — *join* (kernel calls on key-matched
sub-tensor pairs), *aggregation* (⊕-reduce over contracted key dims), and
*repartition* — and the §4.3 rewrite turns any EinSum node plus a
partitioning vector ``d`` into join→agg.

This module is the **reference runtime**: a faithful, pure-numpy/jnp
implementation of the paper's abstraction, used (a) as the oracle for the
equivalence property tests, (b) to count kernel calls and transfers for the
paper-figure benchmarks.  The *production* path lowers the same plans to
GSPMD shardings instead (core/plan.py, core/engine.py).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro.core.einsum import COMBINE1, COMBINE2, EinSpec, eval_einsum_dense

# ---------------------------------------------------------------------------
# Partitioning-vector helpers (the b[l1; l2] projection of §3)
# ---------------------------------------------------------------------------


def project(vec: Sequence[int], onto: Sequence[str], frm: Sequence[str]) -> tuple[int, ...]:
    """``vec[onto; frm]`` — for each label in ``onto`` pick the entry of
    ``vec`` at the first position of that label in ``frm`` (§3)."""
    out = []
    for l in onto:
        out.append(vec[list(frm).index(l)])
    return tuple(out)


def label_parts(d_by_label: dict[str, int], labels: Sequence[str]) -> tuple[int, ...]:
    """Partitioning vector for a tensor with the given labels."""
    return tuple(d_by_label[l] for l in labels)


# ---------------------------------------------------------------------------
# TensorRelation
# ---------------------------------------------------------------------------


@dataclass
class TensorRelation:
    """A tensor stored as keyed sub-tensors (paper §4.1)."""

    bound: tuple[int, ...]
    parts: tuple[int, ...]  # d — partition count along each dimension
    blocks: dict[tuple[int, ...], np.ndarray] = field(default_factory=dict)

    def __post_init__(self):
        assert len(self.bound) == len(self.parts)
        for b, d in zip(self.bound, self.parts):
            if d <= 0 or b % d != 0:
                raise ValueError(f"parts {self.parts} do not divide bound {self.bound}")

    @property
    def block_shape(self) -> tuple[int, ...]:
        return tuple(b // d for b, d in zip(self.bound, self.parts))

    def keys(self):
        return itertools.product(*[range(d) for d in self.parts])

    @property
    def n_blocks(self) -> int:
        return int(np.prod(self.parts)) if self.parts else 1

    # -- conversion ---------------------------------------------------------

    @classmethod
    def from_dense(cls, arr: np.ndarray, parts: Sequence[int]) -> "TensorRelation":
        arr = np.asarray(arr)
        parts = tuple(int(p) for p in parts)
        tr = cls(tuple(arr.shape), parts)
        bs = tr.block_shape
        for key in tr.keys():
            sl = tuple(slice(k * s, (k + 1) * s) for k, s in zip(key, bs))
            tr.blocks[key] = arr[sl]
        return tr

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.bound, dtype=next(iter(self.blocks.values())).dtype)
        bs = self.block_shape
        for key, blk in self.blocks.items():
            sl = tuple(slice(k * s, (k + 1) * s) for k, s in zip(key, bs))
            out[sl] = blk
        return out

    # -- the three TRA operators --------------------------------------------

    def repartition(self, new_parts: Sequence[int]) -> "TensorRelation":
        """Π_d (§4.2): same tensor, different slicing.  Reference impl goes
        through dense; a real runtime moves only the overlapping pieces."""
        return TensorRelation.from_dense(self.to_dense(), new_parts)


def tra_join(
    x: TensorRelation,
    y: TensorRelation,
    lx: Sequence[str],
    ly: Sequence[str],
    kernel: Callable[[np.ndarray, np.ndarray], np.ndarray],
    out_labels: Sequence[str],
    out_block_shape: tuple[int, ...],
) -> tuple["KeyedSet", int]:
    """⋈_{K,ℓX,ℓY} (§4.2).  Returns the joined keyed set (keys over
    ℓX ⊙ ℓY) and the number of kernel calls performed."""
    joined = ld_concat(lx, ly)
    out: dict[tuple[int, ...], np.ndarray] = {}
    calls = 0
    for kxe in x.blocks:
        for kye in y.blocks:
            ok = True
            for i, l in enumerate(lx):
                if l in ly and kxe[i] != kye[list(ly).index(l)]:
                    ok = False
                    break
            if not ok:
                continue
            # natural-join key over ℓX ⊙ ℓY
            kv = dict(zip(lx, kxe))
            kv.update(dict(zip(ly, kye)))
            key = tuple(kv[l] for l in joined)
            out[key] = kernel(x.blocks[kxe], y.blocks[kye])
            calls += 1
    return KeyedSet(tuple(joined), out), calls


def tra_aggregate(
    rel: "KeyedSet",
    agg_labels: Sequence[str],
    agg_fn: Callable[[np.ndarray, np.ndarray], np.ndarray],
) -> "KeyedSet":
    """Σ_{⊕,ℓ,ℓagg} (§4.2): group keys on labels ∉ ℓagg, ⊕-reduce tensors."""
    keep = [l for l in rel.labels if l not in agg_labels]
    groups: dict[tuple[int, ...], np.ndarray] = {}
    for key, blk in rel.blocks.items():
        gk = tuple(k for k, l in zip(key, rel.labels) if l in keep)
        if gk in groups:
            groups[gk] = agg_fn(groups[gk], blk)
        else:
            groups[gk] = blk
    return KeyedSet(tuple(keep), groups)


@dataclass
class KeyedSet:
    """An intermediate tensor relation whose keys are labeled (join output)."""

    labels: tuple[str, ...]
    blocks: dict[tuple[int, ...], np.ndarray]

    def to_relation(self, labels_order: Sequence[str], bound: Sequence[int]) -> TensorRelation:
        order = [self.labels.index(l) for l in labels_order]
        some = next(iter(self.blocks.values()))
        parts = []
        for i, l in enumerate(labels_order):
            keys_along = {k[order[i]] for k in self.blocks}
            parts.append(max(keys_along) + 1)
        tr = TensorRelation(tuple(bound), tuple(parts))
        for key, blk in self.blocks.items():
            tr.blocks[tuple(key[o] for o in order)] = blk
        return tr


def ld_concat(lx: Sequence[str], ly: Sequence[str]) -> list[str]:
    """ℓX ⊙ ℓY — concatenation, dropping duplicates (§4.3)."""
    seen = list(lx)
    for l in ly:
        if l not in seen:
            seen.append(l)
    return seen


# ---------------------------------------------------------------------------
# EinSum → TRA rewrite (§4.3): execute one EinSum node under a partitioning d
# ---------------------------------------------------------------------------


def make_kernel(spec: EinSpec) -> Callable:
    """The kernel function K of §4.3: evaluates the *inner* EinSum on
    sub-tensors (one pl/MKL/XLA kernel call in a real runtime)."""

    def k2(bx: np.ndarray, by: np.ndarray) -> np.ndarray:
        return eval_einsum_dense(spec, bx, by)

    def k1(bx: np.ndarray) -> np.ndarray:
        return eval_einsum_dense(spec, bx)

    return k2 if len(spec.in_labels) == 2 else k1


_AGG_PAIR = {
    "sum": lambda a, b: a + b,
    "max": np.maximum,
    "min": np.minimum,
    "prod": lambda a, b: a * b,
}


def execute_einsum_tra(
    spec: EinSpec,
    d_by_label: dict[str, int],
    *inputs: TensorRelation,
) -> tuple[TensorRelation, dict]:
    """Execute Z ← ⊕ ⊗(X, Y) as join→aggregate per §4.3.

    ``d_by_label`` maps each unique label to its partition count (entries of
    the paper's d vector, co-partitioned labels already merged).  Inputs must
    already be partitioned compatibly (``d[l_X; l_XY]`` etc.); callers use
    ``TensorRelation.repartition`` first if not.

    Returns the output relation (partitioned by ``d[l_Z; l_XY]``) and a stats
    dict with kernel-call and tuple counts for the figures.
    """
    for ls, rel in zip(spec.in_labels, inputs):
        want = label_parts(d_by_label, ls)
        if rel.parts != want:
            raise ValueError(f"input partitioned {rel.parts}, want {want} for labels {ls}")

    kernel = make_kernel(spec)
    out_block = None
    stats: dict = {}

    if len(inputs) == 2:
        x, y = inputs
        lx, ly = spec.in_labels
        joined, calls = tra_join(x, y, lx, ly, kernel, spec.out_labels, out_block)
        stats["kernel_calls"] = calls
        agged = tra_aggregate(joined, spec.agg_labels, _AGG_PAIR[spec.agg or "sum"])
    else:
        (lx,) = spec.in_labels
        x = inputs[0]
        blocks = {}
        for key, blk in x.blocks.items():
            blocks[key] = kernel(blk)
        stats["kernel_calls"] = len(blocks)
        agged = tra_aggregate(KeyedSet(tuple(lx), blocks), spec.agg_labels,
                              _AGG_PAIR[spec.agg or "sum"])

    out_bound = []
    # bound of output = product over labels (taken from inputs)
    bounds: dict[str, int] = {}
    for ls, rel in zip(spec.in_labels, inputs):
        for l, b in zip(ls, rel.bound):
            bounds[l] = b
    out_bound = [bounds[l] for l in spec.out_labels]
    out = agged.to_relation(spec.out_labels, out_bound)
    stats["out_blocks"] = out.n_blocks
    return out, stats


# ---------------------------------------------------------------------------
# Whole-graph TRA execution under a plan {node -> d_by_label}
# ---------------------------------------------------------------------------


def execute_graph_tra(
    g,
    plan: dict[int, dict[str, int]],
    feeds: dict[int, np.ndarray],
) -> tuple[dict[int, TensorRelation], dict]:
    """Execute an EinGraph in the TRA reference runtime.

    ``plan[nid]`` is the d_by_label map for node nid (einsum nodes).  Input
    nodes take their partitioning from their first consumer's requirement.
    map/opaque nodes run densely (reference semantics only).  Returns node
    values as TensorRelations plus aggregate stats (kernel calls,
    repartitions performed).
    """
    from repro.core.einsum import EinGraph  # noqa: F401 (typing only)

    vals: dict[int, TensorRelation] = {}
    stats = {"kernel_calls": 0, "repartitions": 0}

    for nid in g.topo_order():
        n = g.nodes[nid]
        if n.kind == "input":
            d = plan.get(nid)
            parts = label_parts(d, n.labels) if d else tuple([1] * n.rank)
            vals[nid] = TensorRelation.from_dense(feeds[nid], parts)
        elif n.kind == "einsum":
            d = plan[nid]
            ins = []
            for ls, a in zip(n.spec.in_labels, n.inputs):
                want = label_parts(d, ls)
                rel = vals[a]
                if rel.parts != want:
                    rel = rel.repartition(want)
                    stats["repartitions"] += 1
                ins.append(rel)
            out, s = execute_einsum_tra(n.spec, d, *ins)
            stats["kernel_calls"] += s["kernel_calls"]
            vals[nid] = out
        elif n.kind == "map":
            from repro.core import engine as _eng

            src = vals[n.inputs[0]]
            fn = _eng.MAP_FNS[n.op]
            dense = np.asarray(fn(src.to_dense(), **n.params))
            vals[nid] = TensorRelation.from_dense(dense, src.parts)
        else:  # opaque — dense reference
            from repro.core import engine as _eng

            fn = _eng.OPAQUE_FNS[n.op]
            dense = np.asarray(fn(*[vals[a].to_dense() for a in n.inputs],
                                  **n.call_params))
            d = plan.get(nid)
            parts = label_parts(d, n.labels) if d else tuple([1] * len(dense.shape))
            vals[nid] = TensorRelation.from_dense(dense, parts)
    return vals, stats
