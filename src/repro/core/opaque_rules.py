"""Shard rules for opaque nodes: per-device programs for fused ops.

The shard_map executor (core/spmd.py) lowers einsum nodes through the §4.3
join→agg→repartition rewrite, but opaque nodes (flash attention, MoE
dispatch/combine, recurrent scans) are black boxes to that machinery.  The
cost DP already *prices* their internal movement through the ``comm``
declarations on the node (``{"kind": "ring"|"a2a", "label": ..., "input":
..., "rule": ...}``, see ``core/decomp._opaque_comm_cost``); this module is
the executor-side counterpart: an ``OpaqueShardRule`` turns (node, plan
assignment, mesh sizes) into the per-device program — requested input
layouts, internal collective events for the trace, and a ``run`` closure
emitting local kernel calls + explicit collectives inside the shard_map
body.

Built-in rules (the registry; ``register_rule`` admits new ones):

  ``ring``      — sequence-parallel flash attention: q stays sharded on its
                  sequence axis, K/V circulate around the ring via
                  ``lax.ppermute`` with the online-softmax ``(m, l, acc)``
                  state carried across ring steps
                  (``kernels.ops.flash_attention_step``); causal /
                  sliding-window masks stay correct under rotation because
                  every step masks against the block's *absolute* kv offset.
  ``a2a``       — expert-parallel MoE dispatch/combine: tokens stay sharded
                  on the sequence axis, expert assignment is agreed globally
                  via a (tiny) all-gather of per-expert counts, and token
                  payloads cross a real ``lax.all_to_all`` to/from the
                  expert-sharded buffers — never a full token-buffer gather.
  ``local``     — channel-parallel fused ops: the op declares (by binding
                  this rule in its OpDef) that it is independent along
                  every shardable label, so each device runs the dense
                  impl on its local blocks with **zero collectives**.
                  This is what the recurrent scans (ssm/mlstm/slstm) bind:
                  the sequence label is non-shardable (recurrence), the
                  channel labels shard freely — a local scan per channel
                  shard, where the old fallback gathered full state.
  ``paged``     — the serving tier's paged KV cache (``kv_block_gather``):
                  each device gathers its own block-table rows from its own
                  pool shard — batch/head/head_dim-parallel lookup with
                  **zero collectives**, including a t-sharded cache view
                  when the stripes are whole blocks.
  ``replicate`` — the fallback: gather inputs, run the fused op densely on
                  every device, re-slice the output to the plan layout
                  (free local slices).  Used for every opaque op without a
                  declared rule (embedding gathers, derived VJP ops) and
                  whenever a rule's structural preconditions fail (it
                  returns ``None`` from ``lower``).

A rule resolves from the node's **OpDef** (core/opdef.py): the comm
declaration's entries may name their ``rule`` explicitly, entries without
one derive it from ``kind`` (``ring``→ring, ``a2a``→a2a), and comm-less
OpDefs may bind a ``shard_rule`` directly (the scans' ``local``).  An
explicit per-node ``params["comm"]`` still overrides the OpDef template.
``validate_graph`` runs at plan time (``eindecomp``) so a plan can never
price a schedule the executor cannot resolve.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Protocol, Sequence, runtime_checkable

from repro.core import spmd as _spmd
from repro.core.einsum import EinGraph, Node

#: step tuple shape shared with core/spmd.py (("slice", ax, dim), ...)
Layout = _spmd.Layout

_KIND_TO_RULE = {"ring": "ring", "a2a": "a2a"}


# ---------------------------------------------------------------------------
# Protocol + lowering result
# ---------------------------------------------------------------------------


@dataclass
class RuleLowering:
    """What a rule contributes to the static schedule for one opaque node.

    ``arg_layouts`` are the layouts the executor must repartition each input
    into before calling ``run``; ``out_layout`` is the layout of the value
    *after* ``post_steps`` (which the generic step machinery executes);
    ``events`` are the rule's internal collectives, pre-priced as
    ``(kind, axes, elems, nbytes)`` — an optional 5th element marks the
    event as *overlapped* (issued alongside local compute, e.g. the
    double-buffered ring's K/V hops) — so the CollectiveTrace sees ring
    ppermute steps and a2a bytes without tracing; ``run(args)`` executes the
    node's local program inside the shard_map body.
    """

    arg_layouts: list[Layout]
    out_layout: Layout
    run: Callable[[Sequence[Any]], Any]
    post_steps: list[tuple] = field(default_factory=list)
    events: list[tuple] = field(default_factory=list)


@runtime_checkable
class OpaqueShardRule(Protocol):
    """Given a node, its plan assignment and the mesh, emit the per-device
    program.  ``lower`` returns ``None`` when the rule's structural
    preconditions do not hold — the executor then falls back to
    ``replicate`` (always correct, at worst pricier)."""

    name: str

    def lower(self, g: EinGraph, node: Node,
              ax_n: dict[str, tuple[str, ...]],
              sizes: dict[str, int]) -> RuleLowering | None: ...


# ---------------------------------------------------------------------------
# Registry + resolution
# ---------------------------------------------------------------------------

RULES: dict[str, OpaqueShardRule] = {}


def register_rule(rule: OpaqueShardRule) -> None:
    RULES[rule.name] = rule


def get_rule(name: str) -> OpaqueShardRule:
    return RULES[name]


def resolve_rule_name(node: Node) -> str:
    """Rule name declared for a node: its comm entries (explicit ``rule``
    key, else derived from ``kind``), falling back to the OpDef's bound
    ``shard_rule``; ``replicate`` when nothing is declared.  The comm
    declaration itself resolves through the OpDef
    (``opdef.comm_for_node``); explicit node params still override."""
    from repro.core import opdef

    names = set()
    for entry in opdef.comm_for_node(node):
        name = entry.get("rule") or _KIND_TO_RULE.get(entry.get("kind"))
        if name is not None:
            names.add(name)
    if not names:
        return opdef.shard_rule_for_node(node) or "replicate"
    if len(names) > 1:
        raise ValueError(
            f"node {node.name!r}: comm entries declare conflicting shard "
            f"rules {sorted(names)} — one rule lowers the whole node")
    return names.pop()


def validate_graph(g: EinGraph) -> None:
    """Plan-time validation: every opaque node's declaration (OpDef comm
    template or per-node override) must resolve to a registered rule with
    known kinds, so the DP never prices a schedule the executor cannot
    lower."""
    from repro.core import opdef

    for n in g.nodes:
        if n.kind != "opaque":
            continue
        for entry in opdef.comm_for_node(n):
            if entry.get("kind") not in _KIND_TO_RULE:
                raise ValueError(
                    f"node {n.name!r}: comm kind {entry.get('kind')!r} "
                    f"unknown (expected one of {sorted(_KIND_TO_RULE)})")
        name = resolve_rule_name(n)
        if name not in RULES:
            raise ValueError(
                f"node {n.name!r}: comm declares shard rule {name!r}, but "
                f"only {sorted(RULES)} are registered "
                "(core.opaque_rules.register_rule)")


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def _prod(xs) -> int:
    return math.prod(int(x) for x in xs)


# byte accounting must match the einsum path's exactly: share spmd's helper
_itemsize = _spmd._itemsize


def axis_linear_index(axes: Sequence[str], sizes: dict[str, int]):
    """Device's linearized (row-major, major→minor) coordinate along
    ``axes`` — a traced scalar; matches jax's tuple-axis collective order."""
    from jax import lax

    idx = 0
    for ax in axes:
        idx = idx * sizes[ax] + lax.axis_index(ax)
    return idx


def moe_route(route, capacity: int | None = None):
    """Deterministic top-1 routing in sequence-major token order.

    ``route (B, S, E)`` -> ``(expert (T,), pos (T,), gate (T,), cnt (E,))``
    with ``T = S*B`` and token ``t = s*B + b``.  ``pos`` is the token's
    global slot within its expert — the count of *earlier* (sequence-major)
    tokens routed to the same expert — so capacity cutoffs (``pos >=
    capacity`` drops the token) are identical between the dense stubs
    (models/opaque_stubs.py) and the sharded a2a rule, whose per-device
    counts only need a prefix over earlier sequence shards.
    """
    import jax
    import jax.numpy as jnp

    route = jnp.asarray(route)
    B, S, E = route.shape
    r2 = jnp.swapaxes(route, 0, 1).reshape(S * B, E)
    gates = jax.nn.softmax(r2, axis=-1)
    expert = jnp.argmax(r2, axis=-1)
    oneh = (expert[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(oneh, 0) - oneh,
                              expert[:, None], 1)[:, 0]
    gate = jnp.take_along_axis(gates, expert[:, None], 1)[:, 0]
    cnt = jnp.sum(oneh, axis=0)
    return expert, pos, gate, cnt


def _rank_by(dest, n: int):
    """Rank of each token among the tokens sharing its destination (the
    packing order both sides of an all_to_all agree on)."""
    import jax.numpy as jnp

    oneh = (dest[:, None] == jnp.arange(n)[None, :]).astype(jnp.int32)
    return jnp.take_along_axis(jnp.cumsum(oneh, 0) - oneh,
                               dest[:, None], 1)[:, 0]


# ---------------------------------------------------------------------------
# replicate: the always-correct fallback (the pre-rule executor behavior)
# ---------------------------------------------------------------------------


class ReplicateRule:
    """Gather every input to replicated, run the fused op densely on all
    devices, re-slice the output to the plan layout (local, free)."""

    name = "replicate"

    def lower(self, g, node, ax_n, sizes):
        arg_layouts = [tuple(() for _ in g.nodes[a].shape)
                       for a in node.inputs]
        out_layout = _spmd._plan_layout(node, ax_n, sizes)
        post_steps = _spmd.plan_repart(tuple(() for _ in node.shape),
                                       out_layout)

        def run(args):
            from repro.core import engine

            return engine.OPAQUE_FNS[node.op](*args, **node.call_params)

        return RuleLowering(arg_layouts=arg_layouts, out_layout=out_layout,
                            run=run, post_steps=post_steps)


# ---------------------------------------------------------------------------
# local: channel-parallel fused ops (recurrent scans) — zero collectives
# ---------------------------------------------------------------------------


class LocalRule:
    """Run the fused op on local blocks, no movement at all.

    An OpDef binds this rule to assert the op is *independent along every
    shardable label*: the local block of the output equals the global op
    applied to the local blocks of the inputs.  That is exactly the
    recurrent scans' structure — the scan runs along the (non-shardable)
    sequence label, the channel/batch labels are elementwise-independent —
    so sharding only channel labels costs zero collectives, where the
    replicate fallback gathered the full state on every device.

    Structural preconditions (``None`` → replicate): per-input labels are
    declared; a sharded label appearing in an input must also appear in
    the output (otherwise local blocks cannot compose the global result);
    every sharded label's extent divides its shard count.
    """

    name = "local"

    def lower(self, g, node, ax_n, sizes):
        if not node.in_labels or len(node.in_labels) != len(node.inputs):
            return None

        def norm(label):
            return _spmd._norm_axes(ax_n.get(label, ()), sizes)

        in_label_set = {l for ls in node.in_labels for l in ls}
        arg_layouts: list[Layout] = []
        for ls, a in zip(node.in_labels, node.inputs):
            lay = []
            for l, b in zip(ls, g.nodes[a].shape):
                axes = norm(l)
                if axes and l not in node.labels:
                    return None  # sharded label vanishes: not local
                if b % max(_prod(sizes[x] for x in axes), 1):
                    return None
                lay.append(axes)
            arg_layouts.append(tuple(lay))
        out_layout = []
        for l, b in zip(node.labels, node.shape):
            axes = norm(l)
            if axes and l not in in_label_set:
                return None  # output-only sharded label: nothing to slice by
            if b % max(_prod(sizes[x] for x in axes), 1):
                return None
            out_layout.append(axes)

        def run(args):
            from repro.core import opdef

            return opdef.executable(node.op)(*args, **node.call_params)

        return RuleLowering(arg_layouts=arg_layouts,
                            out_layout=tuple(out_layout), run=run)


# ---------------------------------------------------------------------------
# ring: sequence-parallel flash attention
# ---------------------------------------------------------------------------


class RingAttentionRule:
    """K/V circulate the ring; q stays put; (m, l, acc) carried across
    steps.  Structural contract: 3 inputs labeled ``q (b, h, s, d)``,
    ``k/v (b, k, ℓ, d)`` with ``ℓ`` the comm-declared ring label (``s``
    shared with q in prefill, the cache-time label in decode).  The q-head
    and kv-head dims are co-sharded on the union of their planned axes so
    the local GQA group mapping equals the global one; the head_dim must be
    unsharded.  When the ring label is unsharded the rule degenerates to a
    fully local per-shard call — zero collectives, which is exactly what
    the DP priced.

    With ``double_buffer`` (the default) the run closure issues ring step
    t+1's K/V ppermutes *before* block t's flash step: the hop has no data
    dependency on the step, so XLA's latency-hiding scheduler can overlap
    the transfer with the compute.  The values are identical — only the
    issue order changes — and the trace marks the hops ``overlap=True``
    so the schedule stays statically auditable."""

    name = "ring"
    double_buffer = True

    def lower(self, g, node, ax_n, sizes):
        if node.op != "flash_attention" or len(node.inputs) != 3:
            return None
        if len(node.in_labels) != 3 or any(len(ls) != 4
                                           for ls in node.in_labels):
            return None
        lq, lk, lv = node.in_labels
        if lk != lv:
            return None
        from repro.core import opdef

        ring_labels = {c["label"] for c in opdef.comm_for_node(node)
                       if c.get("kind") == "ring"}
        if len(ring_labels) != 1:
            return None
        ell = next(iter(ring_labels))
        b_l, h_l, sq_l, d_l = lq
        if lk[0] != b_l or lk[2] != ell or lk[3] != d_l:
            return None
        if tuple(node.labels) != (b_l, h_l, sq_l, d_l):
            return None
        k_l = lk[1]

        def norm(label):
            return _spmd._norm_axes(ax_n.get(label, ()), sizes)

        ba, ha, ka, ra, da = norm(b_l), norm(h_l), norm(k_l), norm(ell), \
            norm(d_l)
        if da:
            return None  # head_dim sharded: no local kernel call possible
        if sq_l != ell and norm(sq_l):
            return None  # decode: a sharded q-seq has no ring to ride
        head_axes = ha + tuple(a for a in ka if a not in ha)

        qn = g.nodes[node.inputs[0]]
        kn = g.nodes[node.inputs[1]]
        h_total, k_total = qn.shape[1], kn.shape[1]
        ph = _prod(sizes[a] for a in head_axes)
        r = _prod(sizes[a] for a in ra)
        if (k_total == 0 or h_total % k_total or h_total % max(ph, 1)
                or k_total % max(ph, 1)):
            return None
        if kn.shape[2] % max(r, 1) or (sq_l == ell and qn.shape[2] % max(r, 1)):
            return None

        q_ring = sq_l == ell
        q_layout: Layout = (ba, head_axes, ra if q_ring else (), ())
        kv_layout: Layout = (ba, head_axes, ra, ())
        sizes = dict(sizes)
        call = dict(node.call_params)

        db = bool(self.double_buffer)
        events: list[tuple] = []
        if r > 1:
            n_dev = _prod(sizes.values())
            n_loc = _prod(_spmd.local_shape(kn.shape, kv_layout, sizes))
            item = _itemsize(kn.dtype)
            ring_perm = tuple((i, (i + 1) % r) for i in range(r))
            for _step in range(r - 1):
                for _tensor in range(2):  # k and v each take the ring hop
                    events.append(("ppermute", tuple(ra), n_dev * n_loc,
                                   n_dev * n_loc * item, db, ring_perm))

        def run(args):
            import jax.numpy as jnp
            from jax import lax

            from repro.kernels import ops

            q, k, v = (jnp.asarray(a) for a in args)
            causal = call.get("causal", True)
            window = call.get("window", 0)
            scale = call.get("scale")
            q0 = call.get("q_offset", 0)
            if r <= 1:
                return ops.flash_attention(q, k, v, causal=causal,
                                           window=window, scale=scale,
                                           q_offset=q0)
            idx = axis_linear_index(ra, sizes)
            sq_loc, sk_loc = q.shape[2], k.shape[2]
            q_off = q0 + idx * sq_loc if q_ring else q0
            perm = [(i, (i + 1) % r) for i in range(r)]
            carry = None
            for t in range(r):
                j = (idx - t) % r  # kv block resident at ring step t
                if db and t < r - 1:
                    # double buffer: issue block t+1's hops before block
                    # t's flash step — no data dependency, so the
                    # scheduler overlaps the transfer with the compute
                    k_next = lax.ppermute(k, tuple(ra), perm)
                    v_next = lax.ppermute(v, tuple(ra), perm)
                carry = ops.flash_attention_step(
                    q, k, v, carry, causal=causal, window=window, scale=scale,
                    q_offset=q_off, kv_offset=j * sk_loc)
                if t < r - 1:
                    if db:
                        k, v = k_next, v_next
                    else:
                        k = lax.ppermute(k, tuple(ra), perm)
                        v = lax.ppermute(v, tuple(ra), perm)
            return ops.attention_finalize(carry, q.dtype)

        return RuleLowering(arg_layouts=[q_layout, kv_layout, kv_layout],
                            out_layout=q_layout, run=run, events=events)


# ---------------------------------------------------------------------------
# paged: the serving tier's block-table KV gather — zero collectives
# ---------------------------------------------------------------------------


class PagedKVRule:
    """Per-shard lowering of ``kv_block_gather`` (the paged KV cache).

    The gather is independent along batch, kv-heads and head_dim: each
    device looks its own table rows up in its own pool shard.  Structural
    contract: inputs ``pool (n, p, k, d)`` / ``tables (b, w)``, output
    ``(b, k, t, d)``; the block-index labels ``n``/``p``/``w`` must be
    unsharded (a split block has no local lookup), the pool is co-sharded
    with the output on the head labels, the table on batch.

    A sharded cache-time label ``t`` (what the OpDef's a2a comm entry
    prices) is realized *locally* too: it requires ``t = w*p`` exactly and
    the shard count to divide ``w``, so each device's t-stripe is a whole
    number of blocks — the table is sliced along ``w`` to match and each
    device gathers its stripe from the (replicated-over-t) pool.  Zero
    wire either way, which keeps the traced schedule strictly under the
    priced a2a bound.  Any failed precondition returns ``None`` →
    replicate fallback.
    """

    name = "paged"

    def lower(self, g, node, ax_n, sizes):
        if node.op != "kv_block_gather" or len(node.inputs) != 2:
            return None
        if len(node.in_labels) != 2 or len(node.in_labels[0]) != 4 \
                or len(node.in_labels[1]) != 2:
            return None
        n_l, p_l, k_l, d_l = node.in_labels[0]
        b_l, w_l = node.in_labels[1]
        if len(node.labels) != 4:
            return None
        t_l = node.labels[2]
        if tuple(node.labels) != (b_l, k_l, t_l, d_l):
            return None

        def norm(label):
            return _spmd._norm_axes(ax_n.get(label, ()), sizes)

        if norm(n_l) or norm(p_l) or norm(w_l):
            return None  # block-index labels stay whole
        ba, ka, ta, da = norm(b_l), norm(k_l), norm(t_l), norm(d_l)
        pool_n = g.nodes[node.inputs[0]]
        tab_n = g.nodes[node.inputs[1]]
        _n_blk, blk, kh, hd = pool_n.shape
        batch, w = tab_n.shape
        kv_len = node.shape[2]
        for extent, axes in ((batch, ba), (kh, ka), (hd, da)):
            if extent % max(_prod(sizes[x] for x in axes), 1):
                return None
        rt = _prod(sizes[x] for x in ta)
        if rt > 1 and (kv_len != w * blk or w % rt):
            return None  # t-stripes must be whole blocks, no truncated tail

        def run(args):
            import jax.numpy as jnp

            from repro.kernels import ops

            pool, tables = (jnp.asarray(a) for a in args)
            kvl = kv_len if rt <= 1 else tables.shape[1] * pool.shape[1]
            return ops.kv_block_gather(pool, tables, kvl)

        return RuleLowering(
            arg_layouts=[((), (), ka, da), (ba, ta)],
            out_layout=(ba, ka, ta, da), run=run)


# ---------------------------------------------------------------------------
# a2a: expert-parallel MoE dispatch / combine
# ---------------------------------------------------------------------------


class A2AMoERule:
    """Tokens stay sequence-sharded; expert buffers stay expert-sharded;
    the only bulk movement is a real all_to_all of token payloads (plus a
    tiny all-gather of per-expert counts that fixes the global capacity
    slots, and for combine an int32 slot-request all_to_all).  Matches the
    deterministic top-1 routing of ``moe_route`` bit-for-bit with the dense
    stubs.  Preconditions: the expert label carries the a2a mesh axes and
    divides E; the sequence extent divides the shard count."""

    name = "a2a"

    def lower(self, g, node, ax_n, sizes):
        if node.op == "moe_dispatch":
            return self._lower_dispatch(g, node, ax_n, sizes)
        if node.op == "moe_combine":
            return self._lower_combine(g, node, ax_n, sizes)
        return None

    @staticmethod
    def _norm(ax_n, sizes, label):
        return _spmd._norm_axes(ax_n.get(label, ()), sizes)

    def _lower_dispatch(self, g, node, ax_n, sizes):
        # x (b, s, a), route (b, s, e) -> out (e, c, a)
        if len(node.inputs) != 2 or len(node.in_labels) != 2:
            return None
        lx, lr = node.in_labels
        if len(lx) != 3 or len(lr) != 3 or lx[:2] != lr[:2]:
            return None
        e_l, c_l, a_l = node.labels
        if lr[2] != e_l or lx[2] != a_l:
            return None
        a2a_axes = self._norm(ax_n, sizes, e_l)
        if self._norm(ax_n, sizes, a_l):
            return None
        r = _prod(sizes[a] for a in a2a_axes)
        if r <= 1:
            return None  # nothing crosses experts: dense replicate is priced
        xn = g.nodes[node.inputs[0]]
        batch, seq, d_model = xn.shape
        n_exp, cap, _ = node.shape
        if n_exp % r or seq % r:
            return None
        ca = self._norm(ax_n, sizes, c_l)
        if any(a in a2a_axes for a in ca):
            return None

        sizes = dict(sizes)
        t_loc = batch * (seq // r)
        n_dev = _prod(sizes.values())
        item = _itemsize(xn.dtype)
        events = [
            ("all_gather", tuple(a2a_axes), n_dev * (r - 1) * n_exp,
             n_dev * (r - 1) * n_exp * 4),
            ("all_to_all", tuple(a2a_axes), n_dev * (r - 1) * t_loc,
             n_dev * (r - 1) * t_loc * 4),
            ("all_to_all", tuple(a2a_axes), n_dev * (r - 1) * t_loc * d_model,
             n_dev * (r - 1) * t_loc * d_model * item),
        ]
        post_steps = [("slice", ax, 1) for ax in ca]
        out_layout: Layout = (tuple(a2a_axes), tuple(ca), ())
        e_blk = n_exp // r

        def run(args):
            import jax.numpy as jnp
            from jax import lax

            x, route = (jnp.asarray(a) for a in args)
            expert, pos_l, _gate, cnt = moe_route(route)
            idx = axis_linear_index(a2a_axes, sizes)
            allc = lax.all_gather(cnt, tuple(a2a_axes), axis=0,
                                  tiled=False)                      # (r, E)
            prefix = jnp.sum(
                jnp.where(jnp.arange(r)[:, None] < idx, allc, 0), axis=0)
            pos = pos_l + prefix[expert]
            keep = pos < cap
            dest = expert // e_blk
            slot = jnp.where(keep, (expert % e_blk) * cap + pos,
                             -1).astype(jnp.int32)
            rank = _rank_by(dest, r)
            xt = jnp.swapaxes(x, 0, 1).reshape(t_loc, x.shape[-1])
            send_val = jnp.zeros((r, t_loc, x.shape[-1]),
                                 x.dtype).at[dest, rank].set(xt)
            send_slot = jnp.full((r, t_loc), -1,
                                 jnp.int32).at[dest, rank].set(slot)
            recv_val = lax.all_to_all(send_val, tuple(a2a_axes),
                                      split_axis=0, concat_axis=0, tiled=True)
            recv_slot = lax.all_to_all(send_slot, tuple(a2a_axes),
                                       split_axis=0, concat_axis=0, tiled=True)
            rs = recv_slot.reshape(-1)
            rv = recv_val.reshape(-1, x.shape[-1])
            valid = rs >= 0
            sidx = jnp.where(valid, rs, 0)
            out = jnp.zeros((e_blk * cap, x.shape[-1]), node.dtype)
            out = out.at[sidx].add(rv * valid[:, None].astype(x.dtype))
            return out.reshape(e_blk, cap, x.shape[-1])

        return RuleLowering(
            arg_layouts=[((), tuple(a2a_axes), ()), ((), tuple(a2a_axes), ())],
            out_layout=out_layout, run=run, post_steps=post_steps,
            events=events)

    def _lower_combine(self, g, node, ax_n, sizes):
        # y (e, c, a), route (b, s, e) -> out (b, s, a)
        if len(node.inputs) != 2 or len(node.in_labels) != 2:
            return None
        ly, lr = node.in_labels
        if len(ly) != 3 or len(lr) != 3:
            return None
        e_l, c_l, a_l = ly
        b_l, s_l, a_out = node.labels
        if lr[2] != e_l or lr[:2] != (b_l, s_l) or a_out != a_l:
            return None
        a2a_axes = self._norm(ax_n, sizes, e_l)
        if self._norm(ax_n, sizes, a_l):
            return None
        r = _prod(sizes[a] for a in a2a_axes)
        if r <= 1:
            return None
        yn = g.nodes[node.inputs[0]]
        n_exp, cap, d_model = yn.shape
        batch, seq, _ = node.shape
        if n_exp % r or seq % r:
            return None

        sizes = dict(sizes)
        t_loc = batch * (seq // r)
        n_dev = _prod(sizes.values())
        item = _itemsize(yn.dtype)
        events = [
            ("all_gather", tuple(a2a_axes), n_dev * (r - 1) * n_exp,
             n_dev * (r - 1) * n_exp * 4),
            ("all_to_all", tuple(a2a_axes), n_dev * (r - 1) * t_loc,
             n_dev * (r - 1) * t_loc * 4),
            ("all_to_all", tuple(a2a_axes), n_dev * (r - 1) * t_loc * d_model,
             n_dev * (r - 1) * t_loc * d_model * item),
        ]
        e_blk = n_exp // r

        def run(args):
            import jax.numpy as jnp
            from jax import lax

            y, route = (jnp.asarray(a) for a in args)
            expert, pos_l, gate, cnt = moe_route(route)
            idx = axis_linear_index(a2a_axes, sizes)
            allc = lax.all_gather(cnt, tuple(a2a_axes), axis=0, tiled=False)
            prefix = jnp.sum(
                jnp.where(jnp.arange(r)[:, None] < idx, allc, 0), axis=0)
            pos = pos_l + prefix[expert]
            keep = pos < cap
            owner = expert // e_blk
            slot = jnp.where(keep, (expert % e_blk) * cap + pos,
                             -1).astype(jnp.int32)
            rank = _rank_by(owner, r)
            send_req = jnp.full((r, t_loc), -1,
                                jnp.int32).at[owner, rank].set(slot)
            recv_req = lax.all_to_all(send_req, tuple(a2a_axes),
                                      split_axis=0, concat_axis=0, tiled=True)
            validr = recv_req >= 0
            rr = jnp.maximum(recv_req, 0)
            vals = (y.reshape(e_blk * cap, d_model)[rr]
                    * validr[..., None].astype(y.dtype))   # (r, t_loc, D)
            back = lax.all_to_all(vals, tuple(a2a_axes),
                                  split_axis=0, concat_axis=0, tiled=True)
            tok = back[owner, rank]                        # (t_loc, D)
            out = tok * (gate * keep).astype(y.dtype)[:, None]
            s_loc = route.shape[1]
            return out.reshape(s_loc, route.shape[0],
                               d_model).swapaxes(0, 1).astype(node.dtype)

        return RuleLowering(
            arg_layouts=[(tuple(a2a_axes), (), ()), ((), tuple(a2a_axes), ())],
            out_layout=((), tuple(a2a_axes), ()), run=run, events=events)


register_rule(ReplicateRule())
register_rule(LocalRule())
register_rule(RingAttentionRule())
register_rule(A2AMoERule())
register_rule(PagedKVRule())
