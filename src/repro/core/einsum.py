"""EinSum IR: the paper's declarative language as a small graph IR.

The paper (§3) defines a binary EinSum expression

    Z[l_Z]  <-  AGG_{l_agg}  COMBINE( X[l_X], Y[l_Y] )

with an arbitrary associative+commutative aggregation ``AGG`` and scalar
combiner ``COMBINE``.  A complex computation is an EinGraph: a DAG of such
nodes (§5).  Nodes come in four kinds:

  * ``input``  — a tensor fed into the computation (no EinSum, per §5).
  * ``einsum`` — a unary or binary extended-einsum node.
  * ``map``    — a unary elementwise function with static params (a unary
                 einsum with no aggregation; split out so params like scale
                 factors don't need to be graph inputs).
  * ``opaque`` — a fused op the notation cannot express at scale (flash
                 attention, top-k routing, recurrent scan, gather).  Opaque
                 nodes still carry *label metadata* so the decomposition
                 algorithm can reason about which dimensions are shardable
                 (DESIGN.md §2, third adaptation).

Labels are node-local, exactly as in the paper: producers and consumers are
linked positionally through edges, and repartitioning cost is computed on
positional partitioning vectors.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Combine (⊗) and aggregation (⊕) registries.
# ---------------------------------------------------------------------------

# Binary scalar combiners.  Each maps (x, y) -> scalar, vectorised over
# broadcast-aligned arrays by the engine / TRA runtime.
COMBINE2: dict[str, Callable] = {
    "mul": lambda x, y: x * y,
    "add": lambda x, y: x + y,
    "sub": lambda x, y: x - y,
    "div": lambda x, y: x / y,
    "sqdiff": lambda x, y: (x - y) ** 2,
    "absdiff": lambda x, y: abs(x - y),
    "maximum": lambda x, y: np.maximum(x, y) if isinstance(x, np.ndarray) else _jmax(x, y),
    "expsub": lambda x, y: _exp(x - y),   # e^(x-y): the softmax E node (§3)
}

# Unary maps (for einsum nodes with a single input, ⊗ is a unary map).
COMBINE1: dict[str, Callable] = {
    "id": lambda x: x,
    "exp": lambda x: _exp(x),
    "neg": lambda x: -x,
    "abs": lambda x: abs(x),
    "square": lambda x: x * x,
}

# Associative + commutative aggregations (§3 requires assoc+comm).
AGGS = ("sum", "max", "min", "prod")

_AGG_NP = {"sum": np.add, "max": np.maximum, "min": np.minimum, "prod": np.multiply}
_AGG_INIT = {"sum": 0.0, "max": -np.inf, "min": np.inf, "prod": 1.0}


def _exp(x):
    import jax.numpy as jnp

    return np.exp(x) if isinstance(x, (np.ndarray, float, np.floating)) else jnp.exp(x)


def _jmax(x, y):
    import jax.numpy as jnp

    return jnp.maximum(x, y)


# ---------------------------------------------------------------------------
# EinSpec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EinSpec:
    """Labels + operator choice for one (unary or binary) EinSum node."""

    in_labels: tuple[tuple[str, ...], ...]  # one tuple per input (1 or 2)
    out_labels: tuple[str, ...]
    combine: str = "mul"
    agg: str = "sum"  # "" means elementwise (no aggregation)

    def _spec_str(self) -> str:
        """The offending spec rendered as a parseable string — included in
        every validation error so messages are self-locating (and, with the
        sorted lists below, byte-deterministic across runs)."""
        ins = ", ".join(" ".join(ls) for ls in self.in_labels)
        return f"'{ins} -> {' '.join(self.out_labels)}'"

    def __post_init__(self):
        if len(self.in_labels) not in (1, 2):
            raise ValueError(
                f"EinSpec {self._spec_str()}: supports unary and binary "
                "expressions only")
        for ls in self.in_labels:
            if len(set(ls)) != len(ls):
                raise ValueError(
                    f"EinSpec {self._spec_str()}: repeated label within one "
                    f"input: {ls}")
        if self.agg and self.agg not in AGGS:
            raise ValueError(
                f"EinSpec {self._spec_str()}: aggregation {self.agg!r} not "
                f"in {AGGS}")
        reg = COMBINE2 if len(self.in_labels) == 2 else COMBINE1
        if self.combine not in reg:
            raise ValueError(
                f"EinSpec {self._spec_str()}: combine {self.combine!r} not "
                "registered")
        known = set(self.all_labels)
        for l in self.out_labels:
            if l not in known:
                raise ValueError(
                    f"EinSpec {self._spec_str()}: broadcast output label "
                    f"{l!r} unsupported (§3: no broadcasts)")
        if not self.agg and self.agg_labels:
            raise ValueError(
                f"EinSpec {self._spec_str()}: labels {self.agg_labels} "
                "aggregated but agg=''")

    # ℓ_XY with duplicates removed in order of first appearance (the ⊙ of §4)
    @property
    def all_labels(self) -> tuple[str, ...]:
        seen: list[str] = []
        for ls in self.in_labels:
            for l in ls:
                if l not in seen:
                    seen.append(l)
        return tuple(seen)

    # ℓ_agg: labels in inputs but not output (§3)
    @property
    def agg_labels(self) -> tuple[str, ...]:
        out = set(self.out_labels)
        return tuple(l for l in self.all_labels if l not in out)

    @property
    def is_contraction(self) -> bool:
        return self.combine == "mul" and self.agg == "sum"

    def einsum_str(self) -> str:
        """jnp.einsum subscripts (valid only when every label fits one char
        after canonical renaming)."""
        alphabet = "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ"
        ren = {l: alphabet[i] for i, l in enumerate(self.all_labels)}
        ins = ",".join("".join(ren[l] for l in ls) for ls in self.in_labels)
        return f"{ins}->{''.join(ren[l] for l in self.out_labels)}"

    def pretty(self) -> str:
        """The spec's labels as a ``parse_einsum``-able string, preserving
        the original label names: ``"b s e, e h d -> b s h d"``.

        One irreducible ambiguity: if some label is multi-character but
        *every* side holds at most one label, no side contains a space and
        the parser would read characters — fall back to the canonical
        single-char rendering (``einsum_str``) for that corner.
        """
        sides = [" ".join(ls) for ls in self.in_labels]
        sides.append(" ".join(self.out_labels))
        multi = any(len(l) > 1 for l in self.all_labels)
        if multi and not any(" " in s for s in sides):
            return self.einsum_str()
        return f"{', '.join(sides[:-1])} -> {sides[-1]}"


def parse_einsum(expr: str) -> tuple[tuple[tuple[str, ...], ...], tuple[str, ...]]:
    """Parse "b s e, e h d -> b s h d" (space-separated multi-char labels) or
    "bse,ehd->bshd" (single-char labels).

    Word mode is decided for the *whole expression*: if any side contains a
    space, every side is split on whitespace — so a spaceless side inside a
    spaced expression ("b s e, e -> b s") is one single label, never a run
    of characters.  A fully spaceless expression parses per character.
    """
    lhs, rhs = expr.split("->")
    sides = [s.strip() for s in lhs.split(",")] + [rhs.strip()]
    word_mode = any(" " in s for s in sides)

    def side(s: str) -> tuple[str, ...]:
        return tuple(s.split()) if word_mode else tuple(s)

    return tuple(side(s) for s in sides[:-1]), side(sides[-1])


# ---------------------------------------------------------------------------
# Nodes + graph
# ---------------------------------------------------------------------------


#: Node params consumed by the planner only (cost declarations), never
#: forwarded to the op's executable implementation.
PLANNER_ONLY_PARAMS = frozenset({"comm"})


@dataclass
class Node:
    nid: int
    name: str
    kind: str  # input | einsum | map | opaque
    labels: tuple[str, ...]  # output labels
    shape: tuple[int, ...]
    dtype: Any
    inputs: tuple[int, ...] = ()
    spec: EinSpec | None = None
    op: str = ""  # map fn / opaque kind
    params: dict = field(default_factory=dict)
    # opaque: labels that may be partitioned (None = all); agg-like labels
    # that behave as contracted (cost as aggregation) when partitioned.
    shardable: frozenset[str] | None = None
    # For opaque nodes: labels of each input, for repartition reasoning.
    in_labels: tuple[tuple[str, ...], ...] = ()
    # "file.py:line" of the frontend expression that built this node ("" for
    # imperatively-built graphs).  Diagnostics only: canonical hashing
    # (canon.node_struct) enumerates hashed fields explicitly and never
    # sees it, so identical programs traced from different files share plan
    # cache entries.
    srcloc: str = ""

    @property
    def rank(self) -> int:
        return len(self.shape)

    @property
    def call_params(self) -> dict:
        """Params to pass the executable op (planner declarations dropped)."""
        return {k: v for k, v in self.params.items()
                if k not in PLANNER_ONLY_PARAMS}

    def bound_of(self, label: str) -> int:
        return self.shape[self.labels.index(label)]


class EinGraph:
    """A DAG of EinSum nodes (the paper's EinGraph, §5)."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self.nodes: list[Node] = []

    # -- construction -------------------------------------------------------

    def _add(self, node: Node) -> int:
        self.nodes.append(node)
        return node.nid

    def input(self, name: str, labels: str | Sequence[str], shape: Sequence[int],
              dtype=np.float32) -> int:
        labels = _as_labels(labels)
        shape = tuple(int(s) for s in shape)
        if len(labels) != len(shape):
            raise ValueError(f"{name}: {len(labels)} labels vs rank {len(shape)}")
        return self._add(Node(len(self.nodes), name, "input", labels, shape, dtype))

    def einsum(self, expr: str, *args: int, combine: str | None = None,
               agg: str | None = None, name: str = "") -> int:
        in_labels, out_labels = parse_einsum(expr)
        if len(args) != len(in_labels):
            raise ValueError(f"{expr}: expected {len(in_labels)} args, got {len(args)}")
        if combine is None:
            combine = "mul" if len(in_labels) == 2 else "id"
        # default agg: sum if anything is contracted, else elementwise
        tmp = EinSpec(in_labels, out_labels, combine, "sum")
        if agg is None:
            agg = "sum" if tmp.agg_labels else ""
        spec = EinSpec(in_labels, out_labels, combine, agg)
        bounds: dict[str, int] = {}
        for ls, a in zip(in_labels, args):
            node = self.nodes[a]
            if len(ls) != node.rank:
                raise ValueError(
                    f"{expr}: input {node.name} rank {node.rank} vs labels {ls}")
            for l, b in zip(ls, node.shape):
                if bounds.setdefault(l, b) != b:
                    raise ValueError(f"{expr}: label {l} bound mismatch {bounds[l]} vs {b}")
        shape = tuple(bounds[l] for l in out_labels)
        dtype = self.nodes[args[0]].dtype
        return self._add(Node(len(self.nodes), name or f"ein{len(self.nodes)}",
                              "einsum", out_labels, shape, dtype, tuple(args), spec))

    def map(self, fn: str, arg: int, name: str = "", **params) -> int:
        node = self.nodes[arg]
        return self._add(Node(len(self.nodes), name or f"{fn}{len(self.nodes)}",
                              "map", node.labels, node.shape, node.dtype, (arg,),
                              None, fn, dict(params)))

    def opaque(self, kind: str, args: Sequence[int], out_labels: str | Sequence[str],
               out_shape: Sequence[int], *, in_labels: Sequence[Sequence[str]] = (),
               shardable: Iterable[str] | None = None, dtype=None,
               name: str = "", **params) -> int:
        out_labels = _as_labels(out_labels)
        dtype = dtype if dtype is not None else self.nodes[args[0]].dtype
        return self._add(Node(
            len(self.nodes), name or f"{kind}{len(self.nodes)}", "opaque",
            out_labels, tuple(int(s) for s in out_shape), dtype, tuple(args),
            None, kind, dict(params),
            frozenset(shardable) if shardable is not None else None,
            tuple(tuple(ls) for ls in in_labels)))

    # -- structure ----------------------------------------------------------

    def topo_order(self) -> list[int]:
        return [n.nid for n in self.nodes]  # construction order is topological

    def consumers(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {n.nid: [] for n in self.nodes}
        for n in self.nodes:
            for a in n.inputs:
                out[a].append(n.nid)
        return out

    def outputs(self) -> list[int]:
        cons = self.consumers()
        return [nid for nid, cs in cons.items() if not cs]

    def input_ids(self) -> list[int]:
        return [n.nid for n in self.nodes if n.kind == "input"]

    # labels of node `a` as seen by consumer node `v` (positional match).
    def edge_labels(self, v: int, a: int) -> tuple[tuple[str, ...], ...]:
        node = self.nodes[v]
        res = []
        if node.kind == "einsum":
            for i, inp in enumerate(node.inputs):
                if inp == a:
                    res.append(node.spec.in_labels[i])
        elif node.kind in ("map",):
            for inp in node.inputs:
                if inp == a:
                    res.append(node.labels)
        elif node.kind == "opaque" and node.in_labels:
            for i, inp in enumerate(node.inputs):
                if inp == a:
                    res.append(node.in_labels[i])
        return tuple(res)

    def __repr__(self):
        lines = [f"EinGraph({self.name}, {len(self.nodes)} nodes)"]
        for n in self.nodes:
            src = f" <- {n.inputs}" if n.inputs else ""
            op = n.spec.einsum_str() if n.spec else n.op
            lines.append(f"  [{n.nid:3d}] {n.kind:6s} {n.name:20s} {op:24s} "
                         f"{n.labels} {n.shape}{src}")
        return "\n".join(lines)


def _as_labels(labels: str | Sequence[str]) -> tuple[str, ...]:
    if isinstance(labels, str):
        return tuple(labels.split()) if " " in labels else tuple(labels)
    return tuple(labels)


# ---------------------------------------------------------------------------
# Feed resolution: name-keyed (or node-id-keyed) feeds -> {nid: value}
# ---------------------------------------------------------------------------


def resolve_feeds(g: EinGraph, feeds: dict) -> dict[int, Any]:
    """Resolve a feed dict keyed by input *names* (or node ids, or a mix)
    into ``{node id: value}``.

    The reference runtimes and the frontend agree on I/O keys through this
    one function: names resolve once, a name shared by two input nodes is an
    error (ambiguous), an unknown name is an error, and any graph input left
    unfed is an error listing what is missing.  Integer keys pass through
    untouched (the historical surface), including extras for non-input
    nodes, which evaluation simply ignores.
    """
    by_name: dict[str, int] = {}
    dups: set[str] = set()
    for n in g.nodes:
        if n.kind != "input":
            continue
        if n.name in by_name:
            dups.add(n.name)
        by_name[n.name] = n.nid
    out: dict[int, Any] = {}
    for k, v in feeds.items():
        if isinstance(k, str):
            if k in dups:
                raise ValueError(
                    f"feed name {k!r} is ambiguous: multiple input nodes "
                    "share it — feed by node id or rename the inputs")
            if k not in by_name:
                raise KeyError(
                    f"unknown input name {k!r}; graph inputs are "
                    f"{sorted(by_name)}")
            out[by_name[k]] = v
        else:
            out[int(k)] = v
    missing = sorted(n.name for n in g.nodes
                     if n.kind == "input" and n.nid not in out)
    if missing:
        raise ValueError(f"missing feeds for inputs {missing}")
    return out


# ---------------------------------------------------------------------------
# Dense reference evaluation (numpy) — the semantic ground truth used by the
# TRA equivalence tests.  Slow and simple on purpose.
# ---------------------------------------------------------------------------


def eval_einsum_dense(spec: EinSpec, *arrays: np.ndarray) -> np.ndarray:
    """Evaluate one EinSum node densely per the §3 semantics."""
    all_labels = spec.all_labels
    # broadcast every input up to the full joint index space I(b_XY)
    def lift(arr: np.ndarray, labels: tuple[str, ...]) -> np.ndarray:
        perm_src = list(labels)
        expanded = arr
        for l in all_labels:
            if l not in perm_src:
                expanded = expanded[..., None]
                perm_src.append(l)
        order = [perm_src.index(l) for l in all_labels]
        return np.transpose(expanded, order)

    lifted = [lift(a, ls) for a, ls in zip(arrays, spec.in_labels)]
    if len(lifted) == 2:
        joined = COMBINE2[spec.combine](lifted[0], lifted[1])
    else:
        joined = COMBINE1[spec.combine](lifted[0])
    # aggregate out agg labels
    if spec.agg:
        axes = tuple(i for i, l in enumerate(all_labels) if l in spec.agg_labels)
        if axes:
            red = {"sum": np.sum, "max": np.max, "min": np.min, "prod": np.prod}[spec.agg]
            joined = red(joined, axis=axes)
    kept = [l for l in all_labels if l not in spec.agg_labels]
    order = [kept.index(l) for l in spec.out_labels]
    return np.transpose(joined, order)


def eval_graph_dense(g: EinGraph, feeds: dict,
                     map_fns: dict[str, Callable] | None = None,
                     opaque_fns: dict[str, Callable] | None = None) -> dict[int, np.ndarray]:
    """Dense numpy evaluation of the whole graph (reference oracle).

    ``feeds`` may be keyed by input *name* or node id (see resolve_feeds).
    """
    from repro.core import engine as _eng  # late import; shares map registry

    feeds = resolve_feeds(g, feeds)
    vals: dict[int, np.ndarray] = {}
    for nid in g.topo_order():
        n = g.nodes[nid]
        if n.kind == "input":
            vals[nid] = np.asarray(feeds[nid])
        elif n.kind == "einsum":
            vals[nid] = eval_einsum_dense(n.spec, *[vals[a] for a in n.inputs])
        elif n.kind == "map":
            fn = (map_fns or {}).get(n.op) or _eng.MAP_FNS[n.op]
            vals[nid] = np.asarray(fn(vals[n.inputs[0]], **n.params))
        else:
            fn = (opaque_fns or {}).get(n.op) or _eng.OPAQUE_FNS[n.op]
            vals[nid] = np.asarray(fn(*[vals[a] for a in n.inputs],
                                      **n.call_params))
    return vals
