"""Persistent plan cache: in-memory LRU + optional on-disk JSON store.

Every entry is a ``Plan`` stored in *canonical labels* (core/canon.py) under
the key ``plan_key(graph, p, mesh, cost mode, ...)``, so a plan computed for
one graph is a cache **hit** for every isomorphic graph — same structure up
to label renaming, (label, bound) permutation, and commutative operand
order.  On lookup the canonical plan is rewritten back into the caller's
labels via the caller graph's own label maps; the returned object is a fresh
``Plan``, never a reference into the cache.

Two layers:

  * an in-memory LRU (``capacity`` entries) that every lookup goes through;
  * an optional JSON file (``path=``) reusing ``Plan.to_json``/``from_json``
    so serving/training jobs warm-start their planner across restarts
    (``launch/serve.py --plan-cache``, ``launch/train.py --plan-cache``).

The cache also hosts the §8.4 *path memo*: ``eindecomp`` memoizes the
per-path DP on canonical path signatures, so repeated isomorphic layers
inside one graph (or across graphs in one process) skip the DP entirely.
Path-memo entries are in-memory only — they are an intra-process
optimization, cheap to recompute and awkward to version on disk.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import warnings
from collections import OrderedDict

from repro.core import canon
from repro.core import decomp as _decomp
from repro.core.decomp import Plan
from repro.core.einsum import EinGraph

_STORE_VERSION = 1


class PlanCache:
    """LRU plan cache with an optional JSON backing file.

    Parameters
    ----------
    capacity:
        Max in-memory entries; least-recently-used plans are evicted first.
        Evicted entries that were loaded from / saved to disk are still
        rewritten on the next ``save``, so the file only ever grows by use.
    path:
        Optional JSON store.  If the file exists it is loaded eagerly
        (warm start); with ``autosave=True`` (default) every ``insert``
        rewrites it atomically.  Per-insert persistence is deliberate:
        inserts happen once per *unique* (graph, p, mesh, mode) — planner
        events, not request events — and a ~ms file write next to a ~100ms
        DP run buys crash durability.  Jobs that bulk-plan many cells can
        pass ``autosave=False`` and call ``save()`` once at the end.
    """

    def __init__(self, capacity: int = 256, path: str | None = None, *,
                 autosave: bool = True):
        self.capacity = max(1, int(capacity))  # a 0-capacity LRU cannot hold
        # even the entry being served; clamp rather than crash mid-lookup
        self.path = path
        self.autosave = autosave
        self._mem: OrderedDict[str, Plan] = OrderedDict()  # canonical labels
        self._path_memo: dict = {}
        self._lock = threading.Lock()
        # json-form entries known to be on disk (superset of evicted ones)
        # + the store mtime we last observed, so save() only re-reads the
        # file when another process has written it in between.
        self._disk_entries: dict = {}
        self._disk_mtime: float | None = None
        self.hits = 0
        self.misses = 0
        self.path_hits = 0
        self.path_misses = 0
        if path and os.path.exists(path):
            self.load(path)

    @classmethod
    def open(cls, path: str, capacity: int = 256) -> "PlanCache":
        """A disk-backed cache: loads ``path`` if present, persists on every
        insert.  The one-liner serving/training entry points use."""
        return cls(capacity=capacity, path=path, autosave=True)

    @classmethod
    def coerce(cls, cache: "PlanCache | str | os.PathLike | None") -> "PlanCache | None":
        """Accept what entry points take: a PlanCache, a store path (str or
        PathLike, opened disk-backed), or None (caching disabled)."""
        if isinstance(cache, (str, os.PathLike)):
            return cls.open(os.fspath(cache))
        return cache

    # -- keying --------------------------------------------------------------

    def key_for(self, g: EinGraph, p: int, **kw) -> str:
        """The cache key ``eindecomp`` arguments map to (see canon.plan_key)."""
        return canon.plan_key(g, p, **kw)

    # -- core API ------------------------------------------------------------

    def lookup(self, g: EinGraph, p: int, **kw) -> Plan | None:
        """Return a plan for ``g`` translated into its labels, or None.

        ``kw`` is forwarded to ``canon.plan_key`` (mesh_axes, cost_mode,
        offpath_repart, algo) — the same kwargs the plan was inserted under.
        """
        key = self.key_for(g, p, **kw)
        with self._lock:
            plan = self._mem.get(key)
            if plan is None:
                # revive an entry evicted from the LRU (or beyond capacity
                # at load): its JSON is still held in _disk_entries, one
                # deserialization away — never re-run the DP for it
                pj = self._disk_entries.get(key)
                if pj is not None:
                    try:
                        plan = Plan.from_json(pj)
                    except (KeyError, TypeError, ValueError):
                        plan = None
                if plan is not None:
                    self._mem[key] = plan
            if plan is None:
                self.misses += 1
                return None
            self._mem.move_to_end(key)
            self._evict_overflow()  # after move_to_end: key is MRU, kept
            self.hits += 1
        return canon.plan_from_canonical(g, plan)

    def insert(self, g: EinGraph, p: int, plan: Plan, **kw) -> str:
        """Store ``plan`` (computed for ``g``) under its canonical key and
        return that key.  The plan is translated to canonical labels first,
        so the stored entry is graph-name- and label-agnostic."""
        key = self.key_for(g, p, **kw)
        stored = canon.plan_to_canonical(g, plan)
        with self._lock:
            self._mem[key] = stored
            self._mem.move_to_end(key)
            self._evict_overflow()
        if self.path and self.autosave:
            self.save()
        return key

    def _evict_overflow(self) -> None:
        """Trim the LRU (lock held).  Disk-backed caches spill evictions to
        _disk_entries so a not-yet-persisted plan is never lost and a later
        lookup revives it without re-running the DP; memory-only caches keep
        strict LRU bounds (capacity is their only memory limit)."""
        while len(self._mem) > self.capacity:
            ek, ev = self._mem.popitem(last=False)
            if self.path:
                self._disk_entries[ek] = ev.to_json()

    def __len__(self) -> int:
        return len(self._mem)

    def __contains__(self, key: str) -> bool:
        return key in self._mem

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
            self._path_memo.clear()

    @property
    def stats(self) -> dict:
        return {"entries": len(self._mem), "hits": self.hits,
                "misses": self.misses, "path_hits": self.path_hits,
                "path_misses": self.path_misses}

    # -- on-disk JSON store (reuses Plan.to_json / Plan.from_json) -----------

    def save(self, path: str | None = None) -> str:
        """Atomically write the store as JSON.

        Entries already on disk are preserved and merged under the in-memory
        ones (memory wins on key conflicts), so LRU eviction — or a
        small-capacity cache pointed at a large store — never deletes plans
        from the file: the store only ever grows by use.  The read-merge-
        write runs under an advisory ``flock`` on ``<path>.lock``, so
        concurrent jobs sharing one store don't lose each other's inserts;
        the file is only re-read when its mtime shows another writer."""
        path = path or self.path
        if not path:
            raise ValueError("PlanCache.save: no path configured")
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path + ".lock", "w") as lockf:
            try:
                import fcntl

                fcntl.flock(lockf, fcntl.LOCK_EX)
            except (ImportError, OSError):  # non-POSIX: best effort
                pass
            try:
                mtime = os.stat(path).st_mtime_ns
            except OSError:
                mtime = None
            if mtime is not None and mtime != self._disk_mtime:
                try:
                    with open(path) as f:
                        prev = json.load(f)
                    if (isinstance(prev, dict)
                            and prev.get("version") == _STORE_VERSION):
                        self._disk_entries.update(prev.get("entries", {}))
                except (OSError, json.JSONDecodeError):
                    pass  # corrupt store: overwrite with a valid one
            with self._lock:
                self._disk_entries.update(
                    {k: v.to_json() for k, v in self._mem.items()})
                obj = {"version": _STORE_VERSION,
                       "entries": dict(self._disk_entries)}
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(obj, f)
                os.replace(tmp, path)
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
            self._disk_mtime = os.stat(path).st_mtime_ns
        return path

    def load(self, path: str | None = None) -> int:
        """Merge entries from a JSON store; returns how many were loaded.

        The cache is an optimization, never a correctness dependency, so a
        corrupt / unreadable / unknown-version file degrades to a cold start
        (with a warning) instead of taking the job down; individually
        malformed entries are skipped the same way."""
        path = path or self.path
        if not path:
            raise ValueError("PlanCache.load: no path configured")
        try:
            with open(path) as f:
                obj = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            warnings.warn(f"PlanCache: ignoring unreadable store {path}: {e}")
            return 0
        if not isinstance(obj, dict) or obj.get("version") != _STORE_VERSION:
            return 0
        try:
            self._disk_mtime = os.stat(path).st_mtime_ns
        except OSError:
            self._disk_mtime = None
        self._disk_entries.update(obj.get("entries", {}))
        n = 0
        with self._lock:
            for k, pj in obj.get("entries", {}).items():
                try:
                    self._mem[k] = Plan.from_json(pj)
                except (KeyError, TypeError, ValueError) as e:
                    warnings.warn(f"PlanCache: skipping bad entry {k}: {e}")
                    continue
                self._mem.move_to_end(k)
                n += 1
            while len(self._mem) > self.capacity:
                self._mem.popitem(last=False)
        return n

    # -- §8.4 path-DP memo (in-memory only) ----------------------------------

    def path_memo_get(self, key):
        with self._lock:
            v = self._path_memo.get(key)
            if v is None:
                self.path_misses += 1
            else:
                self.path_hits += 1
            return v

    def path_memo_put(self, key, value) -> None:
        with self._lock:
            if len(self._path_memo) >= 4096:  # runaway-graph backstop
                self._path_memo.clear()
            self._path_memo[key] = value


# ---------------------------------------------------------------------------
# Path-memo keying + snapshot/apply (used by core/decomp.eindecomp)
# ---------------------------------------------------------------------------


def path_memo_key(
    g: EinGraph,
    path: list[int],
    labeled: set[int],
    plan: Plan,
    p: int,
    mesh_axes: dict[str, int] | None,
    cost_mode: str,
    offpath_repart: bool,
) -> tuple:
    """A hashable, label-name-free signature of one §8.4 path DP instance.

    Two path invocations share a key only when the DP over them is the same
    problem: identical node structures (canonical per-node form), identical
    relational wiring (producers encoded as path positions, free graph
    inputs, pinned off-path partitionings, or ignored off-path nodes), and
    identical pinned targets from already-labeled consumers (the EinDecomp+
    boundary term).  Everything cost-relevant is in the key, so a hit is
    exact, not approximate.
    """
    pos = {nid: j for j, nid in enumerate(path)}
    entries = []
    for nid in path:
        n = g.nodes[nid]
        rel = []
        for a in (n.inputs[i] for i in canon.operand_order(n)):
            na = g.nodes[a]
            if a in pos:
                rel.append(("path", pos[a]))
            elif na.kind == "input":
                rel.append(("input", tuple(na.shape), canon._dtype_str(na.dtype)))
            elif a in labeled:
                da = tuple(plan.d_by_node[a].get(l, 1) for l in na.labels)
                rel.append(("labeled", da, tuple(na.shape)))
            else:
                rel.append(("ignored",))
        pinned = []
        if offpath_repart:
            # same predicate the DP itself uses (decomp._optimize_path), so
            # key and cost inputs cannot drift apart
            for mn in _decomp._labeled_consumers(g, nid, labeled, pos, plan):
                dm = plan.d_by_node[mn]
                for ls_m in g.edge_labels(mn, nid):
                    pinned.append(tuple(dm.get(l, 1) for l in ls_m))
        entries.append((canon.node_struct(g, nid), tuple(rel),
                        tuple(sorted(pinned))))
    mesh_sig = (tuple(sorted(mesh_axes.items()))
                if mesh_axes is not None else None)
    return (tuple(entries), int(p), mesh_sig, cost_mode, bool(offpath_repart))


def snapshot_path(g: EinGraph, path: list[int], plan: Plan) -> list[tuple]:
    """Capture the plan entries ``_optimize_path`` just produced for the
    path nodes, in canonical labels (the memo value)."""
    out = []
    for nid in path:
        ren = canon.node_label_map(g, nid)
        d = {ren.get(l, l): v for l, v in plan.d_by_node[nid].items()}
        ax = {ren.get(l, l): tuple(a)
              for l, a in plan.axes_by_node.get(nid, {}).items()}
        out.append((d, ax))
    return out


def apply_path(g: EinGraph, path: list[int], value: list[tuple],
               plan: Plan) -> None:
    """Write a memoized path result into ``plan`` in ``g``'s own labels."""
    for nid, (d, ax) in zip(path, value):
        inv = {c: o for o, c in canon.node_label_map(g, nid).items()}
        plan.d_by_node[nid] = {inv.get(l, l): v for l, v in d.items()}
        if ax:
            plan.axes_by_node[nid] = {inv.get(l, l): tuple(a)
                                      for l, a in ax.items()}
