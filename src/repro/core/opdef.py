"""OpDef: one declarative record per op kind — the unified op registry.

The paper's central extensibility claim (§5) is that the extended einsum
notation is *closed under extension*: any fused/opaque op can participate
in the tensor-relational rewrite as long as it declares its index semantics
and communication behavior.  Historically that declaration was scattered
over five private registries in three layers — ``engine.OPAQUE_FNS`` (dense
impl), ``engine.MAP_FNS`` + ``autodiff.GRAD_MAPS`` (elementwise forwards +
derivative links), ``opaque_rules.RULES`` bindings via hand-embedded
``comm`` param dicts, and per-call ``out_shape``/``shardable`` metadata in
the model builders — so adding one op meant editing five files and nothing
cross-validated that the five entries agreed.

An :class:`OpDef` bundles, per op kind:

  (a) an einsum-style **label signature** (``"b h s d, b k l d, b k l d ->
      b h s d"``) driving shape/dtype inference and plan-time label
      validation, so ``frontend.expr.opaque`` no longer needs a
      caller-supplied ``out_shape``;
  (b) the **dense reference implementation** (backend-polymorphic jnp);
  (c) an optional **accelerator kernel dispatcher** (the ``kernels/ops.py``
      pattern: Pallas on TPU, reference elsewhere) — preferred at execution
      time when present;
  (d) a **VJP rule** (``"auto"`` = generic ``jax.vjp`` of the impl as
      derived ``<kind>@vjp<i>`` opaque nodes; or a custom graph builder),
      unifying the map-op ``grad`` links with opaque gradients so
      ``Program.grad`` works through opaque nodes;
  (e) the **comm declaration** the §7 DP prices
      (``decomp._opaque_comm_cost`` consults the OpDef, renamed into the
      node's instance labels, instead of raw node params);
  (f) the bound **shard rule** name (``core/opaque_rules``) with
      registration-time precondition checks (rule must exist, comm kinds
      must be known, comm rules must agree with the bound rule).

Registration happens through :func:`defop` (frontend sugar: ``ein.defop`` /
``@ein.op``).  Registration-time cross-validation replaces the old silent
drift: duplicate kinds are rejected, the dense impl is invoked on tiny
signature-shaped inputs and its output shape is checked against the
signature, and comm/shard-rule references are resolved eagerly.

The legacy registries survive as **live views** over this registry
(:data:`MAP_FNS`, :data:`OPAQUE_FNS`, :data:`GRAD_MAPS` — re-exported from
their historical homes) so in-core callers and tests keep working; direct
use outside ``core/`` is lint-banned (pyproject ``flake8-tidy-imports``).
"""
from __future__ import annotations

import warnings
from collections.abc import MutableMapping
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from repro.core.einsum import parse_einsum

#: comm kinds the DP knows how to price (decomp._opaque_comm_cost).
COMM_KINDS = ("ring", "a2a")

#: tag separating a base kind from its derived auto-VJP kinds
#: (``flash_attention@vjp0`` = grad wrt input 0).
VJP_TAG = "@vjp"


class OpDefError(ValueError):
    """Raised on invalid op registration or on label/shape inference
    failures against a registered signature."""


# ---------------------------------------------------------------------------
# The record
# ---------------------------------------------------------------------------


@dataclass
class OpDef:
    """One registered op kind.  See the module docstring for field roles.

    ``signature=None`` admits fully-dynamic ops (``broadcast_to``) that
    carry their metadata per call; such ops get no inference and no impl
    check.  ``category`` is ``"opaque"`` (fused op, EinGraph ``opaque``
    node) or ``"map"`` (unary elementwise, EinGraph ``map`` node; ``grad``
    names its derivative map).
    """

    kind: str
    category: str = "opaque"
    signature: str | None = None
    in_labels: tuple[tuple[str, ...], ...] = ()
    out_labels: tuple[str, ...] = ()
    fn: Callable | None = None
    kernel: Callable | None = None
    vjp: Any = None                      # None | "auto" | callable(gg, node, dz)
    # why vjp is (deliberately) None — required by the OpDef-completeness
    # lint (tests/test_analysis.py) for any op that is neither
    # differentiable via vjp nor, for maps, via a grad link
    vjp_reason: str | None = None
    grad: str | None = None              # map category: derivative map kind
    comm: tuple[dict, ...] = ()          # template over signature labels
    shard_rule: str | None = None
    shardable: frozenset[str] | None = None
    param_bounds: dict = field(default_factory=dict)  # out-only label -> param
    out_dtype: Any = None                # None = dtype of first argument
    in_dtypes: tuple = ()                # impl-check input dtypes (None=f32)
    impl_override: Callable | None = None  # legacy dict-surface override
    implicit: bool = False               # created through a legacy shim

    @property
    def executable(self) -> Callable | None:
        """The callable execution uses: a test/legacy override wins, then
        the accelerator kernel dispatcher, then the dense reference."""
        if self.impl_override is not None:
            return self.impl_override
        return self.kernel if self.kernel is not None else self.fn

    @property
    def labels(self) -> tuple[str, ...]:
        """Every signature label, inputs first, in order of appearance."""
        seen: list[str] = []
        for ls in self.in_labels + (self.out_labels,):
            for l in ls:
                if l not in seen:
                    seen.append(l)
        return tuple(seen)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, OpDef] = {}
_builtins_loaded = False


def _ensure_builtins() -> None:
    """Load the built-in op catalog on first registry access (lazily, so
    importing core/opdef.py alone stays dependency-free)."""
    global _builtins_loaded
    if _builtins_loaded:
        return
    _builtins_loaded = True
    from repro.core import opdefs_builtin  # noqa: F401  (registers on import)


def get(kind: str) -> OpDef | None:
    _ensure_builtins()
    return _REGISTRY.get(kind)


def require(kind: str) -> OpDef:
    od = get(kind)
    if od is None:
        raise OpDefError(
            f"op kind {kind!r} is not registered — declare it with "
            "ein.defop(kind, signature, fn=...)")
    return od


def list_ops(category: str | None = None) -> list[str]:
    _ensure_builtins()
    return sorted(k for k, od in _REGISTRY.items()
                  if category is None or od.category == category)


def unregister(kind: str) -> None:
    """Remove a registered op (tests / the legacy dict surface)."""
    _ensure_builtins()
    _REGISTRY.pop(kind, None)


# ---------------------------------------------------------------------------
# Registration + cross-validation
# ---------------------------------------------------------------------------


def _as_labels(labels) -> tuple[str, ...]:
    if labels is None:
        return ()
    if isinstance(labels, str):
        return tuple(labels.split()) if " " in labels else tuple(labels)
    return tuple(labels)


def _validate_comm(kind: str, comm: Sequence[Mapping], in_labels, out_labels,
                   shard_rule) -> tuple[dict, ...]:
    known = set()
    for ls in in_labels:
        known.update(ls)
    known.update(out_labels)
    rules = set()
    out = []
    for entry in comm:
        entry = dict(entry)
        ckind = entry.get("kind")
        if ckind not in COMM_KINDS:
            raise OpDefError(
                f"defop({kind!r}): comm kind {ckind!r} unknown "
                f"(expected one of {sorted(COMM_KINDS)})")
        label = entry.get("label")
        if in_labels and label not in known:
            raise OpDefError(
                f"defop({kind!r}): comm entry references label {label!r} "
                f"absent from the signature (labels: {sorted(known)})")
        idx = entry.get("input")
        if in_labels and (not isinstance(idx, int)
                          or not (idx == -1 or 0 <= idx < len(in_labels))):
            raise OpDefError(
                f"defop({kind!r}): comm entry input index {idx!r} missing or "
                f"out of range for {len(in_labels)} inputs (-1 = the output)")
        rules.add(entry.get("rule") or ckind)
        out.append(entry)
    if len(rules) > 1:
        raise OpDefError(
            f"defop({kind!r}): comm entries resolve to conflicting shard "
            f"rules {sorted(rules)} — one rule lowers the whole node")
    if rules and shard_rule is not None and {shard_rule} != rules:
        raise OpDefError(
            f"defop({kind!r}): shard_rule={shard_rule!r} disagrees with the "
            f"rule the comm entries resolve to ({rules.pop()!r})")
    for name in rules | ({shard_rule} if shard_rule else set()):
        from repro.core import opaque_rules

        if name not in opaque_rules.RULES:
            raise OpDefError(
                f"defop({kind!r}): comm declaration references shard rule "
                f"{name!r}, but only {sorted(opaque_rules.RULES)} are "
                "registered (core.opaque_rules.register_rule)")
    return tuple(out)


_CHECK_BOUND = 4  # per-label extent for the registration-time impl check


def check_impl(kind: str) -> None:
    """Run the signature-vs-impl output-shape check for one registered op
    (no-op without both a signature and a dense impl).

    ``defop`` runs this automatically; the built-in catalog registers with
    ``check_impl=False`` — invoking an impl initializes the jax backend,
    which the pure-planning path (a metadata-only registry consumer) must
    never do — and ``tests/test_opdef.py`` sweeps this check over every
    builtin instead.
    """
    od = require(kind)
    if od.fn is not None and od.signature is not None:
        _check_impl_shape(od)


def _check_impl_shape(od: OpDef) -> None:
    """Invoke the dense impl on tiny signature-shaped inputs and verify the
    output shape matches the signature — the cross-validation that used to
    be impossible with impl and signature living in different registries."""
    bounds = {l: _CHECK_BOUND for l in od.labels}
    params = {pname: _CHECK_BOUND for pname in od.param_bounds.values()}
    args = []
    for i, ls in enumerate(od.in_labels):
        dt = od.in_dtypes[i] if i < len(od.in_dtypes) else None
        args.append(np.zeros(tuple(bounds[l] for l in ls),
                             np.dtype(dt) if dt is not None else np.float32))
    try:
        out = od.fn(*args, **params)
    except Exception as e:  # pragma: no cover - defensive
        raise OpDefError(
            f"defop({od.kind!r}): dense impl failed the registration "
            f"check on signature-shaped inputs "
            f"({' , '.join(str(a.shape) for a in args)}): {e!r}") from e
    want = tuple(bounds[l] for l in od.out_labels)
    got = tuple(np.shape(out))
    if got != want:
        raise OpDefError(
            f"defop({od.kind!r}): dense impl output shape {got} does not "
            f"match the signature {od.signature!r} (expected {want} for "
            f"bounds {bounds})")


def defop(kind: str, signature: str | None = None, *,
          fn: Callable | None = None, kernel: Callable | None = None,
          vjp=None, vjp_reason: str | None = None, grad: str | None = None,
          comm: Sequence[Mapping] = (), shard_rule: str | None = None,
          shardable=None, param_bounds: Mapping[str, str] | None = None,
          out_dtype=None, in_dtypes: Sequence = (),
          category: str = "opaque", check_impl: bool = True,
          overwrite: bool = False, implicit: bool = False) -> OpDef:
    """Register one op kind.  This is *the* extension point of the system:
    everything — shape inference, dense execution, kernel dispatch,
    autodiff, comm pricing, shard_map lowering — resolves through the
    record registered here.  See the module docstring for field roles;
    ``frontend`` re-exports this as ``ein.defop`` plus the ``@ein.op``
    decorator.

    Raises :class:`OpDefError` on duplicate kinds (unless ``overwrite``),
    malformed signatures/comm declarations, references to unregistered
    shard rules, and — when ``fn`` and a signature are given and
    ``check_impl`` holds — a dense-impl output shape that contradicts the
    signature.
    """
    _ensure_builtins()
    if category not in ("opaque", "map"):
        raise OpDefError(f"defop({kind!r}): unknown category {category!r}")
    if not overwrite and kind in _REGISTRY:
        raise OpDefError(
            f"defop({kind!r}): op kind already registered — pass "
            "overwrite=True to replace it, or pick another kind")
    if VJP_TAG in kind:
        raise OpDefError(
            f"defop({kind!r}): {VJP_TAG!r} is reserved for derived VJP ops")
    if grad is not None and category != "map":
        raise OpDefError(
            f"defop({kind!r}): grad= names a derivative *map*; opaque ops "
            "declare a vjp= rule instead")

    in_labels: tuple[tuple[str, ...], ...] = ()
    out_labels: tuple[str, ...] = ()
    if signature is not None:
        in_labels, out_labels = parse_einsum(signature)
        for ls in in_labels:
            if len(set(ls)) != len(ls):
                raise OpDefError(
                    f"defop({kind!r}): repeated label within one input: {ls}")
        bound_from_inputs = {l for ls in in_labels for l in ls}
        pb = dict(param_bounds or {})
        for l in out_labels:
            if l not in bound_from_inputs and l not in pb:
                raise OpDefError(
                    f"defop({kind!r}): output label {l!r} appears in no "
                    "input — bind it to a call param via "
                    "param_bounds={'%s': '<param>'}" % l)
        for l in pb:
            if l in bound_from_inputs:
                raise OpDefError(
                    f"defop({kind!r}): param_bounds label {l!r} is already "
                    "bound by an input")
    elif param_bounds:
        raise OpDefError(f"defop({kind!r}): param_bounds needs a signature")

    shardable_set = None
    if shardable is not None:
        shardable_set = frozenset(_as_labels(shardable))
        if signature is not None:
            universe = {l for ls in in_labels for l in ls} | set(out_labels) \
                | set(param_bounds or {})
            unknown = shardable_set - universe
            if unknown:
                raise OpDefError(
                    f"defop({kind!r}): shardable labels {sorted(unknown)} "
                    "absent from the signature")

    if grad is not None and grad != kind:
        target = _REGISTRY.get(grad)
        if target is None or target.category != "map":
            raise OpDefError(
                f"defop({kind!r}): grad names derivative map {grad!r}, "
                "which is not a registered map op — register it first "
                "(or use grad=<own kind> for self-derivative ops like exp)")

    comm_t = _validate_comm(kind, comm, in_labels, out_labels, shard_rule)

    od = OpDef(kind=kind, category=category, signature=signature,
               in_labels=in_labels, out_labels=out_labels, fn=fn,
               kernel=kernel, vjp=vjp, vjp_reason=vjp_reason, grad=grad,
               comm=comm_t,
               shard_rule=shard_rule, shardable=shardable_set,
               param_bounds=dict(param_bounds or {}), out_dtype=out_dtype,
               in_dtypes=tuple(in_dtypes), implicit=implicit)
    if fn is not None and signature is not None and check_impl:
        _check_impl_shape(od)
    _REGISTRY[kind] = od
    return od


def provide_impl(kind: str, fn: Callable, *, check: bool = True) -> OpDef:
    """Attach (or replace) the dense implementation of an already-declared
    op — the unified path for late-bound impls (``models/opaque_stubs``).
    With ``check``, the impl is validated against the declared signature.
    """
    od = require(kind)
    prev = od.fn
    od.fn = fn
    if check and od.signature is not None:
        try:
            _check_impl_shape(od)
        except OpDefError:
            od.fn = prev
            raise
    return od


# ---------------------------------------------------------------------------
# Call-site binding: signature + instance labels -> shapes / metadata
# ---------------------------------------------------------------------------


def instance_label_map(od: OpDef, in_labels: Sequence[Sequence[str]],
                       out_labels: Sequence[str] | None = None,
                       *, strict: bool = False) -> dict[str, str]:
    """{signature label -> instance label}, positional.

    Two signature labels may map to the *same* instance label (prefill
    attention renames the ring label ``l`` to the q-sequence ``s``); one
    signature label mapping to two different instance labels is ill-formed
    and raises when ``strict``.
    """
    ren: dict[str, str] = {}
    for sig_ls, inst_ls in zip(od.in_labels, in_labels):
        for s_l, i_l in zip(sig_ls, inst_ls):
            prev = ren.setdefault(s_l, i_l)
            if strict and prev != i_l:
                raise OpDefError(
                    f"{od.kind}: signature label {s_l!r} bound to both "
                    f"{prev!r} and {i_l!r} — instance labels must rename "
                    "each signature label consistently")
    if out_labels is not None:
        for s_l, i_l in zip(od.out_labels, out_labels):
            prev = ren.setdefault(s_l, i_l)
            if strict and prev != i_l:
                raise OpDefError(
                    f"{od.kind}: signature output label {s_l!r} bound to "
                    f"both {prev!r} and {i_l!r}")
    return ren


def bind_call(od: OpDef, arg_shapes: Sequence[Sequence[int]], *,
              in_labels: Sequence[Sequence[str]] = (),
              out_labels: Sequence[str] | None = None,
              params: Mapping[str, Any] | None = None) -> dict:
    """Infer one call's instance metadata from the signature.

    Returns ``{"in_labels", "out_labels", "out_shape", "shardable"}`` with
    every signature label renamed to the caller's instance labels
    (positionally) and every bound checked for consistency across the
    arguments — the plan-time label validation that makes caller-supplied
    ``out_shape`` unnecessary.
    """
    if od.signature is None:
        raise OpDefError(
            f"{od.kind}: op registered without a signature — pass "
            "out_labels and out_shape explicitly")
    if len(arg_shapes) != len(od.in_labels):
        raise OpDefError(
            f"{od.kind}: signature {od.signature!r} takes "
            f"{len(od.in_labels)} inputs, got {len(arg_shapes)}")
    inst_in = tuple(tuple(ls) for ls in in_labels) or od.in_labels
    if len(inst_in) != len(od.in_labels):
        raise OpDefError(
            f"{od.kind}: {len(inst_in)} in_labels for "
            f"{len(od.in_labels)} signature inputs")
    for i, (ls, shape) in enumerate(zip(inst_in, arg_shapes)):
        if len(ls) != len(od.in_labels[i]):
            raise OpDefError(
                f"{od.kind}: input {i} labels {ls} do not match the "
                f"signature arity {od.in_labels[i]}")
        if len(ls) != len(shape):
            raise OpDefError(
                f"{od.kind}: input {i} rank {len(shape)} vs labels {ls}")

    if out_labels is not None and len(tuple(out_labels)) != \
            len(od.out_labels):
        raise OpDefError(
            f"{od.kind}: {len(tuple(out_labels))} out_labels for the "
            f"{len(od.out_labels)} signature outputs {od.out_labels}")
    ren = instance_label_map(od, inst_in,
                             out_labels if out_labels is not None else None,
                             strict=True)
    # bounds per *instance* label (validates cross-argument consistency)
    bounds: dict[str, int] = {}
    for ls, shape in zip(inst_in, arg_shapes):
        for l, b in zip(ls, shape):
            if bounds.setdefault(l, int(b)) != int(b):
                raise OpDefError(
                    f"{od.kind}: label {l!r} bound mismatch "
                    f"{bounds[l]} vs {int(b)}")

    params = dict(params or {})
    inst_out: list[str] = []
    out_shape: list[int] = []
    for j, s_l in enumerate(od.out_labels):
        i_l = (tuple(out_labels)[j] if out_labels is not None
               else ren.get(s_l, s_l))
        inst_out.append(i_l)
        if i_l in bounds:
            out_shape.append(bounds[i_l])
        elif s_l in od.param_bounds:
            pname = od.param_bounds[s_l]
            if pname not in params:
                raise OpDefError(
                    f"{od.kind}: output label {s_l!r} is bound by call "
                    f"param {pname!r}, which was not passed")
            out_shape.append(int(params[pname]))
        else:
            raise OpDefError(
                f"{od.kind}: cannot infer the bound of output label "
                f"{i_l!r} from the inputs")
    shardable = None
    if od.shardable is not None:
        shardable = frozenset(ren.get(l, l) for l in od.shardable)
    return {"in_labels": inst_in, "out_labels": tuple(inst_out),
            "out_shape": tuple(out_shape), "shardable": shardable}


# ---------------------------------------------------------------------------
# Node-side resolution: comm declaration + shard rule for a graph node
# ---------------------------------------------------------------------------


def comm_for_node(node) -> list[dict]:
    """The comm declaration the DP prices for one opaque node.

    An explicit ``comm`` in the node's params wins (the historical per-call
    override, still honored); otherwise the registered OpDef's template is
    renamed into the node's instance labels via its ``in_labels`` /
    ``labels`` and returned.  Nodes of unregistered kinds declare nothing.
    """
    comm = node.params.get("comm")
    if comm is not None:
        return list(comm)
    cached = node.__dict__.get("_opdef_comm")
    if cached is not None:  # hot in the DP inner loop; nodes are immutable
        return list(cached)
    od = get(node.op)
    if od is None or not od.comm or od.signature is None:
        entries: list[dict] = []
    else:
        ren = instance_label_map(od, node.in_labels or (), node.labels)
        entries = [dict(e, label=ren.get(e["label"], e["label"]))
                   for e in od.comm]
    node.__dict__["_opdef_comm"] = tuple(entries)
    return entries


def shard_rule_for_node(node) -> str | None:
    """The OpDef-declared shard rule for a node whose comm entries name
    none (``opaque_rules.resolve_rule_name`` consults this)."""
    od = get(node.op)
    return od.shard_rule if od is not None else None


# ---------------------------------------------------------------------------
# Execution lookup (incl. derived @vjp kinds)
# ---------------------------------------------------------------------------


def executable_or_none(kind: str) -> Callable | None:
    _ensure_builtins()
    if VJP_TAG in kind:
        base_kind, _, idx = kind.rpartition(VJP_TAG)
        base = _REGISTRY.get(base_kind)
        if base is None or base.executable is None:
            return None
        return _vjp_impl(base_kind, int(idx))
    od = _REGISTRY.get(kind)
    return od.executable if od is not None else None


def executable(kind: str) -> Callable:
    fn = executable_or_none(kind)
    if fn is None:
        od = get(kind.rpartition(VJP_TAG)[0] if VJP_TAG in kind else kind)
        hint = ("its OpDef declares no implementation — attach one with "
                "opdef.provide_impl" if od is not None else
                "declare it with ein.defop(kind, signature, fn=...)")
        raise OpDefError(f"op kind {kind!r} has no implementation; {hint}")
    return fn


_VJP_IMPLS: dict[tuple[str, int], Callable] = {}


def _vjp_impl(base_kind: str, i: int) -> Callable:
    """Executable of the derived ``<kind>@vjp<i>`` op: pull the cotangent
    back through ``jax.vjp`` of the base op's **dense reference impl**,
    differentiating only the inexact (float/complex) arguments.

    The reference is differentiated deliberately: the kernel dispatcher
    may route to a raw ``pallas_call`` with no AD rule on TPU, and the two
    compute the same function — an op whose kernel should own its backward
    declares a custom ``vjp=`` rule instead of ``"auto"``."""
    key = (base_kind, i)
    cached = _VJP_IMPLS.get(key)
    if cached is not None:
        return cached

    def impl(*args, **params):
        import jax
        import jax.numpy as jnp

        *prim, ct = args
        prim = [jnp.asarray(a) for a in prim]
        diff = [j for j, a in enumerate(prim)
                if jnp.issubdtype(a.dtype, jnp.inexact)]
        if i not in diff:
            raise OpDefError(
                f"{base_kind}{VJP_TAG}{i}: input {i} is not differentiable "
                f"(dtype {prim[i].dtype})")
        od = require(base_kind)
        base = od.fn if od.fn is not None else executable(base_kind)

        def f(*da):
            full = list(prim)
            for j, v in zip(diff, da):
                full[j] = v
            return base(*full, **params)

        y, pull = jax.vjp(f, *[prim[j] for j in diff])
        return pull(jnp.asarray(ct, y.dtype))[diff.index(i)]

    _VJP_IMPLS[key] = impl
    return impl


# ---------------------------------------------------------------------------
# VJP graph construction (used by core/autodiff.grad_graph)
# ---------------------------------------------------------------------------


def _is_inexact(dtype) -> bool:
    try:
        return np.dtype(dtype).kind in "fc"
    except TypeError:
        return True


def build_vjp(gg, node, dz: int) -> list[int | None]:
    """Backward nodes for one opaque node: returns one adjoint node id per
    input (``None`` for non-differentiable inputs).

    Dispatches on the OpDef's ``vjp`` field: a callable builds custom
    backward structure (it receives ``(gg, node, dz)`` and returns the same
    shape of result); ``"auto"`` emits one derived ``<kind>@vjp<i>`` opaque
    node per inexact input, executed through ``jax.vjp`` of the forward
    impl.  An OpDef without a VJP — or an unregistered kind — raises the
    actionable error naming the op.
    """
    od = get(node.op)
    if od is None or od.vjp is None:
        have = f"OpDef for {node.op!r} declares no VJP" if od is not None \
            else f"op {node.op!r} has no OpDef"
        raise NotImplementedError(
            f"cannot differentiate through opaque op {node.op!r} "
            f"(node {node.name!r}): {have} — register one with "
            f"ein.defop({node.op!r}, ..., vjp='auto') or a custom "
            "vjp=callable")
    if callable(od.vjp):
        return list(od.vjp(gg, node, dz))
    if od.vjp != "auto":
        raise OpDefError(
            f"{node.op}: vjp must be None, 'auto', or callable, "
            f"got {od.vjp!r}")

    in_lab = node.in_labels or tuple((node.labels,) * len(node.inputs))
    outs: list[int | None] = []
    for i, (a, _ls) in enumerate(zip(node.inputs, in_lab)):
        an = gg.nodes[a]
        if not _is_inexact(an.dtype):
            outs.append(None)
            continue
        nid = gg.opaque(
            f"{node.op}{VJP_TAG}{i}", list(node.inputs) + [dz],
            an.labels, an.shape,
            in_labels=tuple(in_lab) + (tuple(node.labels),),
            shardable=node.shardable, dtype=an.dtype,
            name=f"{node.name or node.op}{VJP_TAG}{i}", **node.call_params)
        outs.append(nid)
    return outs


# ---------------------------------------------------------------------------
# Legacy views: MAP_FNS / OPAQUE_FNS / GRAD_MAPS over the one registry
# ---------------------------------------------------------------------------


class _ImplView(MutableMapping):
    """dict-compatible view of one category's executables.

    ``view[k] = fn`` installs a call-time override (creating a minimal
    implicit OpDef for unknown kinds — the legacy ``register_opaque``
    semantics, also what ``monkeypatch.setitem`` relies on); ``del
    view[k]`` removes the override, dropping implicit records entirely.
    """

    def __init__(self, category: str):
        self._category = category

    def _ods(self):
        _ensure_builtins()
        return {k: od for k, od in _REGISTRY.items()
                if od.category == self._category}

    def __getitem__(self, kind: str) -> Callable:
        fn = executable_or_none(kind)
        if fn is None:
            raise KeyError(kind)
        if VJP_TAG not in kind and require(kind).category != self._category:
            raise KeyError(kind)
        return fn

    def __setitem__(self, kind: str, fn: Callable) -> None:
        _ensure_builtins()
        od = _REGISTRY.get(kind)
        if od is None:
            od = defop(kind, None, category=self._category, implicit=True)
        elif od.category != self._category:
            # op kinds share one namespace now: writing an opaque impl over
            # a registered *map* op (or vice versa) would silently replace
            # its execution everywhere — the old split dicts kept such
            # writes inert, so reject instead of corrupting.
            raise OpDefError(
                f"op kind {kind!r} is registered as a {od.category} op — "
                f"cannot override it through the {self._category} view "
                "(pick another kind, or defop(..., overwrite=True))")
        od.impl_override = fn

    def __delitem__(self, kind: str) -> None:
        _ensure_builtins()
        od = _REGISTRY.get(kind)
        if od is None:
            raise KeyError(kind)
        od.impl_override = None
        if od.implicit and od.fn is None and od.kernel is None:
            del _REGISTRY[kind]

    def __iter__(self):
        return iter(sorted(k for k, od in self._ods().items()
                           if od.executable is not None))

    def __len__(self):
        return sum(1 for od in self._ods().values()
                   if od.executable is not None)

    def __repr__(self):
        return f"<{self._category} impl view over the OpDef registry: " \
               f"{sorted(self)}>"


class _GradMapView(MutableMapping):
    """dict-compatible view of the map-op derivative links (the historical
    ``autodiff.GRAD_MAPS``): ``{map kind: derivative map kind}``."""

    def _items(self):
        _ensure_builtins()
        return {k: od.grad for k, od in _REGISTRY.items()
                if od.category == "map" and od.grad is not None}

    def __getitem__(self, kind: str) -> str:
        grad = self._items().get(kind)
        if grad is None:
            raise KeyError(kind)
        return grad

    def __setitem__(self, kind: str, grad: str) -> None:
        _ensure_builtins()
        od = _REGISTRY.get(kind)
        if od is None:
            od = defop(kind, None, category="map", implicit=True)
        od.grad = grad

    def __delitem__(self, kind: str) -> None:
        od = _REGISTRY.get(kind)
        if od is None or od.grad is None:
            raise KeyError(kind)
        od.grad = None
        if od.implicit and od.executable is None:
            del _REGISTRY[kind]

    def __iter__(self):
        return iter(sorted(self._items()))

    def __len__(self):
        return len(self._items())


#: legacy registry surfaces — live views, re-exported by their historical
#: homes (engine.MAP_FNS / engine.OPAQUE_FNS / autodiff.GRAD_MAPS).
MAP_FNS = _ImplView("map")
OPAQUE_FNS = _ImplView("opaque")
GRAD_MAPS = _GradMapView()


def register_legacy(kind: str, fn: Callable, *, surface: str) -> None:
    """The body of the deprecated ``register_opaque`` entry points."""
    warnings.warn(
        f"{surface} is deprecated: register ops through the unified "
        f"OpDef API instead — ein.defop({kind!r}, '<signature>', fn=...) "
        "(one record: signature, impl, kernel, vjp, comm, shard rule)",
        DeprecationWarning, stacklevel=3)
    OPAQUE_FNS[kind] = fn
