"""Canonical forms + stable content hashes for EinSpecs and EinGraphs.

The §8 dynamic program is a pure function of a graph's *structure*: two
EinGraphs that differ only in label names, in bound/label permutations, or
in the operand order of a commutative combine have identical plan spaces and
identical optimal costs.  This module computes a canonical form whose hash
is invariant under exactly those transformations, so plans keyed by

    (canonical graph, p, cost-model mode, mesh shape)

transfer across isomorphic graphs (the retrieval idea of "Canonicalization
of Batched Einstein Summations for Tuning Retrieval", applied to whole
EinGraphs).  ``core/plancache.py`` builds the persistent cache on top.

Because labels are node-local in this IR (producers and consumers link
positionally, §5), canonicalization is per node: each node's label universe
is renamed de Bruijn-style — ``c0, c1, ...`` in order of first structural
appearance, scanning inputs (in canonical operand order) and then the
output.  Binary einsum nodes with a commutative combine additionally sort
their two operands by a label-name-free structural pattern, so ``X ⊗ Y``
and ``Y ⊗ X`` canonicalize identically.  Bounds enter the hash as a
*bound signature* aligned with the canonical label order, which makes the
hash invariant under joint (label, bound) permutations but sensitive to
any change in actual extents.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.core.decomp import Plan, node_bounds
from repro.core.einsum import EinGraph, EinSpec, Node

#: Binary combiners with COMBINE(x, y) == COMBINE(y, x); for these, operand
#: order is normalized away by canonicalization.  (``sub``/``div``/``expsub``
#: are order-sensitive and keep their operand order.)
COMMUTATIVE_COMBINES = frozenset({"mul", "add", "sqdiff", "absdiff", "maximum"})


# ---------------------------------------------------------------------------
# Per-node canonicalization
# ---------------------------------------------------------------------------


def _operand_patterns(spec: EinSpec) -> list[tuple]:
    """A label-name-free structural code per operand of a binary spec: for
    each label, (index in out_labels or -1, index in the other operand or
    -1).  Invariant under renaming and under swapping the operands."""
    out = spec.out_labels
    pats = []
    for i, ls in enumerate(spec.in_labels):
        other = spec.in_labels[1 - i]
        pats.append(tuple(
            (out.index(l) if l in out else -1,
             other.index(l) if l in other else -1)
            for l in ls))
    return pats


def operand_order(node: Node) -> tuple[int, ...]:
    """Canonical order of a node's operands.

    Identity for everything except binary einsum nodes with a commutative
    combine, whose two operands are sorted by (structural pattern, producer
    node id) — both label-name-free, so the order agrees across isomorphic
    graphs regardless of how the caller happened to write the expression.
    """
    if node.kind != "einsum" or len(node.spec.in_labels) != 2:
        return tuple(range(len(node.inputs)))
    if node.spec.combine not in COMMUTATIVE_COMBINES:
        return (0, 1)
    pats = _operand_patterns(node.spec)
    keys = sorted(range(2), key=lambda i: (pats[i], node.inputs[i], i))
    return tuple(keys)


def node_label_map(g: EinGraph, nid: int) -> dict[str, str]:
    """{original label -> canonical label} over the node's label universe.

    Canonical names are assigned in order of first structural appearance:
    operands first (in canonical operand order), then the output labels.
    Deterministic given the node's structure alone, so isomorphic nodes get
    structurally identical maps.
    """
    node = g.nodes[nid]
    ren: dict[str, str] = {}

    def see(label: str) -> None:
        if label not in ren:
            ren[label] = f"c{len(ren)}"

    if node.kind == "einsum":
        for slot in operand_order(node):
            for l in node.spec.in_labels[slot]:
                see(l)
        for l in node.spec.out_labels:
            see(l)
    else:
        for ls in node.in_labels:
            for l in ls:
                see(l)
        for l in node.labels:
            see(l)
    return ren


def _dtype_str(dtype) -> str:
    try:
        return str(np.dtype(dtype))
    except TypeError:
        return str(dtype)


def _params_sig(params: dict, ren: dict[str, str]) -> str:
    """Stable string form of a node's params with label references (the
    opaque ``comm`` declarations) renamed canonically."""
    if not params:
        return ""
    out = {}
    for k, v in params.items():
        if k == "comm":
            v = [dict(entry, label=ren.get(entry["label"], entry["label"]))
                 for entry in v]
        out[k] = v
    return json.dumps(out, sort_keys=True, default=repr)


def _spec_sig(spec: EinSpec, ren: dict[str, str], order: tuple[int, ...]) -> tuple:
    ins = tuple(tuple(ren[l] for l in spec.in_labels[slot]) for slot in order)
    return (ins, tuple(ren[l] for l in spec.out_labels), spec.combine, spec.agg)


def node_struct(g: EinGraph, nid: int) -> tuple:
    """Canonical structure of one node, *excluding* its producer references
    (used both for whole-graph signatures and for path-local DP memo keys,
    where producers are encoded relationally by the caller)."""
    node = g.nodes[nid]
    ren = node_label_map(g, nid)
    order = operand_order(node)
    bounds = node_bounds(g, nid)
    return (
        node.kind,
        node.op,
        _spec_sig(node.spec, ren, order) if node.spec else None,
        tuple(ren[l] for l in node.labels),
        tuple(node.shape),
        _dtype_str(node.dtype),
        tuple(tuple(ren[l] for l in ls) for ls in node.in_labels),
        (tuple(sorted(ren[l] for l in node.shardable if l in ren))
         if node.shardable is not None else None),
        _params_sig(node.params, ren),
        tuple(sorted((cl, bounds[l]) for l, cl in ren.items() if l in bounds)),
    )


def node_signature(g: EinGraph, nid: int) -> tuple:
    """``node_struct`` plus the producer node ids in canonical operand
    order — the full per-node entry of a graph signature."""
    node = g.nodes[nid]
    inputs = tuple(node.inputs[i] for i in operand_order(node))
    return node_struct(g, nid) + (inputs,)


# ---------------------------------------------------------------------------
# Whole-graph canonicalization
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CanonicalGraph:
    """The canonical form of an EinGraph.

    ``key`` is a stable sha256 content hash: equal for graphs that are
    isomorphic up to label renaming, (label, bound) permutation, and
    commutative operand order; distinct (modulo hash collisions) otherwise.
    ``label_maps[nid]`` maps each node's original labels to canonical ones,
    which is what lets a cached plan stored in canonical labels be rewritten
    back into any isomorphic caller's labels.
    """

    key: str
    signature: tuple
    label_maps: dict[int, dict[str, str]]

    def inverse_map(self, nid: int) -> dict[str, str]:
        """{canonical label -> original label} for one node."""
        return {c: o for o, c in self.label_maps[nid].items()}


def canonicalize(g: EinGraph) -> CanonicalGraph:
    """Compute (and memoize on the graph object) its canonical form.

    The memo is keyed on the node count: EinGraphs only ever grow by
    appending nodes, so a stale entry is impossible without mutating nodes
    in place (which nothing in this codebase does after construction).
    """
    cached = getattr(g, "_canon_cache", None)
    if cached is not None and cached[0] == len(g.nodes):
        return cached[1]
    signature = tuple(node_signature(g, nid) for nid in g.topo_order())
    key = hashlib.sha256(repr(signature).encode()).hexdigest()
    cg = CanonicalGraph(
        key=key,
        signature=signature,
        label_maps={nid: node_label_map(g, nid) for nid in g.topo_order()},
    )
    g._canon_cache = (len(g.nodes), cg)
    return cg


def graph_key(g: EinGraph) -> str:
    """Stable content hash of a whole EinGraph (see CanonicalGraph.key)."""
    return canonicalize(g).key


def subgraph_key(g: EinGraph, nids) -> str:
    """Stable content hash of the subgraph induced by ``nids`` — the
    pipeline tier's stage identity (repro.pipeline).

    In-subgraph producer references are encoded as local positions (in id
    order, which is topo order for this IR); references to producers
    outside the subgraph collapse to ("ext", shape, dtype) placeholders —
    exactly the information stage extraction turns into input stubs.  Two
    node sets that extract to isomorphic stage graphs (repeated
    transformer layers, whatever their global ids) therefore share a key,
    which is what lets per-stage plans resolve warm through the plan
    cache and lets diagnostics report stage dedup honestly.
    """
    order = sorted(int(n) for n in nids)
    pos = {nid: i for i, nid in enumerate(order)}
    sig = []
    for nid in order:
        node = g.nodes[nid]
        refs = tuple(
            ("in", pos[a]) if a in pos
            else ("ext", tuple(g.nodes[a].shape), _dtype_str(g.nodes[a].dtype))
            for a in (node.inputs[i] for i in operand_order(node)))
        sig.append(node_struct(g, nid) + (refs,))
    return hashlib.sha256(repr(tuple(sig)).encode()).hexdigest()


def plan_key(
    g: EinGraph,
    p: int,
    *,
    mesh_axes: dict[str, int] | None = None,
    cost_mode: str = "paper",
    offpath_repart: bool = False,
    algo: str = "eindecomp",
) -> str:
    """The full plan-cache key: canonical graph x every planner input that
    changes the resulting plan (device count, mesh shape + axis names, cost
    model mode, the EinDecomp+ off-path refinement flag, and which planner
    produced it)."""
    mesh_sig = (tuple(sorted(mesh_axes.items()))
                if mesh_axes is not None else None)
    raw = repr((graph_key(g), int(p), mesh_sig, cost_mode,
                bool(offpath_repart), algo))
    return hashlib.sha256(raw.encode()).hexdigest()


# ---------------------------------------------------------------------------
# Canonical EinSpec hashing (spec-level retrieval, no graph required)
# ---------------------------------------------------------------------------


def canonical_spec(
    spec: EinSpec, bounds: dict[str, int] | None = None
) -> tuple[EinSpec, dict[str, str]]:
    """Canonically rename one standalone EinSpec.

    Returns the renamed spec plus the {original -> canonical} label map.
    Commutative binary specs get their operands sorted by structural
    pattern (with per-label bounds as tie-break when given), so e.g.
    ``ij,jk->ik`` and ``jk,ij->ik`` with combine "mul" canonicalize to the
    same spec.
    """
    order = tuple(range(len(spec.in_labels)))
    if len(spec.in_labels) == 2 and spec.combine in COMMUTATIVE_COMBINES:
        pats = _operand_patterns(spec)
        bsig = [tuple((bounds or {}).get(l, 0) for l in ls)
                for ls in spec.in_labels]
        order = tuple(sorted(range(2), key=lambda i: (pats[i], bsig[i], i)))
    ren: dict[str, str] = {}
    for slot in order:
        for l in spec.in_labels[slot]:
            ren.setdefault(l, f"c{len(ren)}")
    for l in spec.out_labels:
        ren.setdefault(l, f"c{len(ren)}")
    new = EinSpec(
        tuple(tuple(ren[l] for l in spec.in_labels[slot]) for slot in order),
        tuple(ren[l] for l in spec.out_labels),
        spec.combine, spec.agg)
    return new, ren


def spec_key(spec: EinSpec, bounds: dict[str, int] | None = None) -> str:
    """Stable content hash of one EinSpec (plus its bound signature when
    bounds are given) — invariant under label renaming and commutative
    operand swap."""
    cspec, ren = canonical_spec(spec, bounds)
    bsig = (tuple(sorted((ren[l], b) for l, b in bounds.items() if l in ren))
            if bounds else None)
    raw = repr((cspec.in_labels, cspec.out_labels, cspec.combine, cspec.agg,
                bsig))
    return hashlib.sha256(raw.encode()).hexdigest()


# ---------------------------------------------------------------------------
# Plan label translation (canonical <-> caller labels)
# ---------------------------------------------------------------------------


def _translate(plan: Plan, maps: dict[int, dict[str, str]]) -> Plan:
    out = Plan(p=plan.p, mode=plan.mode, cost=plan.cost)
    out.d_by_node = {
        nid: {maps[nid].get(l, l): v for l, v in d.items()}
        for nid, d in plan.d_by_node.items()}
    out.axes_by_node = {
        nid: {maps[nid].get(l, l): tuple(a) for l, a in ax.items()}
        for nid, ax in plan.axes_by_node.items()}
    return out


def plan_to_canonical(g: EinGraph, plan: Plan) -> Plan:
    """Rewrite a plan for ``g`` into canonical labels (the storage form)."""
    return _translate(plan, canonicalize(g).label_maps)


def plan_from_canonical(g: EinGraph, plan: Plan) -> Plan:
    """Rewrite a canonically-labeled plan back into ``g``'s own labels —
    valid for any graph with the same canonical key as the one the plan was
    stored under."""
    cg = canonicalize(g)
    return _translate(plan, {nid: cg.inverse_map(nid) for nid in cg.label_maps})


# ---------------------------------------------------------------------------
# Test / benchmark helper: structurally-identical relabeled copies
# ---------------------------------------------------------------------------


def relabel_graph(
    g: EinGraph, fn: Callable[[int, str], str] | None = None
) -> EinGraph:
    """A structurally identical copy of ``g`` with every node's labels
    renamed through ``fn(nid, label)`` (default: suffix with the node id).

    Because labels are node-local, any per-node injective rename yields a
    semantically identical graph; the copy must therefore hash to the same
    canonical key — the invariant tests/test_plancache.py pins down.
    """
    fn = fn or (lambda nid, l: f"{l}_r{nid}")
    out = EinGraph(g.name)
    for n in g.nodes:
        universe = set(n.labels)
        if n.spec is not None:
            for ls in n.spec.in_labels:
                universe.update(ls)
        for ls in n.in_labels:
            universe.update(ls)
        universe.update(n.shardable or ())
        ren = {l: fn(n.nid, l) for l in universe}
        if len(set(ren.values())) != len(ren):
            raise ValueError("relabel fn must be injective per node")
        spec = None
        if n.spec is not None:
            spec = EinSpec(
                tuple(tuple(ren[l] for l in ls) for ls in n.spec.in_labels),
                tuple(ren[l] for l in n.spec.out_labels),
                n.spec.combine, n.spec.agg)
        params = dict(n.params)
        if "comm" in params:
            params["comm"] = [dict(e, label=ren[e["label"]])
                              for e in params["comm"]]
        out.nodes.append(dataclasses.replace(
            n,
            labels=tuple(ren[l] for l in n.labels),
            spec=spec,
            params=params,
            shardable=(frozenset(ren[l] for l in n.shardable)
                       if n.shardable is not None else None),
            in_labels=tuple(tuple(ren[l] for l in ls) for ls in n.in_labels),
        ))
    return out
