"""Communication-cost model for a decomposition (paper §7).

All costs are *upper bounds on floating-point numbers transferred*, exactly
as in the paper: every input to a dataflow node is assumed to be moved to
the processor where it is used.  All decompositions of a node have identical
FLOP counts, so comparing transfer volume is sufficient (§7).

Three cost terms per EinSum node:

  cost_join   — moving sub-tensors to the p join sites.
  cost_agg    — moving joined sub-tensors to their aggregation sites.
  cost_repart — re-slicing a producer's output relation into the
                partitioning the consumer requires.

The paper's §7 worked examples are reproduced in tests/test_cost.py.
(One known erratum: the paper's join example prints "8 x (16+16)" while its
own figures count 16 kernel calls for d=[4,1,1,4]; the *formula* is
p x (n_X + n_Y) with p = N(lX,lY,d) join results, which we implement.)

A second, *beyond-paper* cost mode ("collective") prices repartitions and
aggregations at torus-collective cost instead of point-to-point upper
bounds: all-gather / reduce-scatter at ring cost (k-1)/k * bytes, all-to-all
at bytes/k.  See DESIGN.md §2 (second adaptation).
"""
from __future__ import annotations

import math
from typing import Sequence

from repro.core.einsum import EinSpec
from repro.core.tra import ld_concat, project

# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def n_join_results(lx: Sequence[str], ly: Sequence[str], d_by_label: dict[str, int]) -> int:
    """N(lX, lY, d) (§6): number of tuple pairs matched by the join =
    product of d over the *unique* labels of the two inputs."""
    return _prod(d_by_label[l] for l in ld_concat(lx, ly))


def sub_numel(bounds: dict[str, int], d: dict[str, int], labels: Sequence[str]) -> int:
    """Floats per sub-tensor of a tensor with the given labels: prod(b/d)."""
    return _prod(bounds[l] // d[l] for l in labels)


# ---------------------------------------------------------------------------
# §7 cost terms.  All take d as a {label: parts} map plus {label: bound}.
# ---------------------------------------------------------------------------


def cost_join(spec: EinSpec, d: dict[str, int], bounds: dict[str, int]) -> int:
    """p * (n_X + n_Y): each of the p join sites receives one sub-tensor
    from each side.  Unary nodes move nothing (map runs in place)."""
    if len(spec.in_labels) == 1:
        return 0
    lx, ly = spec.in_labels
    p = n_join_results(lx, ly, d)
    nx = sub_numel(bounds, d, lx)
    ny = sub_numel(bounds, d, ly)
    return p * (nx + ny)


def cost_agg(spec: EinSpec, d: dict[str, int], bounds: dict[str, int]) -> int:
    """(p / n_agg) * (n_agg - 1) * n_Z: per aggregation group, all but one
    of the n_agg sub-tensors must move to the aggregation site."""
    if not spec.agg_labels:
        return 0
    if len(spec.in_labels) == 2:
        lx, ly = spec.in_labels
        p = n_join_results(lx, ly, d)
    else:
        p = _prod(d[l] for l in spec.in_labels[0])
    n_agg = _prod(d[l] for l in spec.agg_labels)
    if n_agg == 1:
        return 0
    n_z = sub_numel(bounds, d, spec.out_labels)
    return (p // n_agg) * (n_agg - 1) * n_z


def cost_repart(
    d_from: Sequence[int], d_to: Sequence[int], bound: Sequence[int],
    sites: int = 1,
) -> int:
    """§7 re-partitioning upper bound, from the producer's partitioning
    ``d_from`` to the consumer's required ``d_to`` over a tensor ``bound``.

    n_p   floats per producer sub-tensor        = prod(bound / d_from)
    n_c   floats per consumer sub-tensor        = prod(bound / d_to)
    n_int floats a producer block contributes
          to one consumer block                 = prod(min of block shapes)
    n     floats in the whole tensor            = prod(bound)

    cost = (n_c/n_int - 1) * (n/n_c) * (n_c + n_p)
           [+ n_p * (n/n_c) if n_p != n_int]

    ``sites`` counts *distinct consumer placement sites* the repartitioned
    relation must land on.  The §7 bound above delivers the tensor to one
    consumer-block site each; when the consumer runs replicated on ``sites``
    device groups (a gather to a replicated opaque consumer on a p-device
    mesh has sites = p / prod(d_to)), every extra group must receive the
    full tensor once more, adding (sites - 1) * n.  The default sites=1 is
    byte-identical to the historical single-site bound.
    """
    d_from = tuple(int(x) for x in d_from)
    d_to = tuple(int(x) for x in d_to)
    if d_from == d_to:
        return 0
    bp = [b // df for b, df in zip(bound, d_from)]   # producer block shape
    bc = [b // dt for b, dt in zip(bound, d_to)]     # consumer block shape
    n_p = _prod(bp)
    n_c = _prod(bc)
    n_int = _prod(min(a, b) for a, b in zip(bp, bc))
    n = _prod(bound)
    cost = (n_c // n_int - 1) * (n // n_c) * (n_c + n_p)
    if n_p != n_int:
        cost += n_p * (n // n_c)
    if sites > 1:
        cost += (sites - 1) * n
    return cost


def node_cost(spec: EinSpec, d: dict[str, int], bounds: dict[str, int]) -> int:
    """cost_join + cost_agg for executing one node under d (repartition of
    the *inputs* into this d is charged separately by the DP)."""
    return cost_join(spec, d, bounds) + cost_agg(spec, d, bounds)


# ---------------------------------------------------------------------------
# Beyond-paper: collective-aware cost mode (DESIGN.md §2, adaptation 2).
#
# On a torus, a repartition is not p2p block shuffling, it lowers to one of:
#   * all-gather   (un-splitting a dimension):    (k-1)/k * n   per device row
#   * all-to-all   (moving split between dims):   ~ n / k
#   * reduce-scatter (during aggregation):        (k-1)/k * n
# We price the aggregated tensor movement accordingly.  This changes the
# *relative* cost of plans that re-shard between ops vs plans that aggregate,
# and is measured as a §Perf iteration, never silently substituted.
# ---------------------------------------------------------------------------


def repart_collective_terms(
    d_from: Sequence[int], d_to: Sequence[int], bound: Sequence[int]
) -> dict[str, int]:
    """Collective repartition price decomposed by collective kind, so a
    calibrated ``CostModel`` can weight each kind by its measured constant."""
    d_from = tuple(int(x) for x in d_from)
    d_to = tuple(int(x) for x in d_to)
    terms = {"all_gather": 0, "all_to_all": 0}
    if d_from == d_to:
        return terms
    n = _prod(bound)
    for df, dt in zip(d_from, d_to):
        if df == dt:
            continue
        if df > dt:
            k = df // max(dt, 1)
            terms["all_gather"] += (k - 1) * n // max(k, 1)
        else:
            k = dt // max(df, 1)
            terms["all_to_all"] += n // max(k, 1)  # scatter / all-to-all
    return terms


def cost_repart_collective(
    d_from: Sequence[int], d_to: Sequence[int], bound: Sequence[int],
    sites: int = 1,
) -> int:
    """Collective repartition price; with ``sites`` > 1 every distinct
    consumer placement group runs its own collective over the same volume
    (the traced schedule replays the gather once per replica group)."""
    return max(sites, 1) * sum(
        repart_collective_terms(d_from, d_to, bound).values())


def cost_agg_collective(spec: EinSpec, d: dict[str, int], bounds: dict[str, int]) -> int:
    """reduce-scatter pricing: (k-1)/k of the *output* tensor per reduction
    group, instead of the paper's (n_agg-1) full sub-tensor moves."""
    if not spec.agg_labels:
        return 0
    n_agg = _prod(d[l] for l in spec.agg_labels)
    if n_agg == 1:
        return 0
    out_total = _prod(bounds[l] for l in spec.out_labels)
    return (n_agg - 1) * out_total // n_agg


def cost_join_collective(spec: EinSpec, d: dict[str, int], bounds: dict[str, int]) -> int:
    """Collective pricing of the join's input movement.

    Partitioning vector ``d`` yields p = N(lX, lY, d) join sites; input i,
    stored as n_i = prod(d over its own labels) blocks, is therefore needed
    at r_i = p / n_i sites per block.  On a torus that replication is a
    broadcast / all-gather over each replica group: the copy already
    resident is free and every one of the (r_i - 1) extra copies crosses
    the wire exactly once, so the term is (r_i - 1) * numel_i per input —
    exactly the §7 p2p join bound r_i * numel_i minus the resident copies.
    Unary nodes move nothing (map runs in place).
    """
    if len(spec.in_labels) == 1:
        return 0
    lx, ly = spec.in_labels
    p = n_join_results(lx, ly, d)
    total = 0
    for ls in (lx, ly):
        n_i = _prod(d[l] for l in ls)
        r = p // n_i
        if r > 1:
            total += (r - 1) * _prod(bounds[l] for l in ls)
    return total


def node_cost_collective(spec: EinSpec, d: dict[str, int], bounds: dict[str, int]) -> int:
    """cost_join_collective + cost_agg_collective — the collective-mode
    counterpart of ``node_cost``.  (Historically the collective mode
    silently dropped the join term entirely, which made any replicating
    partitioning look free; regression-pinned in tests/test_cost.py.)"""
    return (cost_join_collective(spec, d, bounds)
            + cost_agg_collective(spec, d, bounds))


# ---------------------------------------------------------------------------
# Beyond-paper: overlap-aware exposed wire (graph-wide lookahead prefetch).
#
# The §7 terms price wire *volume*; wall-clock pays only the part not hidden
# behind local compute.  The shard_map executor's lookahead pass issues each
# ready consumer's repartition chain before an earlier node's compute block
# (core/spmd.py), so the wire it moves is overlappable — but a compute block
# can only hide so much: we bound the hidden volume per issue site by that
# site's local-compute window (its local output elems), so the term can't
# pretend unbounded traffic disappears behind a tiny block.
# ---------------------------------------------------------------------------


def exposed_wire(total_elems: int, overlap_by_site: dict[int, int],
                 window_by_site: dict[int, int]) -> int:
    """Exposed (non-hidden) wire elems of a schedule.

    ``total_elems`` is the schedule's total traced wire volume;
    ``overlap_by_site`` maps each issue site (node id) to the overlappable
    wire elems issued behind its compute block (hoisted prefetch chains at
    their issue node, rule-internal overlaps like the ring's double buffer
    at their own node); ``window_by_site`` maps each node to its
    local-compute window (``Schedule.compute_elems`` — local output elems,
    the proxy for how much wire that block can hide).

        exposed = max(total − Σ_site min(overlap, window), 0)
    """
    hidden = sum(min(int(v), int(window_by_site.get(site, 0)))
                 for site, v in overlap_by_site.items())
    return max(int(total_elems) - hidden, 0)


# ---------------------------------------------------------------------------
# Beyond-paper: pipeline-bubble pricing (GPipe fill/drain over a `pp` axis).
#
# The §7 terms price wire; a pipeline additionally pays *idle* device time
# while the schedule fills and drains.  With p stages and m microbatches the
# GPipe schedule runs m + p - 1 ticks of which p - 1 are fill/drain, so the
# static bubble fraction is (p-1)/(m+p-1) — independent of tensor sizes.
# The measured variant replaces the uniform tick with per-stage compute
# weights: makespan = sum(c_s) + (m-1) * max(c_s) (every microbatch after
# the first waits on the slowest stage), busy = m * sum(c_s) over p workers.
# ---------------------------------------------------------------------------


def bubble_fraction(stages: int, microbatches: int) -> float:
    """Static GPipe bubble fraction (p - 1) / (m + p - 1)."""
    p, m = int(stages), int(microbatches)
    if p <= 1:
        return 0.0
    return (p - 1) / (m + p - 1)


def bubble_fraction_weighted(stage_compute: Sequence[int],
                             microbatches: int) -> float:
    """Bubble fraction under per-stage compute weights ``stage_compute``
    (per-microbatch cost proxies, e.g. local compute elems).  Equals the
    static ``bubble_fraction`` exactly when the stages are balanced and
    degrades gracefully under imbalance (the slowest stage paces the
    steady state)."""
    cs = [int(c) for c in stage_compute]
    p, m = len(cs), int(microbatches)
    if p <= 1 or sum(cs) == 0:
        return 0.0
    makespan = sum(cs) + (m - 1) * max(cs)
    busy = m * sum(cs)
    return max(1.0 - busy / (p * makespan), 0.0)


# ---------------------------------------------------------------------------
# CostModel: the pricing strategy the §8 DP runs with.
# ---------------------------------------------------------------------------


class CostModel:
    """Paper (§7 p2p upper bound) vs collective (torus ring) pricing —
    DESIGN.md §2 second adaptation.  The DP is identical; only the repart
    and aggregation prices change.

    In collective mode an optional ``coeffs`` map scales each collective
    kind's ring-formula price by a measured constant (relative to
    all-gather), so the DP prices with *observed* interconnect behavior
    instead of the analytic formulas.  Build one from a
    ``bench_spmd.py --emit-costs`` dump via ``CostModel.with_measured``.
    """

    def __init__(self, mode: str = "paper",
                 coeffs: dict[str, float] | None = None):
        assert mode in ("paper", "collective")
        self.mode = mode
        self.coeffs = dict(coeffs) if coeffs else None

    def describe(self) -> str:
        """Stable one-line description of the pricing this model applies —
        recorded alongside benchmark output so BENCH artifacts say which
        cost model produced their numbers."""
        if not self.coeffs:
            return self.mode
        co = ", ".join(f"{k}={v:.3f}" for k, v in sorted(self.coeffs.items()))
        return f"{self.mode}[{co}]"

    def __repr__(self):
        return f"CostModel({self.describe()})"

    def repart(self, d_from, d_to, bound, sites: int = 1):
        if self.mode == "collective":
            if self.coeffs:
                terms = repart_collective_terms(d_from, d_to, bound)
                return int(max(sites, 1) * sum(v * self.coeffs.get(k, 1.0)
                                               for k, v in terms.items()))
            return cost_repart_collective(d_from, d_to, bound, sites=sites)
        return cost_repart(d_from, d_to, bound, sites=sites)

    def node(self, spec, d, bounds):
        if self.mode == "collective":
            if self.coeffs:
                join = cost_join_collective(spec, d, bounds)
                agg = cost_agg_collective(spec, d, bounds)
                return int(join * self.coeffs.get("all_gather", 1.0)
                           + agg * self.coeffs.get("psum_scatter", 1.0))
            return node_cost_collective(spec, d, bounds)
        return node_cost(spec, d, bounds)

    def exposed(self, total_elems: int, overlap_by_site: dict[int, int],
                window_by_site: dict[int, int]) -> int:
        """Overlap-aware exposed wire of a realized schedule (see
        ``exposed_wire``) — the volume left after hiding each issue site's
        overlappable traffic behind its local-compute window.  Mode- and
        coefficient-independent: overlap changes *when* wire moves, not
        how a kind is priced."""
        return exposed_wire(total_elems, overlap_by_site, window_by_site)

    @classmethod
    def with_measured(cls, source) -> "CostModel":
        """Collective-mode model calibrated from measured constants.

        ``source`` is a path to (or dict of) the JSON that
        ``benchmarks/bench_spmd.py --emit-costs out.json`` writes:
        ``{"kinds": {kind: {"ns_per_elem": float, ...}, ...}, ...}``.
        Each kind's price is scaled by its measured ns-per-element relative
        to all-gather's (the reference collective); kinds the measurement
        missed keep coefficient 1.0.
        """
        import json
        from pathlib import Path

        obj = source if isinstance(source, dict) else json.loads(
            Path(source).read_text())
        kinds = obj.get("kinds", obj)
        ns = {k: float(v["ns_per_elem"]) for k, v in kinds.items()
              if isinstance(v, dict) and v.get("ns_per_elem")}
        if not ns:
            return cls("collective")
        base = ns.get("all_gather") or (sum(ns.values()) / len(ns))
        return cls("collective",
                   coeffs={k: v / base for k, v in ns.items()})
