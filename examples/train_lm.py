"""End-to-end training driver: train a small LM for a few hundred steps on
the synthetic pipeline, with EinDecomp-planned sharding, checkpointing and
restart.  The loss must visibly drop (the synthetic stream has learnable
motif structure).

Default scale (~10M params, CPU-friendly).  On a real pod, swap --arch for
any assigned architecture and point the mesh at the pod:

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --arch yi-9b --reduced --steps 50
"""
import argparse
import dataclasses

from repro.configs import get_config, reduced
from repro.configs.base import ModelConfig, ShapeConfig, register
from repro.launch.train import train

LM10M = ModelConfig(
    name="lm-10m", family="dense",
    n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=1024, vocab=2048,
    act="silu", gated_ffn=True, dtype="float32",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-10m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    if args.arch == "lm-10m":
        cfg = LM10M
    else:
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = reduced(cfg)
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")
    shape = ShapeConfig("train_cli", "train", args.seq, args.batch)
    out = train(cfg, shape, steps_total=args.steps, ckpt_dir=args.ckpt,
                ckpt_every=max(args.steps // 4, 1))
    hist = out["history"]
    first, last = hist[0][1], hist[-1][1]
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'DROPPED' if last < first else 'no drop — investigate'})")
    assert last < first


if __name__ == "__main__":
    main()
