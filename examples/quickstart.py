"""Quickstart: the paper in one file.

1. Declare a computation in EinSum notation (an EinGraph).
2. EinDecomp chooses a partitioning vector per node (the TRA decomposition).
3. Execute it two ways — through the faithful tensor-relational reference
   runtime (keyed sub-tensors, join/agg/repartition) and through the
   production JAX engine (GSPMD shardings) — and check they agree.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.decomp import eindecomp, plan_sqrt
from repro.core.einsum import EinGraph
from repro.core import engine
from repro.core.tra import execute_graph_tra


def main() -> None:
    # --- 1. declare:  Z = softmax_rows((A @ B) / 8) @ C ---------------------
    g = EinGraph("quickstart")
    A = g.input("A", "ij", (64, 128))
    B = g.input("B", "jk", (128, 64))
    C = g.input("C", "kl", (64, 32))
    AB = g.einsum("ij,jk->ik", A, B, name="AB")
    scaled = g.map("scale", AB, c=1 / 8.0)
    # the paper's §3 softmax, written as EinSum nodes
    mx = g.einsum("ik->i", scaled, combine="id", agg="max")
    e = g.einsum("ik,i->ik", scaled, mx, combine="expsub", agg="")
    s = g.einsum("ik->i", e, combine="id", agg="sum")
    sm = g.einsum("ik,i->ik", e, s, combine="div", agg="")
    Z = g.einsum("ik,kl->il", sm, C, name="Z")
    print(g)

    # --- 2. decompose for p=8 devices ---------------------------------------
    plan = eindecomp(g, p=8, offpath_repart=True)
    sqrt_plan = plan_sqrt(g, 8)
    print(f"\nEinDecomp plan cost: {plan.cost:,} floats moved "
          f"(SQRT heuristic: {sqrt_plan.cost:,})")
    for nid, d in sorted(plan.d_by_node.items()):
        print(f"  node {nid:2d} {g.nodes[nid].name:10s} d={d}")

    # --- 3a. execute through the TRA reference runtime ----------------------
    rng = np.random.default_rng(0)
    feeds = {n.nid: rng.normal(size=n.shape).astype(np.float32)
             for n in g.nodes if n.kind == "input"}
    vals, stats = execute_graph_tra(g, plan.d_by_node, feeds)
    print(f"\nTRA execution: {stats['kernel_calls']} kernel calls, "
          f"{stats['repartitions']} repartitions")

    # --- 3b. execute through the JAX engine ---------------------------------
    jax_vals = engine.run(g, feeds)
    np.testing.assert_allclose(vals[Z].to_dense(), np.asarray(jax_vals[Z]),
                               rtol=1e-4, atol=1e-5)
    print("TRA result == JAX result  [OK]")

    # --- 4. cache the plan: isomorphic graphs replan in ~µs -----------------
    import time

    from repro.core.plancache import PlanCache

    cache = PlanCache()
    t0 = time.perf_counter()
    eindecomp(g, p=8, offpath_repart=True, cache=cache)   # cold: runs the DP
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    eindecomp(g, p=8, offpath_repart=True, cache=cache)   # warm: cache hit
    warm = time.perf_counter() - t0
    print(f"plan cache: cold {cold * 1e3:.2f}ms -> warm {warm * 1e3:.3f}ms "
          f"({cache.stats})")


if __name__ == "__main__":
    main()
