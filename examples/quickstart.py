"""Quickstart: the paper in one file, on the declarative Program surface.

1. Declare a computation symbolically — named tensors + extended einsum
   expressions (no graphs, no node ids).
2. ``Program.compile`` traces it to an EinGraph and runs EinDecomp (the §8
   DP, through the persistent plan cache) to choose a partitioning vector
   per node.
3. Execute it two ways — through the compiled Program (JAX engine) and
   through the faithful tensor-relational reference runtime (keyed
   sub-tensors, join/agg/repartition) — and check they agree.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import frontend as ein
from repro.core.decomp import plan_sqrt
from repro.core.einsum import resolve_feeds
from repro.core.tra import execute_graph_tra


def main() -> None:
    # --- 1. declare:  Z = softmax_rows((A @ B) / 8) @ C ---------------------
    A = ein.tensor("A", "i j", (64, 128))
    B = ein.tensor("B", "j k", (128, 64))
    C = ein.tensor("C", "k l", (64, 32))
    AB = ein.einsum("i j, j k -> i k", A, B, name="AB")
    scaled = AB / 8.0                                  # scalar ops are maps
    # the paper's §3 softmax, written as extended-einsum expressions
    mx = ein.einsum("i k -> i", scaled, agg="max")
    e = ein.einsum("i k, i -> i k", scaled, mx, combine="expsub", agg="")
    s = ein.einsum("i k -> i", e, agg="sum")
    sm = ein.einsum("i k, i -> i k", e, s, combine="div", agg="")
    Z = ein.einsum("i k, k l -> i l", sm, C, name="Z")

    prog = ein.Program({"Z": Z}, name="quickstart")
    print(prog)
    print(prog.graph)

    # --- 2. compile: EinDecomp for p=8 devices ------------------------------
    run = prog.compile(p=8)
    plan = run.plan
    sqrt_plan = plan_sqrt(prog.graph, 8)
    print(f"\nEinDecomp plan cost: {plan.cost:,} floats moved "
          f"(SQRT heuristic: {sqrt_plan.cost:,})")
    for nid, d in sorted(plan.d_by_node.items()):
        print(f"  node {nid:2d} {prog.graph.nodes[nid].name:10s} d={d}")

    # --- 3a. execute the compiled program (name-keyed I/O) ------------------
    rng = np.random.default_rng(0)
    feeds = {"A": rng.normal(size=(64, 128)).astype(np.float32),
             "B": rng.normal(size=(128, 64)).astype(np.float32),
             "C": rng.normal(size=(64, 32)).astype(np.float32)}
    z = run(feeds)["Z"]

    # --- 3b. cross-check against the TRA reference runtime ------------------
    tra_feeds = resolve_feeds(prog.graph, feeds)       # names -> node ids
    vals, stats = execute_graph_tra(prog.graph, plan.d_by_node, tra_feeds)
    print(f"\nTRA execution: {stats['kernel_calls']} kernel calls, "
          f"{stats['repartitions']} repartitions")
    z_nid = prog.graph.outputs()[0]
    np.testing.assert_allclose(vals[z_nid].to_dense(), np.asarray(z),
                               rtol=1e-4, atol=1e-5)
    print("TRA result == Program result  [OK]")

    # --- 4. cache the plan: isomorphic programs replan in ~µs ---------------
    import time

    from repro.core.plancache import PlanCache

    cache = PlanCache()
    t0 = time.perf_counter()
    prog.compile(p=8, cache=cache)                 # cold: runs the DP
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    prog.compile(p=8, cache=cache)                 # warm: cache hit
    warm = time.perf_counter() - t0
    print(f"plan cache: cold {cold * 1e3:.2f}ms -> warm {warm * 1e3:.3f}ms "
          f"({cache.stats})")

    # --- 5. differentiate: the training program is just another Program -----
    # (a relu chain: core/autodiff covers contractions, add/sub/mul and maps)
    P = ein.einsum("i k, k l -> i l", AB.map("relu"), C, name="P")
    Y = ein.tensor("Y", "i l", (64, 32))
    loss = ein.einsum("i l -> ", (P - Y) ** 2, agg="sum")
    gprog = ein.Program({"loss": loss}).grad(wrt=["B", "C"])
    grun = gprog.compile(p=8)
    gres = grun({**feeds, "Y": np.zeros((64, 32), np.float32)})
    print(f"\ngrad program: loss={float(gres['loss']):.1f}, "
          f"grad_B {gres['grad_B'].shape}, grad_C {gres['grad_C'].shape}, "
          f"fwd+bwd planned jointly at cost {grun.plan.cost:,}")


if __name__ == "__main__":
    main()
