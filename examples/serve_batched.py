"""Batched serving example: prefill a batch of prompts in one sharded
forward, then decode with the jitted serve step (ring-buffer KV caches for
sliding-window archs, recurrent state for SSM archs).

  PYTHONPATH=src python examples/serve_batched.py --arch llama-7b --reduced
  PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x7b --reduced
  PYTHONPATH=src python examples/serve_batched.py --arch xlstm-125m --reduced
"""
import argparse

import numpy as np

from repro.configs import get_config, reduced
from repro.launch.serve import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab, size=(args.batch, args.prompt_len)).astype(np.int32)
    gen, stats = serve(cfg, prompts, max_new=args.max_new)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.max_new}")
    print(f"prefill {stats['t_prefill_s'] * 1e3:.1f}ms, "
          f"decode {stats['tok_per_s']:.1f} tok/s")
    for i, row in enumerate(gen):
        print(f"  seq{i}: {row.tolist()}")
    assert gen.shape == (args.batch, args.max_new)
    assert (gen >= 0).all() and (gen < cfg.vocab_padded).all()


if __name__ == "__main__":
    main()
